"""Data-pipeline dedup: the hash table doing production work.

Streams synthetic batches with a 25% duplicate-document rate through the
HashGraph dedup stage and reports how many rows were replaced per batch.

    PYTHONPATH=src python examples/dedup_pipeline.py
"""
import numpy as np

from repro.data import SyntheticCorpus, dedup_mask, sequence_fingerprints


def main() -> None:
    corpus = SyntheticCorpus(vocab_size=32_000, seq_len=256, seed=3, dup_rate=0.25)
    total, removed = 0, 0
    for step in range(8):
        toks = corpus.batch(step, batch_size=64)
        keep = dedup_mask(toks[:, :-1])
        n_dup = int((~keep).sum())
        fp = sequence_fingerprints(toks[:, :-1])
        uniq = len(np.unique(np.asarray(fp)))
        print(
            f"batch {step}: {n_dup:2d}/64 duplicate rows removed "
            f"({uniq} unique fingerprints)"
        )
        total += 64
        removed += n_dup
    print(f"total: removed {removed}/{total} rows ({removed/total:.1%})")
    assert removed > 0, "dup_rate=0.25 should produce duplicates"


if __name__ == "__main__":
    main()
