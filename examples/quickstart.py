"""Quickstart: build and query a HashGraph, single- and multi-device.

    PYTHONPATH=src python examples/quickstart.py
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashgraph
from repro.core.table import DistributedHashTable


def main() -> None:
    rng = np.random.default_rng(0)
    n = 1 << 14
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))

    # ---- single-device (paper Alg. 1, TPU-native build) --------------------
    hg = hashgraph.build(keys, table_size=n)  # C = 1
    counts = hashgraph.query_count_sorted(hg, queries)
    print(f"single-device: {int(jnp.sum(counts > 0))}/{n} queries hit, "
          f"join size {int(jnp.sum(counts))}")

    # ---- multi-device (paper Alg. 2: bin, split, all-to-all, build) --------
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    table = DistributedHashTable(mesh, ("d",), hash_range=n)
    state = table.build(keys)
    dcounts = table.query(state, queries)
    assert (np.asarray(dcounts) == np.asarray(counts)).all(), "mismatch!"
    print(f"multi-device ({d} devices): identical counts, "
          f"join size {int(table.join_size(state, queries))}, "
          f"0 capacity drops = {int(state.num_dropped) == 0}")


if __name__ == "__main__":
    main()
