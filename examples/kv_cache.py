"""KV-cache mode — upsert, TTL expiry, and capacity-reclaiming eviction.

The cache facade turns the multiset table into a map with lifetimes:
``put`` is insert-or-replace (last writer wins, read-your-writes), TTLs
expire rows against a logical clock the moment it passes their deadline,
and the compaction policy folds expired/superseded rows out of the base
so a steady write stream holds capacity flat.  A YCSB-style zipfian
workload drives the same machinery at the end.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/kv_cache.py
"""
import jax
import numpy as np

from repro.cache import KVCache, WORKLOADS, YCSBWorkload
from repro.core.table import DistributedHashTable


def main() -> None:
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    table = DistributedHashTable(
        mesh, ("d",), hash_range=1 << 12, max_deltas=4, tombstone_capacity=512
    )

    # ---- put / get / delete: map semantics over the multiset core ----------
    cache = KVCache(table, default_ttl=None)
    keys = np.arange(100, 164, dtype=np.uint32)
    cache.put(keys, np.arange(64, dtype=np.int32))
    cache.put(keys[:8], np.full(8, 777, np.int32))  # overwrite: one live row
    print(f"get after overwrite: {cache.get(keys[:4]).tolist()} "
          f"(live rows: {cache.live_count()})")
    cache.delete(keys[:4])
    print(f"after delete: contains {cache.contains(keys[:8]).tolist()}")

    # ---- TTL: rows age out when the clock passes their deadline ------------
    cache.put(keys[32:40], np.arange(8, dtype=np.int32), ttl=3)
    print(f"t={cache.now}: ttl rows visible = {cache.contains(keys[32:40]).all()}")
    cache.advance(3)
    print(f"t={cache.now}: ttl rows visible = {cache.contains(keys[32:40]).any()} "
          f"(live rows: {cache.live_count()})")

    # ---- eviction: expired capacity is reclaimed, not leaked ---------------
    hot = np.arange(5000, 5064, dtype=np.uint32)
    allocs = []
    for t in range(8):
        cache.put(hot, np.full(64, t, np.int32), ttl=2)  # replace + re-arm
        cache.tick()
        s = cache.stats()
        allocs.append(s.base_rows + s.delta_rows)
    print(f"steady upsert+expire: allocated rows per cycle {allocs}")
    print(f"maintenance: {cache.folds} folds, {cache.evictions} evictions "
          f"(expired tombstones now: {cache.stats().tombstone_expired})")
    reclaimed = cache.evict_expired()
    print(f"forced eviction reclaimed {reclaimed} rows; "
          f"live count {cache.live_count()}")

    # ---- a YCSB-B read-heavy zipfian burst through the cache ---------------
    w = YCSBWorkload(WORKLOADS["B"], 1 << 10, theta=0.99, batch=128, seed=1)
    cache2 = KVCache(table, w.load_keys(), w.load_values())
    reads = writes = 0
    for kind, kk, vv in w.batches(1024):
        if kind == "read":
            reads += kk.shape[0]
            cache2.get(kk)
        else:
            writes += kk.shape[0]
            cache2.put(kk, vv)
    print(f"YCSB-B: {reads} reads / {writes} upserts, "
          f"live {cache2.live_count()}, folds {cache2.folds}")


if __name__ == "__main__":
    main()
