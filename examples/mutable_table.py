"""Mutable distributed table — the plan/execute API over versioned state.

Builds a table, inserts a batch, deletes some keys, re-inserts one of
them, and retrieves — first eagerly, then as ONE jitted program built
around a pre-sized plan (zero device→host syncs after planning), and
finally compacts the deltas + tombstones back into a single base graph.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/mutable_table.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import DistributedHashTable, retrieval_to_lists


def main() -> None:
    rng = np.random.default_rng(0)
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = 1 << 12

    table = DistributedHashTable(mesh, ("d",), hash_range=n)
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    values = jnp.arange(n, dtype=jnp.int32)

    # ---- eager mutation flow ----------------------------------------------
    state = table.init(keys, values)  # versioned TableState
    fresh = jnp.asarray(rng.integers(n, 2 * n, size=64, dtype=np.uint32))
    state = state.insert(fresh, jnp.arange(n, n + 64, dtype=jnp.int32))
    state = state.delete(keys[:32])  # tombstones: hides base rows
    state = state.insert(keys[:8], jnp.arange(9000, 9008, dtype=jnp.int32))
    print(f"epoch {state.epoch} (deltas), drops {int(state.num_dropped)}")

    queries = jnp.concatenate([keys[:64], fresh[:32], keys[100:132]])
    plan = table.plan_retrieve(state, queries)  # counts round sizes caps
    res = plan(state, queries)
    lists = retrieval_to_lists(res)
    print(
        f"planned caps out={plan.out_capacity} seg={plan.seg_capacity}; "
        f"query 0 -> {np.asarray(lists[0]).tolist()} "
        f"(deleted key, reinserted value only)"
    )

    # ---- the same flow as one jitted program ------------------------------
    @jax.jit
    def program(k, v, ins_k, ins_v, dead):
        st = table.init(k, v)
        st = st.insert(ins_k, ins_v)
        st = st.delete(dead)
        return plan(st, queries)

    res2 = program(keys, values, fresh, jnp.arange(64, dtype=jnp.int32), keys[:32])
    print(f"jitted program: drops {int(res2.num_dropped)}")

    # ---- compaction: fold deltas + tombstones into a fresh base -----------
    # capacity=None sizes the rebuild from a live-count round (rows that
    # survive the fold), so steady update/compact cycles keep the base flat.
    compacted = state.compact()
    assert compacted.epoch == 0
    same = np.array_equal(
        np.asarray(table.query(state, queries)),
        np.asarray(table.query(compacted, queries)),
    )
    print(f"compacted: 1 layer again, answers identical = {same}")

    # ---- auto-compaction: fold when the state says it is due ---------------
    # should_compact() fires on a full delta ring, a tombstone-load
    # threshold, or tombstone overflow; insert(..., auto_compact=True)
    # folds first instead of raising "delta ring full".  Every read path
    # stays single-route (one exchange round per query/retrieve, whatever
    # the delta depth) because inserts build deltas on the base's splits.
    state = compacted
    for step in range(3 * table.max_deltas):
        batch = jnp.asarray(rng.integers(0, n, size=64, dtype=np.uint32))
        state = state.insert(batch, auto_compact=True)  # never raises
    print(
        f"after {3 * table.max_deltas} auto-compacting inserts: "
        f"epoch {state.epoch}, should_compact={state.should_compact()}"
    )


if __name__ == "__main__":
    main()
