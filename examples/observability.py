"""Observability — per-phase latency breakdown, metrics export, traces.

One :class:`~repro.obs.registry.MetricsRegistry` per server collects
every counter, gauge, and latency histogram the serving stack produces:
the batcher, the AOT executor grid, maintenance folds, and each
:class:`AsyncFrontend`.  This example drives a short request stream and
then shows the three read sides:

* the **per-phase latency breakdown** — every traced request records
  admission / linger / dispatch / device / scatter durations into
  ``trace_phase_seconds{phase=...}`` histograms;
* the **device-cost profile** — the jaxpr-walking accountant attached to
  warmup reports collectives and bytes per compiled executor (the fused
  read path must show exactly 2 all-to-alls at every delta depth);
* the **exporters** — Prometheus text for scraping, JSONL for artifact
  stamping, and the bounded trace ring dumped as one JSON object per
  request.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/observability.py
"""
import tempfile

import jax
import numpy as np

from repro.core.table import DistributedHashTable
from repro.obs import PHASES, render_prometheus
from repro.serve_table import (
    AsyncFrontend,
    CompactionPolicy,
    MicroBatcher,
    TableServer,
)


def main() -> None:
    rng = np.random.default_rng(0)
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = 1 << 12

    table = DistributedHashTable(
        mesh, ("d",), hash_range=n, max_deltas=4, tombstone_capacity=256
    )
    keys = rng.integers(0, n, size=n, dtype=np.uint32)
    server = TableServer(
        table,
        keys,
        np.arange(n, dtype=np.int32),
        policy=CompactionPolicy(max_delta_depth=2, fold_k=1),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )

    # Warmup profiles each compiled executor's collective footprint.
    warm = server.warm(buckets=(8, 16), depths=(0, 1, 2), fold_horizon=1)
    print(f"warmed {warm.entries} executables; per-executor device cost:")
    for p in warm.profiles:
        print(
            f"  {p.kind:5s} bucket={p.bucket:<3d} depth={p.depth}  "
            f"all_to_alls={p.all_to_alls}  "
            f"collective_bytes={p.total_collective_bytes}  "
            f"flop/byte={p.flop_per_byte:.2f}"
        )

    # ---- a traced request stream -------------------------------------------
    with AsyncFrontend(server, linger=0.002, flush_keys=16) as fe:
        futs = [
            fe.submit_query(rng.choice(keys, size=8).astype(np.uint32))
            for _ in range(48)
        ]
        fe.submit_insert(rng.integers(n, 2 * n, size=16, dtype=np.uint32))
        server.drain()
        for f in futs:
            f.result(timeout=10.0)

        # ---- per-phase latency breakdown -----------------------------------
        snap = fe.metrics()  # ONE atomic sample of the shared registry
        print("\nper-phase latency (where each request's time went):")
        for phase in PHASES:
            h = snap.histogram("trace_phase_seconds", {"phase": phase})
            print(
                f"  {phase:10s} n={h.count:<4d} mean={h.mean * 1e3:7.3f}ms  "
                f"p50={h.p50 * 1e3:7.3f}ms  p99={h.p99 * 1e3:7.3f}ms"
            )
        total = snap.histogram("request_latency_seconds")
        print(
            f"  {'total':10s} n={total.count:<4d} "
            f"mean={total.mean * 1e3:7.3f}ms  p50={total.p50 * 1e3:7.3f}ms  "
            f"p99={total.p99 * 1e3:7.3f}ms"
        )

        # ---- the trace ring: per-request records, JSONL-dumpable -----------
        recent = fe.tracer.recent()
        t = recent[-1]
        marks = t.durations()
        print(
            f"\nlast trace (id {t.trace_id}, {t.size} keys, bucket "
            f"{t.bucket}): "
            + "  ".join(f"{ph}={marks[ph] * 1e3:.3f}ms" for ph in marks)
        )
        with tempfile.NamedTemporaryFile("r", suffix=".jsonl") as f_tmp:
            wrote = fe.tracer.dump_jsonl(f_tmp.name)
            print(f"dumped {wrote} trace records to {f_tmp.name}")

    # ---- exporters ----------------------------------------------------------
    snap = server.metrics()
    text = render_prometheus(snap)
    wanted = (
        "serve_reads_total",
        "aot_hits_total",
        "aot_misses_total",
        "executor_all_to_alls",
        "frontend_completed_total",
        "maintenance_folds_total",
    )
    print("\nPrometheus export (selected lines):")
    for line in text.splitlines():
        if line.startswith(wanted):
            print(f"  {line}")
    print(
        f"\nfull export: {len(text.splitlines())} lines, "
        f"{len(snap.as_dict())} metrics — also available as "
        "render_jsonl(snap) / write_bench_json(..., snapshot=snap)"
    )


if __name__ == "__main__":
    main()
