"""Async serving — futures API, AOT warmup, deadline batching.

The :class:`AsyncFrontend` puts a request queue in front of the
:class:`TableServer`: callers get a ``Future`` back immediately, a
dispatcher thread flushes the queue when a pow2 bucket's worth of keys
accumulates **or** the oldest request's deadline nears, and a scatter
thread resolves futures while the dispatcher already works on the next
batch.  ``server.warm(...)`` AOT-compiles the whole reachable executor
grid first, so no live request ever traces or compiles.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_async.py
"""
import time

import jax
import numpy as np

from repro.core.table import DistributedHashTable
from repro.serve_table import (
    AsyncFrontend,
    CompactionPolicy,
    MicroBatcher,
    TableServer,
)


def main() -> None:
    rng = np.random.default_rng(0)
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = 1 << 12

    table = DistributedHashTable(
        mesh, ("d",), hash_range=n, max_deltas=4, tombstone_capacity=256
    )
    keys = rng.integers(0, n, size=n, dtype=np.uint32)
    values = np.arange(n, dtype=np.int32)

    # write_bucket fixes every insert delta to one geometry — the property
    # that makes the executor grid finite and therefore AOT-warmable.
    server = TableServer(
        table,
        keys,
        values,
        policy=CompactionPolicy(max_delta_depth=2, fold_k=1),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )

    # ---- AOT warmup: compile the grid before the first request -------------
    t0 = time.perf_counter()
    warm = server.warm(buckets=(8, 16, 32), depths=(0, 1, 2), fold_horizon=1)
    print(
        f"warmup: {warm.entries} executables in {time.perf_counter() - t0:.1f}s "
        f"(buckets {warm.buckets}, depths {warm.depths}, "
        f"fold horizon {warm.fold_horizon})"
    )

    # ---- the futures API ----------------------------------------------------
    with AsyncFrontend(server, linger=0.002, flush_keys=32) as fe:
        # submit_query never blocks on execution: each call returns a Future
        # the scatter thread resolves once its batch lands.
        futs = [
            fe.submit_query(rng.choice(keys, size=8).astype(np.uint32))
            for _ in range(64)
        ]
        # urgent request: a tight deadline pulls the flush forward instead of
        # waiting out the linger window.
        urgent = fe.submit_query(keys[:4], deadline=fe.clock() + 0.001)

        res = urgent.result(timeout=5.0)
        print(f"urgent request answered at seqno {res.seqno}: {res.counts.tolist()}")

        # writes flow through a bounded backlog into the writer loop; reads
        # keep resolving against the last published snapshot meanwhile.
        # (16 keys = two write_bucket chunks -> depth 2, one policy fold:
        # exactly the structures warmed above, so coverage stays 100%.)
        fresh = rng.integers(n, 2 * n, size=16, dtype=np.uint32)
        fe.submit_insert(fresh)
        fe.submit_delete(keys[:8])
        server.drain()
        after = fe.submit_query(fresh[:4]).result(timeout=5.0)
        print(f"after insert (seqno {after.seqno}): {after.counts.tolist()}")

        for f in futs:
            f.result(timeout=5.0)
        st = fe.stats()
        print(
            f"front end: {st.completed}/{st.submitted} answered in "
            f"{st.batches_dispatched} batches "
            f"({st.batches_fill} fill-triggered, {st.batches_due} deadline-"
            f"triggered), write backpressure waits {st.write_backpressure_waits}"
        )

    # ---- the whole point: zero live compiles --------------------------------
    w = server.stats().warmup
    print(
        f"AOT coverage {w.coverage:.0%}: {w.aot_hits} reads on warmed "
        f"executables, {w.aot_misses} fell back to the jit path"
    )


if __name__ == "__main__":
    main()
