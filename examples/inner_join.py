"""Database inner-join via the distributed HashGraph (paper's headline app).

Two relations R(key, payload) and S(key, payload); the join size and the
matched row pairs for a probe sample are computed through the multi-device
hash table and verified against a numpy oracle.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/inner_join.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashgraph
from repro.core.table import DistributedHashTable


def main() -> None:
    rng = np.random.default_rng(7)
    n_r, n_s = 1 << 15, 1 << 14
    # R: build side (fact table); S: probe side, 50% of keys overlap
    r_keys = rng.integers(0, 1 << 16, size=n_r, dtype=np.uint32)
    s_keys = np.concatenate(
        [
            rng.choice(r_keys, size=n_s // 2),
            rng.integers(1 << 16, 1 << 17, size=n_s // 2).astype(np.uint32),
        ]
    )
    rng.shuffle(s_keys)

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    table = DistributedHashTable(mesh, ("d",), hash_range=n_r)
    # values = R row ids ride through the exchange for the join payload
    state = table.build(
        jnp.asarray(r_keys), values=jnp.arange(n_r, dtype=jnp.int32)
    )

    join_size = int(table.join_size(state, jnp.asarray(s_keys)))
    # numpy oracle
    from collections import Counter

    c = Counter(r_keys.tolist())
    expect = sum(c[int(k)] for k in s_keys)
    assert join_size == expect, (join_size, expect)
    print(f"|R ⋈ S| = {join_size} (verified), R={n_r} S={n_s} devices={d}")

    # membership + first-match row id for a probe sample (single-device API)
    hg = hashgraph.build(jnp.asarray(r_keys), table_size=n_r)
    sample = jnp.asarray(s_keys[:8])
    rows = hashgraph.lookup_first(hg, sample)
    print("probe sample → first matching R row:", np.asarray(rows))


if __name__ == "__main__":
    main()
