"""Database inner-join via the distributed HashGraph (paper's headline app).

Two relations R(key, payload) and S(key, payload).  The join is *materialized*
through the retrieval subsystem: ``inner_join`` returns every matched
``(S row, R row)`` pair, and ``retrieve`` returns the full CSR of R-rows per
probe key — both verified against a numpy dict-of-lists oracle.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/inner_join.py
"""
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hashgraph
from repro.core.table import (
    DistributedHashTable,
    join_to_pairs,
    retrieval_to_lists,
)


def main() -> None:
    rng = np.random.default_rng(7)
    n_r, n_s = 1 << 15, 1 << 14
    # R: build side (fact table); S: probe side, 50% of keys overlap
    r_keys = rng.integers(0, 1 << 16, size=n_r, dtype=np.uint32)
    s_keys = np.concatenate(
        [
            rng.choice(r_keys, size=n_s // 2),
            rng.integers(1 << 16, 1 << 17, size=n_s // 2).astype(np.uint32),
        ]
    )
    rng.shuffle(s_keys)

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    table = DistributedHashTable(mesh, ("d",), hash_range=n_r)
    # values = R row ids ride through the exchange for the join payload
    state = table.build(
        jnp.asarray(r_keys), values=jnp.arange(n_r, dtype=jnp.int32)
    )

    # numpy oracle: key -> list of R row ids
    oracle = defaultdict(list)
    for row, k in enumerate(r_keys.tolist()):
        oracle[k].append(row)
    expect_pairs = sorted(
        (i, r) for i, k in enumerate(s_keys) for r in oracle[int(k)]
    )

    # --- join cardinality (counting path) ---------------------------------
    join_size = int(table.join_size(state, jnp.asarray(s_keys)))
    assert join_size == len(expect_pairs), (join_size, len(expect_pairs))
    print(f"|R ⋈ S| = {join_size} (verified), R={n_r} S={n_s} devices={d}")

    # --- materialized join (retrieval path) -------------------------------
    cap = 8 * ((2 * len(expect_pairs) // d + 64) // 8)
    join = table.inner_join(
        state, jnp.asarray(s_keys), out_capacity=cap, seg_capacity=cap
    )
    assert int(join.num_dropped) == 0, "raise out_capacity/seg_capacity"
    pairs = join_to_pairs(join)
    assert sorted(map(tuple, pairs.tolist())) == expect_pairs
    print(f"materialized {len(pairs)} (S row, R row) pairs (verified)")

    # --- CSR retrieval of all matching R rows per probe key ---------------
    res = table.retrieve(
        state, jnp.asarray(s_keys), out_capacity=cap, seg_capacity=cap
    )
    assert int(res.num_dropped) == 0
    per_query = retrieval_to_lists(res)
    for i in range(0, n_s, n_s // 7):
        assert sorted(np.asarray(per_query[i]).tolist()) == sorted(
            oracle[int(s_keys[i])]
        )
    sample = [np.asarray(per_query[i]).tolist() for i in range(4)]
    print("probe sample → matching R rows:", sample)

    # membership + first-match row id (single-device API, unchanged)
    hg = hashgraph.build(jnp.asarray(r_keys), table_size=n_r)
    rows = hashgraph.lookup_first(hg, jnp.asarray(s_keys[:8]))
    print("probe sample → first matching R row:", np.asarray(rows))


if __name__ == "__main__":
    main()
