"""Continuous-batching serving demo over the smoke-scale qwen3 model.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys


def main() -> None:
    from repro.launch import serve as serve_mod

    sys.argv = [
        "serve",
        "--arch", "qwen3_4b",
        "--requests", "10",
        "--slots", "4",
        "--prompt-len", "24",
        "--max-new", "12",
    ]
    serve_mod.main()


if __name__ == "__main__":
    main()
