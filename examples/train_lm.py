"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production stack — config registry, HashGraph-dedup data
pipeline, AdamW + cosine schedule, remat train step, async checkpointing
— at a CPU-runnable scale (qwen3 family, ~100M params).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.launch import train as train_mod

    sys.argv = [
        "train",
        "--arch", "qwen3_4b",
        "--smoke",
        # ~100M params: 12 layers × d_model 512 over the qwen3 smoke family
        "--layers", "12",
        "--d-model", "512",
        "--vocab", "32000",
        "--steps", str(args.steps),
        "--batch", "8",
        "--seq", "256",
        "--microbatches", "2",
        "--dedup", "local",
        "--checkpoint-dir", args.checkpoint_dir,
        "--checkpoint-every", "100",
    ]
    train_mod.main()


if __name__ == "__main__":
    main()
