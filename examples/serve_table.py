"""Serving the distributed table — mixed insert/delete/query traffic.

A :class:`TableServer` drives the full serving loop: ragged read requests
coalesce onto cached static shapes through the micro-batcher, a writer
loop applies queued mutations to a shadow state and publishes immutable
seqno-stamped snapshots, and compaction runs as an incremental background
fold that never touches the read path.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_table.py
"""
import jax
import numpy as np

from repro.core.table import DistributedHashTable
from repro.serve_table import CompactionPolicy, TableServer


def main() -> None:
    rng = np.random.default_rng(0)
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = 1 << 12

    table = DistributedHashTable(mesh, ("d",), hash_range=n, max_deltas=6)
    keys = rng.integers(0, n, size=n, dtype=np.uint32)
    values = np.arange(n, dtype=np.int32)

    # seqno-0 snapshot; the policy folds the 2 oldest deltas whenever the
    # ring fills, so the write stream below never hits a ring-full error.
    server = TableServer(
        table, keys, values, policy=CompactionPolicy(max_delta_depth=6, fold_k=2)
    )

    # ---- reads: ragged requests, one fused execution ----------------------
    requests = [keys[:5], keys[100:103], keys[200:264]]
    counts, seqno = server.query_many(requests)
    print(f"seqno {seqno}: request sizes {[len(r) for r in requests]} "
          f"-> first counts {counts[0].tolist()}")

    # ---- mixed write traffic, applied by the writer loop ------------------
    for wave in range(12):
        fresh = rng.integers(n, 2 * n, size=64, dtype=np.uint32)
        server.submit_insert(fresh, np.arange(64, dtype=np.int32) + 1000 * wave)
        if wave % 3 == 2:
            server.submit_delete(keys[wave * 16 : wave * 16 + 16])
    server.drain()  # apply + publish everything queued
    stats = server.stats()
    print(f"after traffic: seqno {stats.seqno}, delta depth "
          f"{stats.shadow.delta_depth}, folds {stats.folds}, "
          f"full compacts {stats.full_compacts}")

    # ---- a background fold while reads keep flowing -----------------------
    pre = server.current().seqno
    thread = server.fold_async(k=2) if stats.shadow.delta_depth > 2 else None
    reads = 0
    while thread is not None and thread.is_alive():
        _, seq = server.query_many([keys[:32]])
        assert seq == pre  # the old snapshot serves until the fold publishes
        reads += 1
    if thread is not None:
        thread.join()
    print(f"background fold: {reads} reads served mid-fold at seqno {pre}, "
          f"now at seqno {server.current().seqno}")

    # ---- provenance read: which layer answered? ---------------------------
    (result,), _ = server.retrieve_many([keys[:4]], per_layer_counts=True)
    values4, layer_counts = result
    print(f"per-key values {[v.tolist() for v in values4]} with per-layer "
          f"breakdown\n{layer_counts}")

    # ---- server metrics ----------------------------------------------------
    final = server.stats()
    b = final.batcher
    print(f"served {final.reads} requests in {b.batches} fused batches, "
          f"plan-cache hit rate {b.cache_hits}/{b.cache_hits + b.cache_misses}, "
          f"pad fraction {b.pad_fraction:.2f}, "
          f"skew fallbacks {final.skew_fallbacks}")


if __name__ == "__main__":
    main()
