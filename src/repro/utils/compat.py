"""Version shims for jax APIs that moved between releases.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
(and its replication-check kwarg was renamed ``check_rep`` → ``check_vma``
along the way).  Every call site in this repo imports the shim and uses the
modern keyword spelling; the shim translates for older jax.
"""
from __future__ import annotations

try:  # jax >= 0.6
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, axis_names=None, **kwargs):
    """``jax.shard_map`` with a stable keyword interface across jax versions.

    ``axis_names`` (the manual axes, new-style) maps onto the old API's
    complementary ``auto`` set.
    """
    kwargs[_CHECK_KW] = check_vma
    if axis_names is not None:
        if _CHECK_KW == "check_vma":
            kwargs["axis_names"] = axis_names
        else:
            kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


try:  # jax >= 0.4.38
    from jax.lax import axis_size
except ImportError:  # older jax: the axis frame holds the static size
    import jax.core as _core

    def axis_size(name):
        """Static size of a shard_map mesh axis (python int)."""
        return _core.axis_frame(name)


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across the constructor-signature change.

    Newer jax takes ``(axis_sizes, axis_names)``; older jax takes a single
    ``((name, size), ...)`` shape tuple.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


def compiled_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` as a dict (older jaxlib returns a list)."""
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


__all__ = ["shard_map", "axis_size", "abstract_mesh", "compiled_cost_analysis"]
