"""Integer/shape arithmetic helpers."""
from __future__ import annotations

import numpy as np


def cdiv(a: int, b: int) -> int:
    """Ceiling division for non-negative python ints."""
    if b <= 0:
        raise ValueError(f"cdiv divisor must be positive, got {b}")
    return -(-a // b)


def next_multiple(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return cdiv(x, m) * m


_DTYPE_BYTES = {
    "float32": 4,
    "bfloat16": 2,
    "float16": 2,
    "int32": 4,
    "uint32": 4,
    "int8": 1,
    "uint8": 1,
    "bool": 1,
    "int64": 8,
    "uint64": 8,
    "float64": 8,
}


def bytes_of(shape, dtype) -> int:
    """Bytes of an array with ``shape`` and ``dtype`` (dtype may be str or np dtype)."""
    name = np.dtype(dtype).name if not isinstance(dtype, str) else dtype
    nbytes = _DTYPE_BYTES.get(name)
    if nbytes is None:
        nbytes = np.dtype(name).itemsize
    n = 1
    for s in shape:
        n *= int(s)
    return n * nbytes


def human_bytes(n: float) -> str:
    """Pretty-print a byte count."""
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n:.2f}EiB"
