"""Pytree helpers (param counting, norms, sizes)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of elements across all leaves (works on ShapeDtypeStructs too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape)) if l.shape else 1 for l in leaves))


def tree_size_bytes(tree) -> int:
    """Total bytes across leaves (works on ShapeDtypeStructs too)."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        n = int(np.prod(l.shape)) if l.shape else 1
        total += n * np.dtype(l.dtype).itemsize
    return total


def tree_global_norm(tree) -> jax.Array:
    """Global L2 norm over all leaves of a pytree of arrays."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    return jnp.sqrt(sq)
