"""Small shared helpers used across the framework."""
from repro.utils.numerics import cdiv, next_multiple, bytes_of, human_bytes
from repro.utils.treeutil import tree_size_bytes, tree_param_count, tree_global_norm

__all__ = [
    "cdiv",
    "next_multiple",
    "bytes_of",
    "human_bytes",
    "tree_size_bytes",
    "tree_param_count",
    "tree_global_norm",
]
