"""Fault-tolerant training loop.

Production behaviors, exercised at smoke scale in tests:

* **checkpoint/restart** — async sharded snapshots every
  ``checkpoint_every`` steps; on construction the trainer restores the
  latest checkpoint if one exists and resumes the data pipeline by step
  counter (loader batches are pure functions of step — resume is exact).
* **elastic re-sharding** — restore accepts a different mesh than the
  writer's: arrays are saved unsharded and re-``device_put`` against the
  current mesh's specs.
* **straggler mitigation** — per-step wall time is tracked with an EWMA;
  steps slower than ``straggler_factor ×`` the EWMA are counted and logged.
  On real multi-host pods this signal feeds the coordinator's
  replace-or-reshard decision; here the detector + its counters are the
  testable artifact (single-process CPU can only simulate the signal).
* **failure injection** — ``crash_at_step`` raises mid-run (tests restart
  semantics end-to-end: a new Trainer on the same directory resumes and
  reaches the same final loss as an uninterrupted run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed import sharding as shd
from repro.models.api import ModelBundle
from repro.train.step import TrainStepConfig, make_train_step
from repro.optim import adamw_init


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 0  # 0 = off
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2
    crash_at_step: Optional[int] = None  # failure injection (tests)


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(
        self,
        bundle: ModelBundle,
        loader,
        tcfg: TrainStepConfig = TrainStepConfig(),
        run_cfg: TrainerConfig = TrainerConfig(),
        log_fn: Callable[[str], None] = print,
    ):
        self.bundle = bundle
        self.loader = loader
        self.tcfg = tcfg
        self.cfg = run_cfg
        self.log = log_fn
        self.parallel = bundle.parallel
        self.step = 0
        self.metrics_history: list[dict] = []
        self.straggler_steps = 0
        self._ewma: Optional[float] = None

        self._ckpt = (
            CheckpointManager(run_cfg.checkpoint_dir)
            if run_cfg.checkpoint_dir
            else None
        )
        self._build_state()
        self._step_fn = self._jit_step()
        if self._ckpt is not None and self._ckpt.latest_step() is not None:
            self._restore()

    # -- state ---------------------------------------------------------------
    def _shardings(self):
        if self.parallel is None or self.parallel.mesh is None:
            return None, None
        pshapes = self.bundle.param_shapes()
        pspecs = shd.param_pspecs(pshapes, self.parallel)
        params_sh = shd.to_named(self.parallel.mesh, pspecs)
        opt_shapes = jax.eval_shape(
            lambda p: adamw_init(p, self.tcfg.adamw), pshapes
        )
        from jax.sharding import PartitionSpec as P

        opt_specs = {"step": P(), "m": pspecs, "v": pspecs}
        if self.parallel.grad_compression:
            opt_specs["ef_error"] = pspecs
        opt_sh = shd.to_named(self.parallel.mesh, opt_specs)
        return params_sh, opt_sh

    def _build_state(self):
        key = jax.random.key(self.cfg.seed)
        params_sh, opt_sh = self._shardings()
        from repro.train.step import make_train_state

        if params_sh is not None:
            init = jax.jit(
                lambda k: make_train_state(self.bundle, self.tcfg, k),
                out_shardings=(params_sh, opt_sh),
            )
            self.params, self.opt_state = init(key)
        else:
            self.params, self.opt_state = make_train_state(
                self.bundle, self.tcfg, key
            )
        self._params_sh, self._opt_sh = params_sh, opt_sh

    def _jit_step(self):
        fn = make_train_step(self.bundle, self.tcfg)
        if self._params_sh is not None:
            return jax.jit(
                fn,
                out_shardings=(self._params_sh, self._opt_sh, None),
                donate_argnums=(0, 1),
            )
        return jax.jit(fn, donate_argnums=(0, 1))

    # -- checkpoint / restore ---------------------------------------------------
    def _save(self):
        if self._ckpt is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        self._ckpt.save(self.step, tree, extra={"loader_step": self.loader.state.step})

    def _restore(self):
        like = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self._params_sh is not None:
            sh = {"params": self._params_sh, "opt": self._opt_sh}
        step, tree, extra = self._ckpt.restore(like, shardings=sh)
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        self.loader.skip_to(int(extra.get("loader_step", step)))
        self.log(f"[trainer] restored step {step} from {self.cfg.checkpoint_dir}")

    # -- loop ----------------------------------------------------------------------
    def run(self) -> dict:
        while self.step < self.cfg.total_steps:
            if (
                self.cfg.crash_at_step is not None
                and self.step == self.cfg.crash_at_step
            ):
                # flush pending snapshots, then die mid-training.
                if self._ckpt is not None:
                    self._ckpt.wait()
                raise SimulatedFailure(f"injected failure at step {self.step}")
            batch = self.loader.next_batch()
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self._track_stragglers(dt)
            self.step += 1
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step_time_s"] = dt
                self.metrics_history.append({"step": self.step, **m})
                self.log(
                    f"[trainer] step {self.step} loss={m['loss']:.4f} "
                    f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e} {dt*1e3:.0f}ms"
                )
            if (
                self.cfg.checkpoint_every
                and self.step % self.cfg.checkpoint_every == 0
            ):
                self._save()
        if self._ckpt is not None:
            self._save()
            self._ckpt.wait()
        return {
            "final_step": self.step,
            "stragglers": self.straggler_steps,
            "history": self.metrics_history,
        }

    def _track_stragglers(self, dt: float):
        if self._ewma is None:
            self._ewma = dt
            return
        if dt > self.cfg.straggler_factor * self._ewma:
            self.straggler_steps += 1
            self.log(
                f"[trainer] straggler step: {dt*1e3:.0f}ms vs EWMA "
                f"{self._ewma*1e3:.0f}ms"
            )
        self._ewma = (1 - self.cfg.ewma_alpha) * self._ewma + self.cfg.ewma_alpha * dt
