"""Training layer: jitted train step, trainer loop, pipeline parallelism."""
from repro.train.step import TrainStepConfig, make_train_state, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = [
    "TrainStepConfig",
    "make_train_state",
    "make_train_step",
    "Trainer",
    "TrainerConfig",
]
