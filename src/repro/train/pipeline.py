"""GPipe-style pipeline parallelism over a mesh axis (dense archs).

The scanned layer stack ``params["layers"]`` (leading dim = num_periods)
is split across the ``stage`` axis: each stage owns ``num_periods/S``
contiguous periods.  A step runs ``M + S - 1`` pipeline ticks; at tick
``t`` stage ``s`` processes microbatch ``t - s``, then hands its
activation to stage ``s+1`` with a ``ppermute`` — the JAX-native
equivalent of the paper's point-to-point NVLink hops, with autodiff
producing the reversed (backward) schedule through the same permutes.

Scope: decoder-only dense archs (no MoE-in-PP — MoE uses EP via the
paper's exchange instead).  Embedding and head weights are replicated;
their gradient contributions are psum'd over the stage axis.  The bubble
fraction is the textbook ``(S-1)/(M+S-1)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.utils.compat import shard_map

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import transformer as tfm
from repro.models.api import ModelBundle
from repro.optim import adamw_update, clip_by_global_norm
from repro.train.step import TrainStepConfig


def pipeline_param_specs(stage_axis: str):
    """in_specs pytree hint: layer stack sharded on the stage axis."""

    def spec_for(path_key: str):
        return P(stage_axis) if path_key == "layers" else P()

    return spec_for


def _run_local_periods(local_layers, x, positions, cfg: ArchConfig):
    def period_step(x, pp):
        for j, bt in enumerate(cfg.block_pattern):
            x, _ = tfm.apply_block_train(bt, pp[f"b{j}"], x, positions, cfg, None)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(period_step), x, local_layers)
    return x


def make_pp_train_step(
    bundle: ModelBundle,
    tcfg: TrainStepConfig,
    *,
    stage_axis: str = "stage",
    num_microbatches: int = 4,
):
    cfg = bundle.cfg
    parallel = bundle.parallel
    assert parallel is not None and parallel.mesh is not None
    assert not cfg.is_moe, "PP path covers dense archs; MoE uses EP"
    mesh = parallel.mesh
    s_stages = mesh.shape[stage_axis]
    assert cfg.num_periods % s_stages == 0, (
        f"{cfg.num_periods} periods not divisible by {s_stages} stages"
    )
    m = num_microbatches

    def pipelined_loss(params, tokens):
        """Inside shard_map: params['layers'] is the LOCAL period slice."""
        stage = jax.lax.axis_index(stage_axis)
        b, sp1 = tokens.shape
        assert b % m == 0, f"batch {b} % microbatches {m}"
        mb = b // m
        toks = tokens.reshape(m, mb, sp1)
        seq = sp1 - 1
        positions = jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32), (mb, seq)
        )
        dt = jnp.dtype(cfg.dtype)
        ticks = m + s_stages - 1
        perm = [(i, i + 1) for i in range(s_stages - 1)]

        def tick(carry, t):
            x_in, loss_acc, cnt = carry
            # stage 0 ingests microbatch t (zeros during drain ticks)
            idx0 = jnp.clip(t, 0, m - 1)
            tok0 = jax.lax.dynamic_index_in_dim(toks, idx0, 0, keepdims=False)
            x0 = tfm._embed(params, tok0[:, :-1], cfg)
            x = jnp.where(stage == 0, x0, x_in.astype(dt))
            y = _run_local_periods(params["layers"], x, positions, cfg)
            # last stage emits loss for microbatch t - (S-1)
            idx_l = t - (s_stages - 1)
            tok_l = jax.lax.dynamic_index_in_dim(
                toks, jnp.clip(idx_l, 0, m - 1), 0, keepdims=False
            )
            logits = tfm._head(params, y, cfg)
            ce = L.softmax_cross_entropy_logits(logits, tok_l[:, 1:])
            valid = (
                (idx_l >= 0) & (idx_l < m) & (stage == s_stages - 1)
            ).astype(jnp.float32)
            x_next = jax.lax.ppermute(y.astype(jnp.float32), stage_axis, perm)
            return (x_next, loss_acc + ce * valid, cnt + valid), None

        x0 = jnp.zeros((mb, seq, cfg.d_model), jnp.float32)
        (_, loss_acc, cnt), _ = jax.lax.scan(
            tick, (x0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(ticks),
        )
        # loss lives on the last stage; share it with everyone.
        total = jax.lax.psum(loss_acc, stage_axis)
        n = jax.lax.psum(cnt, stage_axis)
        return total / jnp.maximum(n, 1.0)

    def body(params, opt_state, tokens):
        loss, grads = jax.value_and_grad(pipelined_loss)(params, tokens)
        # layer grads are local to the stage; replicated leaves (embed,
        # head, final_norm) accumulate across stages.
        grads = {
            k: (v if k == "layers" else jax.tree.map(
                lambda g: jax.lax.psum(g, stage_axis), v))
            for k, v in grads.items()
        }
        grads, gnorm_local = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = tcfg.lr_at(opt_state["step"] + 1)  # schedule counts from 1
        new_params, new_opt = adamw_update(
            params,
            grads,
            {k: opt_state[k] for k in ("step", "m", "v")},
            lr,
            tcfg.adamw,
        )
        metrics = {
            "loss": loss,
            "ce": loss,
            "moe_aux": jnp.zeros((), jnp.float32),
            "grad_norm": gnorm_local,
            "lr": lr,
        }
        return new_params, new_opt, metrics

    def tree_specs(tree, layer_spec_dim0: bool):
        def leaf_spec(leaf):
            nd = getattr(leaf, "ndim", None)
            if nd is None:
                nd = len(leaf.shape)
            return P(stage_axis, *([None] * (nd - 1)))

        return jax.tree.map(leaf_spec, tree)

    def step(params, opt_state, batch):
        pspecs = {
            k: (tree_specs(v, True) if k == "layers" else jax.tree.map(lambda _: P(), v))
            for k, v in params.items()
        }
        ospecs = {
            "step": P(),
            "m": {k: (tree_specs(v, True) if k == "layers" else jax.tree.map(lambda _: P(), v))
                  for k, v in opt_state["m"].items()},
            "v": {k: (tree_specs(v, True) if k == "layers" else jax.tree.map(lambda _: P(), v))
                  for k, v in opt_state["v"].items()},
        }
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(pspecs, ospecs, P()),
            out_specs=(pspecs, ospecs, P()),
            check_vma=False,
        )(params, opt_state, batch["tokens"])

    return step
