"""The jitted training step: grad-accum microbatching, remat, AdamW, ZeRO.

``make_train_step`` returns a function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

suitable for ``jax.jit`` with NamedSharding-annotated arguments:

* gradient accumulation over ``parallel.microbatches`` via ``lax.scan`` —
  one microbatch's activations live at a time, which is what lets
  train_4k fit under remat for the 100B+ archs;
* gradients accumulate in f32 into a buffer sharded like the params
  (ZeRO); XLA turns the batch-sharded loss backward into reduce-scatters;
* optional int8 error-feedback gradient compression (``grad_compression``)
  — quantization applied to the accumulated gradient with the residual
  carried in ``opt_state["ef_error"]``; the wire-level int8 collective
  lives in ``repro.optim.compress.compressed_psum_int8`` and is exercised
  by the manual-DP path (``repro.train.manual_dp``);
* AdamW with schedule + global-norm clip.

Metrics are scalar f32: loss, ce, moe aux, grad norm, lr, tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.distributed.parallel import ParallelConfig
from repro.models.api import ModelBundle
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    error_feedback_compress,
    warmup_cosine,
)


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    adamw: AdamWConfig = AdamWConfig()
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0

    def lr_at(self, step):
        return warmup_cosine(
            step,
            peak_lr=self.peak_lr,
            warmup_steps=self.warmup_steps,
            total_steps=self.total_steps,
        )


def make_train_state(
    bundle: ModelBundle, tcfg: TrainStepConfig, key: jax.Array
) -> tuple[Any, dict]:
    """(params, opt_state) on the current default device(s)."""
    params = bundle.init(key)
    opt_state = adamw_init(params, tcfg.adamw)
    if bundle.parallel is not None and bundle.parallel.grad_compression:
        opt_state["ef_error"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params
        )
    return params, opt_state


def _split_microbatches(batch: dict, k: int, parallel: Optional[ParallelConfig]) -> dict:
    """(B, ...) leaves → (k, B//k, ...) for lax.scan.

    The microbatch dim is scan-iterated (replicated); the per-microbatch
    batch dim stays sharded over dp — pinned with a sharding constraint so
    GSPMD doesn't materialize the full batch anywhere.
    """
    mesh = parallel.mesh if parallel is not None else None

    def f(x):
        b = x.shape[0]
        assert b % k == 0, f"batch {b} not divisible by microbatches {k}"
        out = x.reshape(k, b // k, *x.shape[1:])
        if mesh is not None and parallel.dp_axes and (b // k) % parallel.dp_size == 0:
            from jax.sharding import NamedSharding, PartitionSpec as P

            spec = P(None, parallel.dp_axes, *([None] * (out.ndim - 2)))
            out = jax.lax.with_sharding_constraint(out, NamedSharding(mesh, spec))
        return out

    return jax.tree.map(f, batch)


def make_train_step(
    bundle: ModelBundle,
    tcfg: TrainStepConfig,
) -> Callable[[Any, dict, dict], tuple[Any, dict, dict]]:
    parallel = bundle.parallel
    k = parallel.microbatches if parallel is not None else 1
    compress = parallel is not None and parallel.grad_compression
    on_mesh = parallel is not None and parallel.mesh is not None

    def loss_fn(params, mb):
        loss, metrics = bundle.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    # MoE expert weights stay f32: their gradients psum over the EP
    # shard_map axes, and XLA:CPU's AllReducePromotion pass CHECK-fails
    # cloning the reducer of that bf16 all-reduce (crash isolated in the
    # dry-run; stack: AllReducePromotion → CloneAllReduce → CreateBinary).
    moe_arch = bundle.cfg.is_moe

    def _compute_copy(params):
        """bf16 view of the f32 master weights (matrices only), cast ONCE
        per step: FSDP weight all-gathers and gradient reductions both move
        bf16 on the wire — 2× fewer collective bytes (§Perf iter 3).
        Norm vectors stay f32 (tiny, precision-sensitive)."""
        if not on_mesh or moe_arch:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 and p.ndim >= 2
            else p,
            params,
        )

    if on_mesh:
        from repro.distributed import sharding as shd
        from jax.sharding import NamedSharding, PartitionSpec as _P

        _pspecs = shd.param_pspecs(bundle.param_shapes(), parallel)
    else:
        _pspecs = None

    def _rs_hint(g, spec):
        """Constrain per-microbatch grads to the param sharding so GSPMD
        emits reduce-scatter into the ZeRO accumulator, not all-reduce."""
        if not on_mesh:
            return g
        return jax.lax.with_sharding_constraint(
            g, NamedSharding(parallel.mesh, spec)
        )

    def train_step(params, opt_state, batch):
        params_c = _compute_copy(params)
        if k > 1:
            mbs = _split_microbatches(batch, k, parallel)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def body(carry, mb):
                acc, metrics_acc = carry
                (loss, metrics), grads = grad_fn(params_c, mb)
                if _pspecs is not None:
                    grads = jax.tree.map(
                        _rs_hint, grads, _pspecs,
                        is_leaf=lambda x: isinstance(x, _P),
                    )
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / k, acc, grads
                )
                metrics_acc = jax.tree.map(
                    lambda m, x: m + x.astype(jnp.float32) / k, metrics_acc, metrics
                )
                return (acc, metrics_acc), None

            zero_m = {
                "loss": jnp.zeros((), jnp.float32),
                "ce": jnp.zeros((), jnp.float32),
                "moe_aux": jnp.zeros((), jnp.float32),
            }
            (grads, metrics), _ = jax.lax.scan(body, (zero_g, zero_m), mbs)
        else:
            (loss, metrics), grads = grad_fn(params_c, batch)
            if _pspecs is not None:
                grads = jax.tree.map(
                    _rs_hint, grads, _pspecs, is_leaf=lambda x: isinstance(x, _P)
                )
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            metrics = jax.tree.map(lambda x: x.astype(jnp.float32), metrics)

        if compress:
            grads, new_err = error_feedback_compress(grads, opt_state["ef_error"])

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = tcfg.lr_at(opt_state["step"] + 1)  # schedule counts from 1
        new_params, new_opt = adamw_update(
            params,
            grads,
            {kk: opt_state[kk] for kk in ("step", "m", "v")},
            lr,
            tcfg.adamw,
        )
        if compress:
            new_opt["ef_error"] = new_err
        tokens = batch["tokens"]
        metrics = dict(metrics)
        metrics.update(
            grad_norm=gnorm,
            lr=lr,
            tokens=jnp.float32(tokens.shape[0] * (tokens.shape[1] - 1)),
        )
        return new_params, new_opt, metrics

    return train_step
