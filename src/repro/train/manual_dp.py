"""Manual data-parallel train step with int8-compressed gradient all-reduce.

Unlike the GSPMD path (``repro.train.step``) where XLA inserts the gradient
reduce-scatters, this path runs the whole step inside ``shard_map`` over
the dp axes and performs the gradient all-reduce explicitly through
``repro.optim.compress.compressed_psum_int8`` — the int8 payload is
visible as ``s8`` all-to-all/all-gather collectives in the HLO (~4× fewer
wire bytes than an f32 ring all-reduce).  Error feedback is carried per
device in ``opt_state["ef_error"]``.

Params and optimizer state are replicated (classic DP); the GSPMD path
covers FSDP/TP.  This is the configuration the paper's "communication
primitives that are prohibitive in distributed settings" argument maps to:
dense all-to-alls on a fast fabric beat sparse parameter-server schemes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.utils.compat import shard_map

from repro.models.api import ModelBundle
from repro.optim import adamw_update, clip_by_global_norm
from repro.optim.compress import compressed_psum_int8, quantize_int8, dequantize_int8
from repro.train.step import TrainStepConfig


def make_manual_dp_train_step(bundle: ModelBundle, tcfg: TrainStepConfig):
    parallel = bundle.parallel
    assert parallel is not None and parallel.mesh is not None
    dp_axes = parallel.dp_axes
    compress = parallel.grad_compression

    def body(params, opt_state, local_batch):
        def loss_fn(p):
            return bundle.loss(p, local_batch)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)

        ef = opt_state.get("ef_error")

        def reduce_leaf(g, e):
            gf = g.astype(jnp.float32)
            if compress:
                gf = gf + e.astype(jnp.float32)
                q, s = quantize_int8(gf)
                sent = dequantize_int8(q, s)
                new_e = (gf - sent).astype(e.dtype)
                total = compressed_psum_int8(sent, dp_axes)
            else:
                new_e = e
                total = jax.lax.pmean(gf, dp_axes)
            return total.astype(g.dtype), new_e

        if ef is None:
            ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.bfloat16), grads)
        out = jax.tree.map(reduce_leaf, grads, ef)
        grads = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_ef = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = tcfg.lr_at(opt_state["step"] + 1)  # schedule counts from 1
        new_params, new_opt = adamw_update(
            params,
            grads,
            {k: opt_state[k] for k in ("step", "m", "v")},
            lr,
            tcfg.adamw,
        )
        new_opt["ef_error"] = new_ef
        metrics = {k: jax.lax.pmean(v.astype(jnp.float32), dp_axes)
                   for k, v in metrics.items()}
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_params, new_opt, metrics

    def step(params, opt_state, batch):
        return shard_map(
            body,
            mesh=parallel.mesh,
            in_specs=(P(), P(), P(dp_axes)),
            out_specs=(P(), P(), P()),
            check_vma=False,
        )(params, opt_state, batch)

    return step
