"""AdamW with decoupled weight decay and optional reduced-precision moments.

State is a pytree mirroring params (ZeRO-3: it inherits the params'
sharding specs — see ``repro.distributed.sharding.param_pspecs``).  For the
405B-class archs the moments default to bf16, halving optimizer HBM; the
update still runs in f32 (moments are upcast, updated, recast).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer HBM


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(
    params: Any,
    grads: Any,
    state: dict,
    lr: jax.Array,
    cfg: AdamWConfig,
) -> tuple[Any, dict]:
    """One AdamW step. ``lr`` is a traced scalar (schedules stay jittable)."""
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - jnp.power(cfg.b1, t)
    c2 = 1.0 - jnp.power(cfg.b2, t)
    dt = jnp.dtype(cfg.moment_dtype)

    def leaf(p, g, m, v):
        g = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + (1.0 - cfg.b1) * g
        vf = v.astype(jnp.float32) * cfg.b2 + (1.0 - cfg.b2) * jnp.square(g)
        update = (mf / c1) / (jnp.sqrt(vf / c2) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), mf.astype(dt), vf.astype(dt)

    out = jax.tree.map(leaf, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"step": step, "m": new_m, "v": new_v}
