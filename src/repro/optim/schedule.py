"""Jittable learning-rate schedules (step → scalar lr)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_linear(step, *, peak_lr: float, warmup_steps: int, total_steps: int):
    """Linear warmup then linear decay to zero."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = s / max(1, warmup_steps)
    decay = (total_steps - s) / max(1, total_steps - warmup_steps)
    return peak_lr * jnp.clip(jnp.minimum(warm, decay), 0.0, 1.0)


def warmup_cosine(
    step, *, peak_lr: float, warmup_steps: int, total_steps: int, floor: float = 0.1
):
    """Linear warmup then cosine decay to ``floor * peak_lr``."""
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.clip(s / max(1, warmup_steps), 0.0, 1.0)
    frac = jnp.clip(
        (s - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
    )
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return peak_lr * jnp.where(s < warmup_steps, warm, cos)
