"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import tree_global_norm


def clip_by_global_norm(grads, max_norm: float):
    """Scale ``grads`` so their global L2 norm is at most ``max_norm``.

    Returns ``(clipped_grads, pre_clip_norm)``.
    """
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm
