"""Int8 gradient compression with error feedback (DP all-reduce path).

The compressed all-reduce follows the standard two-hop scheme (1-bit
Adam / DeepSpeed lineage, adapted to int8):

1. quantize the local gradient shard to int8 with a per-chunk f32 scale,
2. **reduce-scatter in int8**: all-to-all the chunks so device ``d`` holds
   chunk ``d`` from every peer, dequantize + sum locally in f32,
3. requantize the reduced chunk and **all-gather in int8**.

Both wire hops move int8 payloads (scales are 1 f32 per chunk), so the
collective bytes drop ~4× vs an f32 ring all-reduce — visible in the HLO
the dry-run parses for the roofline's collective term.

Error feedback: the quantization residual is added back into the next
step's gradient (``error_feedback_compress``), which keeps SGD/Adam
convergence unbiased in expectation — state rides in the optimizer pytree.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.utils.compat import axis_size as _axis_size


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def error_feedback_compress(
    grads: Any, error: Any
) -> tuple[Any, Any]:
    """Quantize ``grads + error`` per leaf; return (dequantized, new_error).

    The returned gradient is what the optimizer consumes; ``new_error`` is
    the residual to carry into the next step.  Pure local transform — used
    standalone in tests and composed with :func:`compressed_psum_int8` in
    the trainer's manual-collective path.
    """

    def leaf(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), (gf - deq).astype(e.dtype)

    out = jax.tree.map(leaf, grads, error)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def compressed_psum_int8(
    x: jax.Array, axis_names: Sequence[str]
) -> jax.Array:
    """Mean-reduce ``x`` across ``axis_names`` with int8 wire traffic.

    Must run inside ``shard_map``.  ``x`` is the per-device value (e.g. a
    flattened gradient shard); every device returns the full mean.

    reduce-scatter hop: reshape to (D, chunk) → per-chunk int8 quantize →
    ``all_to_all`` (int8) + ``all_gather`` of scales (f32, D floats) →
    dequantize + sum.  all-gather hop: requantize the summed chunk →
    ``all_gather`` (int8) + scale exchange → dequantize.
    """
    d = 1
    for a in axis_names:
        d *= _axis_size(a)
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % d
    chunks = jnp.pad(flat, (0, pad)).reshape(d, -1)

    # per-destination-chunk int8 quantization
    amax = jnp.maximum(jnp.max(jnp.abs(chunks), axis=1), 1e-12)
    scales = amax / 127.0
    q = jnp.clip(jnp.round(chunks / scales[:, None]), -127, 127).astype(jnp.int8)

    # hop 1 (reduce-scatter): all-to-all int8 payload + f32 scale all-gather.
    sizes = [_axis_size(a) for a in axis_names]
    qq = q.reshape(*sizes, -1)
    for i, a in enumerate(axis_names):
        qq = jax.lax.all_to_all(qq, a, split_axis=i, concat_axis=i, tiled=True)
    q_recv = qq.reshape(d, -1)  # row = source device, my chunk id
    s_all = scales
    for a in axis_names:
        s_all = jax.lax.all_gather(s_all, a, tiled=True)
    s_all = s_all.reshape(d, d)  # [source, chunk]
    rank = jnp.int32(0)
    for a in axis_names:
        rank = rank * _axis_size(a) + jax.lax.axis_index(a)
    my_scales = jnp.take(s_all, rank, axis=1)  # (D,) scale of my chunk per src
    reduced = jnp.sum(q_recv.astype(jnp.float32) * my_scales[:, None], axis=0) / d

    # hop 2: requantize the reduced chunk + all-gather int8.
    amax2 = jnp.maximum(jnp.max(jnp.abs(reduced)), 1e-12)
    s2 = amax2 / 127.0
    q2 = jnp.clip(jnp.round(reduced / s2), -127, 127).astype(jnp.int8)
    qg, sg = q2, s2.reshape(1)
    for a in axis_names:
        qg = jax.lax.all_gather(qg, a, tiled=True)
        sg = jax.lax.all_gather(sg, a, tiled=True)
    out = qg.reshape(d, -1).astype(jnp.float32) * sg.reshape(d, 1)
    return out.reshape(-1)[:n].reshape(shape).astype(x.dtype)
