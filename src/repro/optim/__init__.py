"""Optimizer substrate: AdamW, LR schedules, clipping, grad compression."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine, warmup_linear
from repro.optim.clip import clip_by_global_norm
from repro.optim.compress import (
    quantize_int8,
    dequantize_int8,
    error_feedback_compress,
    compressed_psum_int8,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "warmup_linear",
    "clip_by_global_norm",
    "quantize_int8",
    "dequantize_int8",
    "error_feedback_compress",
    "compressed_psum_int8",
]
