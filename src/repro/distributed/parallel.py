"""Parallelism configuration threaded through model/train/serve builders.

Axis roles on the production mesh (DESIGN.md §5):

* ``dp_axes``  — data parallel + FSDP parameter sharding (``("pod","data")``
  multi-pod, ``("data",)`` single-pod).
* ``tp_axis``  — tensor parallel (heads / d_ff / vocab).
* ``ep_axes``  — expert-parallel dispatch axes for MoE (defaults to
  ``dp_axes``); the dispatch itself is the paper's binned capacity
  all-to-all from ``repro.core.exchange``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    mesh: Optional[jax.sharding.Mesh]
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"
    ep_axes: Optional[Tuple[str, ...]] = None  # None → dp_axes
    moe_impl: str = "dense"  # dense | ep
    # serve-time options
    seq_shard_decode: bool = False  # shard KV cache over tp_axis on seq dim
    # train-time options
    microbatches: int = 1  # gradient accumulation steps
    remat: bool = True
    grad_compression: bool = False  # int8 + error feedback on dp all-reduce
    seq_parallel: bool = False  # residual stream sequence-sharded over tp
    act_barrier: bool = False  # optimization_barrier after block outputs:
    # forces GSPMD to resolve partial sums in bf16 instead of sinking the
    # all-reduce past the next rmsnorm's f32 upcast (2× wire bytes).

    @property
    def ep_axes_(self) -> Tuple[str, ...]:
        return self.ep_axes if self.ep_axes is not None else self.dp_axes

    @property
    def dp_spec(self) -> P:
        return P(self.dp_axes)

    def batch_spec(self, extra_dims: int = 1) -> P:
        """(B, ...) activations: batch over dp axes, rest replicated."""
        return P(self.dp_axes, *([None] * extra_dims))

    def num_devices(self, axes: Tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def dp_size(self) -> int:
        return self.num_devices(self.dp_axes)

    def shard_act(self, x, *, batch_dim: int = 0, seq_dim: Optional[int] = 1):
        """Pin activation sharding: batch over dp (+ seq over tp under SP).

        GSPMD left alone can resolve sharding conflicts by replicating the
        batch (measured: 16× activation all-reduces on the 16×16 mesh) —
        every residual-stream tensor goes through this constraint.  No-op
        off-mesh or when dims don't divide.
        """
        if self.mesh is None or getattr(x, "ndim", 0) < 2:
            return x
        from jax import lax
        from jax.sharding import NamedSharding

        spec: list = [None] * x.ndim
        if self.dp_axes and x.shape[batch_dim] % max(self.dp_size, 1) == 0 and self.dp_size > 1:
            spec[batch_dim] = self.dp_axes
        if (
            self.seq_parallel
            and seq_dim is not None
            and self.tp_axis
            and x.shape[seq_dim] % max(self.tp_size, 1) == 0
            and self.tp_size > 1
        ):
            spec[seq_dim] = self.tp_axis
        if all(s is None for s in spec):
            return x
        x = lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))
        if self.act_barrier:
            x = lax.optimization_barrier(x)
        return x

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]


def single_device_parallel() -> ParallelConfig:
    """Degenerate config for CPU smoke tests (no mesh, dense MoE)."""
    return ParallelConfig(mesh=None, dp_axes=(), tp_axis=None, moe_impl="dense")
