"""Name-based sharding rules: param/optimizer/cache pytrees → PartitionSpecs.

The framework uses GSPMD (``jax.jit`` + ``NamedSharding``) for the LM stack
and reserves manual ``shard_map`` for the paper's exchange (hash table, MoE
dispatch).  Rules here are *logical*: every leaf is classified by the last
component of its tree path into Megatron-style roles, then physical axes are
assigned only when the dimension divides the axis size (otherwise that dim
falls back to replicated — keeps whisper-base's odd vocab safe).

Roles (trailing-dim logic; scanned stacks carry a leading ``num_periods``
dim which is never sharded):

* **column-parallel** (out-features on ``tp``): wq/wk/wv, w_gate/w_up,
  w_in, w_rec, w_if, w_a, w_x, lm_head.
* **row-parallel** (in-features on ``tp``): wo, w_down, w_out.
* **embed** (V, D): vocab on ``tp``, d_model on ``dp`` (FSDP).
* everything else: FSDP only.

FSDP assigns the ``dp`` axes to the largest still-unsharded dim.  Optimizer
state inherits param specs (ZeRO-3).  KV caches shard batch on ``dp`` and
heads on ``tp`` when the head count divides; otherwise the *sequence* dim
goes on ``tp`` (sequence-sharded cache — required for kv_heads=1 archs).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.parallel import ParallelConfig

# Last-path-component names → role.
_COL_PARALLEL = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_rec", "w_if",
    "w_a", "w_x", "lm_head",
}
_ROW_PARALLEL = {"wo", "w_down", "w_out"}
_EMBED = {"embed"}
_REPLICATED = {
    "norm", "norm1", "norm2", "norm_x", "out_norm", "final_norm", "enc_norm",
    "dec_norm", "q_norm", "k_norm", "b", "b_in", "b_out", "b_a", "b_x",
    "conv_b", "lambda", "r", "conv_w", "pos_emb",
}


def _leaf_name(path: Tuple) -> str:
    """Last string key in a jax tree path."""
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def _axis_size(mesh_shape: dict, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_shape.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_shape.get(a, 1)
    return n


def param_spec(
    path: Tuple,
    shape: Tuple[int, ...],
    *,
    dp_axes: Tuple[str, ...],
    tp_axis: Optional[str],
    mesh_shape: dict,
    scanned: bool = False,
) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _leaf_name(path)
    ndim = len(shape)
    spec: list = [None] * ndim
    # dims eligible for sharding (skip the leading scan dim of layer stacks)
    first = 1 if (scanned and ndim >= 2) else 0
    tp_size = _axis_size(mesh_shape, tp_axis)
    dp_size = _axis_size(mesh_shape, dp_axes)

    def try_assign(dim: int, axes) -> bool:
        size = _axis_size(mesh_shape, axes)
        if spec[dim] is None and size > 1 and shape[dim] % size == 0:
            spec[dim] = axes
            return True
        return False

    if ndim - first >= 2 and name not in _REPLICATED:
        if name in _EMBED:
            # vocab over tp ONLY.  FSDP'ing d_model over `data` was measured
            # to poison GSPMD propagation: the gather output carries
            # feature-over-data sharding into the residual stream, GSPMD
            # resolves the conflict by REPLICATING the batch over `data`
            # and all-reducing f32 activations every layer (§Perf iter 1).
            if tp_axis:
                try_assign(first, tp_axis)
        elif name in _COL_PARALLEL and tp_axis and tp_size > 1:
            try_assign(ndim - 1, tp_axis)
        elif name in _ROW_PARALLEL and tp_axis and tp_size > 1:
            try_assign(ndim - 2, tp_axis)
        # FSDP: dp axes on the largest remaining unsharded dim.
        if dp_size > 1 and name not in _EMBED:
            order = sorted(
                range(first, ndim), key=lambda d: shape[d], reverse=True
            )
            for d in order:
                if try_assign(d, dp_axes):
                    break
    return P(*spec)


def _is_scanned_layer(path: Tuple) -> bool:
    return any(
        hasattr(e, "key") and str(e.key) == "layers" for e in path
    ) or any(
        hasattr(e, "key") and str(e.key) in ("enc_layers", "dec_layers")
        for e in path
    )


def param_pspecs(params_shapes: Any, parallel: ParallelConfig):
    """Pytree of PartitionSpec matching a params (shape) pytree."""
    mesh_shape = dict(parallel.mesh.shape) if parallel.mesh is not None else {}

    def f(path, leaf):
        return param_spec(
            path,
            tuple(leaf.shape),
            dp_axes=parallel.dp_axes,
            tp_axis=parallel.tp_axis,
            mesh_shape=mesh_shape,
            scanned=_is_scanned_layer(path),
        )

    return jax.tree_util.tree_map_with_path(f, params_shapes)


def cache_pspecs(cache_shapes: Any, parallel: ParallelConfig):
    """PartitionSpecs for a decode-cache pytree.

    Cache leaves are scanned stacks ``(num_periods, B, ...)``:

    * KV caches ``(P, B, KV, S, hd)``: B on dp; KV on tp when divisible,
      else S on tp (sequence-sharded decode — kv_heads < tp_size).
    * recurrent states ``(P, B, D...)``: B on dp; widest trailing dim on tp.
    """
    mesh_shape = dict(parallel.mesh.shape) if parallel.mesh is not None else {}
    dp_axes, tp_axis = parallel.dp_axes, parallel.tp_axis
    dp_size = _axis_size(mesh_shape, dp_axes)
    tp_size = _axis_size(mesh_shape, tp_axis)

    def f(path, leaf):
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if ndim < 2:
            return P()
        spec: list = [None] * ndim
        if dp_size > 1 and shape[1] % dp_size == 0:
            spec[1] = dp_axes  # batch
        if tp_axis and tp_size > 1 and ndim >= 3:
            # prefer heads (dim 2 of 5-dim KV), else sequence, else widest.
            cands = []
            if ndim == 5:
                cands = [2, 3]  # (P, B, KV, S, hd): heads, then seq
            else:
                cands = sorted(
                    range(2, ndim), key=lambda d: shape[d], reverse=True
                )
            for d in cands:
                if spec[d] is None and shape[d] % tp_size == 0 and shape[d] >= tp_size:
                    spec[d] = tp_axis
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(f, cache_shapes)


def batch_pspec(shape_len: int, parallel: ParallelConfig) -> P:
    """(B, ...) input batch: batch dim over dp axes."""
    if parallel.mesh is None or not parallel.dp_axes:
        return P()
    return P(parallel.dp_axes, *([None] * (shape_len - 1)))


def to_named(mesh: Mesh, specs: Any):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def spec_summary(params_shapes: Any, specs: Any, max_rows: int = 0) -> str:
    """Human-readable table of leaf → shape → spec (debugging/DESIGN docs)."""
    rows = []
    flat_s, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    for (path, leaf), spec in zip(flat_s, flat_p):
        name = jax.tree_util.keystr(path)
        rows.append(f"{name:70s} {str(tuple(leaf.shape)):28s} {spec}")
    if max_rows:
        rows = rows[:max_rows]
    return "\n".join(rows)


def shard_bytes_per_device(shapes: Any, specs: Any, mesh_shape: dict) -> int:
    """Static per-device byte estimate of a sharded pytree."""
    total = 0
    flat_s, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_p = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    for (_, leaf), spec in zip(flat_s, flat_p):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            denom *= _axis_size(mesh_shape, entry)
        total += -(-n // denom) * np.dtype(leaf.dtype).itemsize
    return total
