"""Distribution substrate: parallel config, sharding rules, fault tolerance."""
from repro.distributed.parallel import ParallelConfig, single_device_parallel

__all__ = ["ParallelConfig", "single_device_parallel"]
