"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set the fake-device flag before any other import (jax locks the
device count on first init):
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# isort: split
import argparse
import json
import re
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, SHAPE_SUITE, get_config, shape_cell
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh, production_parallel
from repro.models.api import build_model
from repro.optim import adamw_init
from repro.train.step import TrainStepConfig, make_train_step
from repro.utils import human_bytes, tree_param_count

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e, per assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / ICI link

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def _with_sharding(sds_tree: Any, spec_tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p)),
        sds_tree,
        spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _batch_shardings(specs: dict, parallel, batch: int) -> dict:
    """NamedSharding per input leaf: batch over dp when divisible."""
    mesh = parallel.mesh
    dp = parallel.dp_axes
    dp_size = parallel.dp_size
    out = {}
    for k, s in specs.items():
        if batch % max(dp_size, 1) == 0 and dp:
            spec = P(dp, *([None] * (len(s.shape) - 1)))
        else:
            spec = P(*([None] * len(s.shape)))
        out[k] = jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, spec))
    return out


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[dims] token in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [groups, group_size]
    return default


def parse_collectives(hlo: str, num_devices: int) -> dict:
    """Per-device wire bytes by collective kind, from post-SPMD HLO.

    Shapes in the partitioned module are already per-device.  Wire-byte
    model per op (g = replica-group size):
      all-gather           out × (g-1)/g
      reduce-scatter       out × (g-1)          (input = out × g)
      all-reduce           out × 2(g-1)/g
      all-to-all           out × (g-1)/g
      collective-permute   out
    """
    per_kind: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        ls = line.lstrip()
        if "=" not in ls:
            continue
        head, _, rest = ls.partition("=")
        # match "<shape> kind(" right after '='
        kind = None
        for k in _COLLECTIVES:
            if f" {k}(" in rest or f" {k}-start(" in rest:
                kind = k
                break
        if kind is None:
            continue
        out_bytes = _shape_bytes(rest.split("(", 1)[0])
        g = _group_size(line, num_devices)
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = out_bytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif kind == "all-reduce":
            wire = out_bytes * 2 * (g - 1) / g
        elif kind == "all-to-all":
            wire = out_bytes * (g - 1) / g
        else:  # collective-permute
            wire = out_bytes
        per_kind[kind] += wire
        counts[kind] += 1
    per_kind_total = sum(per_kind.values())
    return {"bytes_by_kind": per_kind, "counts": counts, "wire_bytes": per_kind_total}


# ---------------------------------------------------------------------------
# model-flops convention
# ---------------------------------------------------------------------------
def model_flops(cfg, params_shapes, cell) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params."""
    n_total = tree_param_count(params_shapes)
    n_active = n_total
    if cfg.is_moe:
        # expert weights count k/E; find them by shape: leading dim == E.
        flat, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
        n_exp = sum(
            int(np.prod(l.shape))
            for p, l in flat
            if len(l.shape) >= 3 and l.shape[-3] == cfg.num_experts
            and "moe" in jax.tree_util.keystr(p)
        )
        n_active = n_total - n_exp + n_exp * cfg.experts_per_token / cfg.num_experts
    if cell.kind == "train":
        d = cell.global_batch * cell.seq_len
        return 6.0 * n_active * d
    if cell.kind == "prefill":
        d = cell.global_batch * cell.seq_len
        return 2.0 * n_active * d
    d = cell.global_batch  # decode: one token per sequence
    return 2.0 * n_active * d


# ---------------------------------------------------------------------------
# the dry run
# ---------------------------------------------------------------------------
def _cost_dict(compiled) -> dict:
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return dict(c or {})


def _memory_dict(compiled) -> dict:
    m = compiled.memory_analysis()
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(m, attr, None)
        if v is not None:
            out[attr] = int(v)
    return out


def dryrun_cell(
    arch: str,
    cell_name: str,
    multi_pod: bool,
    *,
    microbatches: int = 8,
    moe_impl: str = "ep",
    save_hlo: Optional[str] = None,
    seq_shard_decode: bool = False,
    seq_parallel: bool = True,
    act_barrier: bool = False,
) -> dict:
    cfg = get_config(arch)
    cell = shape_cell(cell_name)
    ok, why = cfg.supports_cell(cell)
    if not ok:
        return {"arch": arch, "cell": cell_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    # decode lowers a single token step — no microbatching there.
    k = microbatches if cell.kind == "train" else 1
    if cell.kind == "train" and cell.global_batch % (
        k * max(1, int(np.prod([mesh.shape[a] for a in ("pod", "data") if a in mesh.axis_names])))
    ):
        k = 1
    parallel = production_parallel(
        mesh, moe_impl=moe_impl, microbatches=k,
        seq_parallel=seq_parallel, act_barrier=act_barrier,
    )
    if seq_shard_decode:
        import dataclasses as _dc
        parallel = _dc.replace(parallel, seq_shard_decode=True)
    bundle = build_model(cfg, parallel)

    pshapes = bundle.param_shapes()
    pspecs = shd.param_pspecs(pshapes, parallel)
    params_in = _with_sharding(pshapes, pspecs, mesh)

    t0 = time.time()
    if cell.kind == "train":
        tcfg = TrainStepConfig()
        opt_shapes = jax.eval_shape(lambda p: adamw_init(p, tcfg.adamw), pshapes)
        opt_specs = {
            "step": P(),
            "m": pspecs,
            "v": pspecs,
        }
        opt_in = _with_sharding(opt_shapes, opt_specs, mesh)
        batch_in = _batch_shardings(
            bundle.train_input_specs(cell), parallel, cell.global_batch
        )
        step_fn = make_train_step(bundle, tcfg)
        jitted = jax.jit(
            step_fn,
            out_shardings=(
                jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs,
                             is_leaf=lambda x: isinstance(x, P)),
                jax.tree.map(lambda p: NamedSharding(mesh, p), opt_specs,
                             is_leaf=lambda x: isinstance(x, P)),
                None,
            ),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_in, opt_in, batch_in)
    elif cell.kind == "prefill":
        batch_in = _batch_shardings(
            bundle.prefill_input_specs(cell), parallel, cell.global_batch
        )

        from repro.serve.engine import serving_compute_copy

        def prefill_fn(params, batch):
            return bundle.prefill(
                serving_compute_copy(params), batch, cache_len=cell.seq_len
            )

        cache_shapes = jax.eval_shape(
            lambda: bundle.init_cache(cell.global_batch, cell.seq_len)
        )
        cspecs = shd.cache_pspecs(cache_shapes, parallel)
        jitted = jax.jit(
            prefill_fn,
            out_shardings=(
                None,
                jax.tree.map(lambda p: NamedSharding(mesh, p), cspecs,
                             is_leaf=lambda x: isinstance(x, P)),
            ),
        )
        lowered = jitted.lower(params_in, batch_in)
    else:  # decode
        b = cell.global_batch
        specs = bundle.decode_input_specs(cell)
        cache_shapes = specs["caches"]
        cspecs = shd.cache_pspecs(cache_shapes, parallel)
        caches_in = _with_sharding(cache_shapes, cspecs, mesh)
        dp_ok = b % max(parallel.dp_size, 1) == 0
        tok_spec = P(parallel.dp_axes, None) if dp_ok else P(None, None)
        pos_spec = P(parallel.dp_axes) if dp_ok else P(None)
        token_in = jax.ShapeDtypeStruct(
            (b, 1), jnp.int32, sharding=NamedSharding(mesh, tok_spec)
        )
        pos_in = jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, pos_spec)
        )

        from repro.serve.engine import serving_compute_copy

        def serve_step(params, caches, token, pos):
            return bundle.decode_step(
                serving_compute_copy(params), caches, token, pos
            )

        jitted = jax.jit(
            serve_step,
            out_shardings=(
                None,
                jax.tree.map(lambda p: NamedSharding(mesh, p), cspecs,
                             is_leaf=lambda x: isinstance(x, P)),
            ),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_in, caches_in, token_in, pos_in)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = _cost_dict(compiled)
    memd = _memory_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, chips)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)

    # Trip-count-aware re-analysis: XLA's cost_analysis counts while bodies
    # once (a scanned train step under-reports ~layers×microbatches).
    from repro.analysis import hlo_cost

    # flash-kernel accounting only for attention-family blocks (mLSTM's
    # quadratic gates are fixed algorithmically by chunking, not modeled).
    attn_family = any(
        bt in ("attn", "swa", "local") for bt in cfg.block_pattern
    ) or cfg.is_encoder_decoder
    summ = hlo_cost.analyze(
        hlo, chips,
        fused_attention_shapes=attn_family,
        # recurrence weights pinned in VMEM across the time loop — the
        # contract of kernels/slstm.py (validated vs the scan oracle).
        pin_loop_invariants=True,
    )
    flops = summ.flops
    bytes_accessed = summ.hbm_bytes
    mf = model_flops(cfg, pshapes, cell)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": summ.wire_bytes / LINK_BW,
    }
    bottleneck = max(terms, key=lambda kk: terms[kk])
    rec = {
        "arch": arch,
        "cell": cell_name,
        "multi_pod": multi_pod,
        "chips": chips,
        "status": "ok",
        "microbatches": k,
        "moe_impl": moe_impl,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "wire_bytes_per_device": summ.wire_bytes,
        "wire_by_kind": summ.wire_by_kind,
        "collective_op_counts": summ.collective_counts,
        "unknown_trip_loops": summ.unknown_trip_loops,
        "xla_cost_analysis": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "collectives_lineparse": coll,
        "memory_analysis": memd,
        "terms_s": terms,
        "bottleneck": bottleneck,
        "model_flops_global": mf,
        "useful_flops_ratio": mf / max(flops * chips, 1.0),
        "params_total": tree_param_count(pshapes),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS) + ["all"])
    ap.add_argument("--cell", default="all",
                    choices=[c.name for c in SHAPE_SUITE] + ["all"])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--moe-impl", default="ep", choices=["ep", "dense"])
    ap.add_argument("--seq-shard-decode", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--act-barrier", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", default=None, help="dir to dump optimized HLO text")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    cells = [c.name for c in SHAPE_SUITE] if args.cell == "all" else [args.cell]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    if args.save_hlo:
        os.makedirs(args.save_hlo, exist_ok=True)
    failures = 0
    for arch in archs:
        for cell in cells:
            for mp in meshes:
                tag = f"{arch}.{cell}.{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached")
                    continue
                hlo_path = (
                    os.path.join(args.save_hlo, tag + ".hlo.txt")
                    if args.save_hlo
                    else None
                )
                try:
                    rec = dryrun_cell(
                        arch, cell, mp,
                        microbatches=args.microbatches,
                        moe_impl=args.moe_impl,
                        save_hlo=hlo_path,
                        seq_shard_decode=args.seq_shard_decode,
                        seq_parallel=not args.no_seq_parallel,
                        act_barrier=args.act_barrier,
                    )
                except Exception as e:  # record and continue the sweep
                    failures += 1
                    rec = {
                        "arch": arch, "cell": cell, "multi_pod": mp,
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    t = rec["terms_s"]
                    print(
                        f"[dryrun] {tag}: OK lower={rec['lower_s']}s "
                        f"compile={rec['compile_s']}s "
                        f"compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s "
                        f"collective={t['collective_s']:.3e}s "
                        f"bottleneck={rec['bottleneck']} "
                        f"temp={human_bytes(rec['memory_analysis'].get('temp_size_in_bytes', 0))}"
                    )
                elif rec["status"] == "skipped":
                    print(f"[dryrun] {tag}: SKIPPED ({rec['reason'][:90]})")
                else:
                    print(f"[dryrun] {tag}: ERROR {rec['error'][:200]}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
