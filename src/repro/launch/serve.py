"""Batched serving driver: continuous batching over a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --requests 12 \
        --slots 4 --prompt-len 32 --max-new 16
"""
import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs.base import get_smoke_config
    from repro.distributed.parallel import single_device_parallel
    from repro.models.api import build_model
    from repro.serve import ContinuousBatcher, Request, make_prefill_step, make_serve_step

    cfg = get_smoke_config(args.arch)
    bundle = build_model(cfg, single_device_parallel())
    params = bundle.init(jax.random.key(args.seed))
    caches = bundle.init_cache(args.slots, args.cache_len)
    prefill = make_prefill_step(bundle, cache_len=args.cache_len)
    decode = make_serve_step(bundle, donate=False)

    rng = np.random.default_rng(args.seed)
    batcher = ContinuousBatcher(
        params, caches, prefill, decode, num_slots=args.slots
    )
    for uid in range(args.requests):
        batcher.submit(
            Request(
                uid=uid,
                prompt=rng.integers(
                    1, cfg.vocab_size, size=args.prompt_len, dtype=np.int32
                ),
                max_new_tokens=args.max_new,
            )
        )
    t0 = time.perf_counter()
    done = batcher.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(
        f"[serve] arch={cfg.name} requests={len(done)} tokens={toks} "
        f"time={dt:.2f}s ({toks/dt:.1f} tok/s, slots={args.slots})"
    )
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
