"""End-to-end training driver.

Examples::

    # ~100M-param qwen3-family model, 200 steps on CPU
    PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \
        --steps 200 --batch 8 --seq 256 --d-model 256 --layers 8

    # data-parallel over 8 fake devices with int8 grad compression + dedup
    PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \
        --fake-devices 8 --grad-compression --dedup local --steps 50

Device count is locked at first jax import, so ``--fake-devices`` is
handled *before* importing jax.
"""
import argparse
import os


def _parse():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--d-model", type=int, default=0, help="override width (smoke)")
    ap.add_argument("--layers", type=int, default=0, help="override depth (smoke)")
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--dedup", default=None, choices=[None, "local"])
    ap.add_argument("--fake-devices", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--crash-at-step", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args()


def main() -> None:
    args = _parse()
    if args.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.fake_devices}"
        )
    import dataclasses

    import jax

    from repro.configs.base import get_config, get_smoke_config
    from repro.data import ShardedLoader, SyntheticCorpus
    from repro.distributed.parallel import ParallelConfig, single_device_parallel
    from repro.launch.mesh import make_smoke_mesh
    from repro.models.api import build_model
    from repro.train import Trainer, TrainerConfig, TrainStepConfig
    from repro.utils import tree_param_count

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.layers:
        overrides["num_layers"] = args.layers
    if args.vocab:
        overrides["vocab_size"] = args.vocab
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    if args.fake_devices and len(jax.devices()) > 1:
        mesh = make_smoke_mesh()
        dp = ("data",)
        tp = "model" if "model" in mesh.axis_names else None
        parallel = ParallelConfig(
            mesh=mesh,
            dp_axes=dp,
            tp_axis=tp,
            moe_impl="ep" if cfg.is_moe else "dense",
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
        )
    else:
        parallel = dataclasses.replace(
            single_device_parallel(),
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
        )

    bundle = build_model(cfg, parallel)
    n = tree_param_count(bundle.param_shapes())
    print(f"[train] arch={cfg.name} params={n/1e6:.1f}M devices={len(jax.devices())}")

    corpus = SyntheticCorpus(
        vocab_size=cfg.vocab_size, seq_len=args.seq, seed=args.seed, dup_rate=0.05
    )
    loader = ShardedLoader(
        corpus,
        batch_size=args.batch,
        mesh=parallel.mesh,
        dp_axes=parallel.dp_axes or ("data",),
        dedup=args.dedup,
    )
    tcfg = TrainStepConfig(
        peak_lr=args.lr, warmup_steps=max(10, args.steps // 10), total_steps=args.steps
    )
    trainer = Trainer(
        bundle,
        loader,
        tcfg,
        TrainerConfig(
            total_steps=args.steps,
            checkpoint_every=args.checkpoint_every if args.checkpoint_dir else 0,
            checkpoint_dir=args.checkpoint_dir,
            log_every=max(1, args.steps // 20),
            seed=args.seed,
            crash_at_step=args.crash_at_step,
        ),
    )
    out = trainer.run()
    hist = out["history"]
    if hist:
        print(
            f"[train] done: step={out['final_step']} "
            f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
            f"stragglers={out['stragglers']}"
        )


if __name__ == "__main__":
    main()
