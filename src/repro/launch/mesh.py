"""Production mesh construction (single-pod 16×16, multi-pod 2×16×16).

``make_production_mesh`` is a *function* so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else (smoke tests, benches) sees the default single
CPU device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.distributed.parallel import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """TPU v5e production mesh: one pod = 16×16 = 256 chips.

    single-pod: ``("data", "model") = (16, 16)``
    multi-pod:  ``("pod", "data", "model") = (2, 16, 16)`` — the ``pod``
    axis composes with ``data`` for DP/FSDP by default (DCN-friendly:
    only gradient/weight collectives cross pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: Optional[int] = None) -> jax.sharding.Mesh:
    """Small mesh over however many (fake) devices the process has."""
    n = devices or len(jax.devices())
    if n >= 4:
        return jax.make_mesh((n // 2, 2), ("data", "model"))
    return jax.make_mesh((n,), ("data",))


def production_parallel(
    mesh: jax.sharding.Mesh,
    *,
    moe_impl: str = "ep",
    microbatches: int = 8,
    grad_compression: bool = False,
    seq_parallel: bool = True,
    act_barrier: bool = False,
) -> ParallelConfig:
    """ParallelConfig wired for the production mesh axes.

    ``seq_parallel`` defaults on: residual-stream tensors are sequence-
    sharded over ``model``, turning the per-layer Megatron activation
    all-reduces into reduce-scatter/all-gather pairs (2× fewer wire bytes
    — §Perf iter 3) and cutting activation HBM residency tp-fold.
    """
    names = mesh.axis_names
    dp_axes: Tuple[str, ...] = tuple(a for a in names if a in ("pod", "data"))
    tp_axis = "model" if "model" in names else None
    return ParallelConfig(
        mesh=mesh,
        dp_axes=dp_axes,
        tp_axis=tp_axis,
        moe_impl=moe_impl,
        microbatches=microbatches,
        remat=True,
        grad_compression=grad_compression,
        seq_parallel=seq_parallel,
        act_barrier=act_barrier,
    )
