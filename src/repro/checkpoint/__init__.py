"""Checkpointing substrate: sharded npz + manifest, async, elastic restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
