"""Checkpoint manager: atomic, async, shard-aware, elastically restorable.

Layout (one directory per step)::

    <dir>/step_00000042/
        manifest.json     # tree paths, shapes, dtypes, step, user extra
        arrays.npz        # one entry per leaf, keyed by manifest index

Guarantees:

* **Atomicity** — everything is written into ``step_X.tmp/`` and the dir is
  ``os.rename``d into place last; a crash mid-write never corrupts the
  latest checkpoint (rename is atomic on POSIX).
* **Async** — ``save()`` device_gets the tree (cheap: shards are already
  in host-reachable memory on CPU; on TPU this is the D2H copy) and hands
  serialization to a writer thread, so the train loop isn't blocked on
  disk. ``wait()`` drains the queue; the manager never drops a enqueued
  save.
* **Elastic restore** — arrays are stored *unsharded* (global view); on
  restore they are ``device_put`` against the **target** shardings, which
  may belong to a different mesh shape / device count than the writer's
  (re-sharding happens on load).  The trainer resumes the data pipeline
  from the stored step counter — the loader is a pure function of step, so
  resume is exact.
* **Retention** — keeps the newest ``keep`` checkpoints, deleting older
  ones after a successful save.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._errors: list = []
        self._thread: Optional[threading.Thread] = None
        if async_write:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    # -- paths ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[len("step_"):]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ---------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None) -> None:
        """Snapshot ``tree`` at ``step``.  Returns once data is off-device."""
        paths, leaves, _ = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        manifest = {
            "step": int(step),
            "paths": paths,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
        }
        if self.async_write:
            self._q.put((step, manifest, host))
        else:
            self._write(step, manifest, host)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except Exception as e:  # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _write(self, step: int, manifest: dict, host: list) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **{str(i): a for i, a in enumerate(host)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def wait(self) -> None:
        """Drain pending async writes; re-raise the first writer error."""
        if self.async_write:
            self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        if self._thread is not None:
            self._q.join()
            self._q.put(None)
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------------
    def restore(
        self,
        like: Any,
        step: Optional[int] = None,
        shardings: Optional[Any] = None,
    ) -> tuple[int, Any, dict]:
        """Load a checkpoint into the structure of ``like``.

        ``shardings``: optional pytree of ``NamedSharding`` matching ``like``
        — pass the *current* mesh's shardings to restore elastically onto a
        different device count than the writer used.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        paths, leaves, treedef = _flatten(like)
        if manifest["paths"] != paths:
            raise ValueError(
                "checkpoint tree mismatch:\n"
                f"  stored:  {manifest['paths'][:5]}...\n  wanted: {paths[:5]}..."
            )
        arrays = [data[str(i)] for i in range(len(paths))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec")
            )
            out = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        else:
            out = [jax.numpy.asarray(a) for a in arrays]
        return step, jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
