"""Blocked compare-tile histogram Pallas kernel (Alg. 2 Phase 1 counters).

The CUDA build scatter-increments ``BinCounter`` with ``AtomicAdd``.  TPUs
have no global atomics and scatters serialize, so the TPU-native histogram
is a *dense compare*: for a VMEM tile of bin ids and a 128-aligned tile of
candidate bins, accumulate ``sum(bin_id == bin)`` on the VPU.

Grid is ``(num_bin_tiles, num_key_blocks)`` — key blocks innermost so each
output tile accumulates across all key blocks while resident in VMEM
(revision-friendly: the output block's index_map ignores the key-block
index, making this the canonical Pallas accumulation pattern).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import cdiv


def _kernel(bins_ref, out_ref, *, bin_tile: int):
    j = pl.program_id(0)  # bin tile
    i = pl.program_id(1)  # key block

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blk = bins_ref[...].astype(jnp.int32)  # (block_rows, 128)
    base = j * bin_tile
    tile = base + jax.lax.broadcasted_iota(jnp.int32, (1, bin_tile), 1)
    # (block_rows, 128, bin_tile) compare, reduced on the VPU.
    hits = (blk[:, :, None] == tile[None, :, :]).astype(jnp.int32)
    out_ref[...] += jnp.sum(hits, axis=(0, 1), keepdims=False)[None, :]


def histogram_2d(
    bins2d: jax.Array,
    num_bins: int,
    *,
    block_rows: int = 8,
    bin_tile: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Histogram of int32 bin ids in ``[0, num_bins)``; ids < 0 are ignored
    (padding).  ``bins2d`` is ``(rows, 128)``; returns ``(num_bins,)`` int32.

    ``num_bins`` must be a multiple of ``bin_tile``.
    """
    rows, lanes = bins2d.shape
    if lanes != 128:
        raise ValueError(f"lane dim must be 128, got {lanes}")
    if num_bins % bin_tile != 0:
        raise ValueError(f"num_bins {num_bins} must be a multiple of bin_tile {bin_tile}")
    num_bin_tiles = num_bins // bin_tile
    grid = (num_bin_tiles, cdiv(rows, block_rows))
    out = pl.pallas_call(
        partial(_kernel, bin_tile=bin_tile),
        out_shape=jax.ShapeDtypeStruct((num_bin_tiles, bin_tile), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_rows, lanes), lambda j, i: (i, 0), memory_space=pltpu.VMEM
            )
        ],
        out_specs=pl.BlockSpec(
            (1, bin_tile), lambda j, i: (j, 0), memory_space=pltpu.VMEM
        ),
        interpret=interpret,
        name="bin_histogram",
    )(bins2d)
    return out.reshape(num_bins)
