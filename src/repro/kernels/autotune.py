"""Block-shape autotuner for the probe/gather Pallas kernels.

Sweeps ``block_rows`` candidates for each kernel wrapped by
:mod:`repro.kernels.ops` and records the fastest per
``(kernel, backend, lane width, log2-size bucket)``.  Winners live in an
in-process cache consulted by :func:`repro.kernels.common.resolve_block_rows`
— i.e. every ops call that leaves ``block_rows=None`` — and round-trip
through a JSON artifact so a one-off sweep seeds future processes.  AOT
warmup (``plans.py`` / ``warm_server``) traces through the ops wrappers, so
executors compiled after :func:`load_cache` bake the tuned shapes in.

Usage::

    from repro.kernels import autotune
    autotune.autotune(sizes=(1 << 14, 1 << 20))  # sweep, fill cache
    autotune.save_cache()                        # persist winners
    # later / another process
    autotune.load_cache()                        # ops defaults now tuned

Cache file format (version 1)::

    {"version": 1,
     "entries": {"csr_gather|cpu|w2|b20": {
         "block_rows": 16, "best_ms": 0.41,
         "timings_ms": {"1": 0.9, "8": 0.52, "16": 0.41, ...}}}}

``REPRO_AUTOTUNE_CACHE`` names the default JSON path for save and load
(falls back to ``autotune_cache.json`` in the working directory).

The sweep calls the public ops wrappers with an *explicit* ``block_rows``
override, so timing never re-enters the resolver (no recursion, and a
half-filled cache cannot skew the measurements it is being filled from).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_ENV_CACHE = "REPRO_AUTOTUNE_CACHE"
_DEFAULT_FILE = "autotune_cache.json"

DEFAULT_CANDIDATES: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)

#: kernels the sweep knows how to drive — the resolve keys used by ops.py.
KERNELS: Tuple[str, ...] = (
    "murmur",
    "bin_histogram",
    "bucket_probe",
    "csr_gather",
    "csr_gather_batched",
)

# In-process winner cache: key → block_rows.  ``_details`` keeps the full
# sweep record per key for the JSON artifact.
_cache: Dict[str, int] = {}
_details: Dict[str, dict] = {}


def _size_bucket(n: int) -> int:
    """log2 bucket: sizes within a factor of 2 share one tuned shape."""
    return max(0, int(n) - 1).bit_length()


def _key(kernel: str, backend: str, width: int, bucket: int) -> str:
    return f"{kernel}|{backend}|w{width}|b{bucket}"


def cached_block_rows(
    kernel: str, *, n: Optional[int] = None, width: int = 1
) -> Optional[int]:
    """Tuned ``block_rows`` for a call, or None if nothing relevant is cached.

    Exact (kernel, backend, width, size-bucket) hit first; otherwise the
    nearest size bucket tuned for the same kernel/backend/width — a sweep
    at 1M rows still informs a 4M-row call.  Hot path for every ops call
    with ``block_rows=None``, so the empty-cache early-out matters.
    """
    if not _cache or n is None:
        return None
    backend = jax.default_backend()
    bucket = _size_bucket(n)
    hit = _cache.get(_key(kernel, backend, width, bucket))
    if hit is not None:
        return hit
    prefix = f"{kernel}|{backend}|w{width}|b"
    buckets = [int(k[len(prefix) :]) for k in _cache if k.startswith(prefix)]
    if not buckets:
        return None
    nearest = min(buckets, key=lambda b: abs(b - bucket))
    return _cache[prefix + str(nearest)]


def clear_cache() -> None:
    """Drop all in-process winners (tests; the JSON artifact is untouched)."""
    _cache.clear()
    _details.clear()


def _default_path() -> str:
    return os.environ.get(_ENV_CACHE, _DEFAULT_FILE)


def save_cache(path: Optional[str] = None) -> str:
    """Write the in-process winners to the JSON artifact; returns the path."""
    path = path or _default_path()
    entries = {}
    for key, br in sorted(_cache.items()):
        entries[key] = _details.get(key, {"block_rows": int(br)})
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_cache(path: Optional[str] = None) -> int:
    """Merge winners from the JSON artifact; returns entries loaded.

    Missing file is not an error (0 loaded) — callers opportunistically
    load at startup and fall back to ``common.DEFAULT_BLOCK_ROWS``.
    """
    path = path or _default_path()
    if not os.path.exists(path):
        return 0
    with open(path) as f:
        blob = json.load(f)
    entries = blob.get("entries", {})
    for key, rec in entries.items():
        _cache[key] = int(rec["block_rows"])
        _details[key] = dict(rec)
    return len(entries)


# ---------------------------------------------------------------------------
# Sweep drivers: build representative inputs and invoke the public ops
# wrapper with an explicit block_rows.  Shapes mirror how the table code
# actually calls each kernel (n = the resolver's dominant-size argument).
# ---------------------------------------------------------------------------


def _driver(kernel: str, n: int, width: int, interpret: Optional[bool]):
    from repro.kernels import ops

    rng = np.random.default_rng(0xA07)
    if kernel == "murmur":
        keys = jnp.asarray(rng.integers(0, 1 << 32, size=n, dtype=np.uint32))
        return lambda br: ops.hash_to_buckets(
            keys, max(8, n), block_rows=br, interpret=interpret
        )
    if kernel == "bin_histogram":
        num_bins = 256
        bins = jnp.asarray(rng.integers(0, num_bins, size=n, dtype=np.int32))
        return lambda br: ops.bin_histogram(
            bins, num_bins, block_rows=br, interpret=interpret
        )
    if kernel == "bucket_probe":
        nv = max(8, n // 8)
        table = jnp.asarray(
            np.sort(rng.integers(0, 1 << 32, size=n, dtype=np.uint32))
        )
        edges = np.linspace(0, n, nv + 1).astype(np.int32)
        b = rng.integers(0, nv, size=n, dtype=np.int32)
        starts = jnp.asarray(edges[b])
        ends = jnp.asarray(edges[b + 1])
        queries = jnp.asarray(rng.integers(0, 1 << 32, size=n, dtype=np.uint32))
        return lambda br: ops.bucket_probe(
            table, starts, ends, queries, block_rows=br, interpret=interpret
        )
    if kernel in ("csr_gather", "csr_gather_batched"):
        run = 8
        shape = (n,) if width == 1 else (n, width)
        table = jnp.asarray(rng.integers(0, 1 << 31, size=shape, dtype=np.int32))
        if kernel == "csr_gather":
            rows = max(1, n // run)
            starts = jnp.arange(rows, dtype=jnp.int32) * run
            counts = jnp.full((rows,), run, jnp.int32)
            return lambda br: ops.csr_gather(
                starts, counts, table, capacity=n, block_rows=br, interpret=interpret
            )
        s_dim = 4
        rows = max(1, n // (run * s_dim))
        starts = jnp.tile(jnp.arange(rows, dtype=jnp.int32)[None] * run, (s_dim, 1))
        counts = jnp.full((s_dim, rows), run, jnp.int32)
        return lambda br: ops.csr_gather_batched(
            starts,
            counts,
            table,
            capacity=rows * run,
            block_rows=br,
            interpret=interpret,
        )
    raise ValueError(f"unknown kernel {kernel!r} (one of {KERNELS})")


def _time(fn, repeats: int) -> float:
    """Best-of wall time in ms; first call (compile) excluded."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def sweep_kernel(
    kernel: str,
    *,
    n: int,
    width: int = 1,
    candidates: Sequence[int] = DEFAULT_CANDIDATES,
    repeats: int = 3,
    interpret: Optional[bool] = None,
) -> dict:
    """Time every ``block_rows`` candidate for one kernel/size/width cell.

    Stores the winner in the in-process cache (keyed by backend and the
    log2 size bucket of ``n``) and returns the full record::

        {"key": ..., "block_rows": 16, "best_ms": ..., "timings_ms": {...}}
    """
    call = _driver(kernel, n, width, interpret)
    timings = {}
    for cand in candidates:
        timings[str(int(cand))] = _time(lambda c=cand: call(int(c)), repeats)
    winner = min(timings, key=timings.get)
    key = _key(kernel, jax.default_backend(), width, _size_bucket(n))
    record = {
        "key": key,
        "block_rows": int(winner),
        "best_ms": timings[winner],
        "timings_ms": timings,
        "n": int(n),
        "width": int(width),
    }
    _cache[key] = int(winner)
    _details[key] = record
    return record


def autotune(
    kernels: Sequence[str] = KERNELS,
    *,
    sizes: Sequence[int] = (1 << 16, 1 << 20),
    widths: Sequence[int] = (1, 2),
    candidates: Sequence[int] = DEFAULT_CANDIDATES,
    repeats: int = 3,
    interpret: Optional[bool] = None,
    save: bool = False,
) -> list:
    """Sweep the kernel × size × width grid; optionally persist the artifact.

    ``widths`` only fans out the gather kernels (murmur/histogram/probe move
    single-lane streams regardless of schema width).  Returns every sweep
    record; winners land in the in-process cache as they are measured.
    """
    records = []
    for kernel in kernels:
        kwidths = widths if kernel.startswith("csr_gather") else (1,)
        for n in sizes:
            for width in kwidths:
                records.append(
                    sweep_kernel(
                        kernel,
                        n=int(n),
                        width=int(width),
                        candidates=candidates,
                        repeats=repeats,
                        interpret=interpret,
                    )
                )
    if save:
        save_cache()
    return records
