"""Shared kernel helpers: interpret-mode selection and padding utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils import cdiv


def use_interpret_mode() -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends.

    This container is CPU-only: TPU is the *target*, interpret mode is the
    validation vehicle (assignment contract).  On a real TPU this returns
    False and the kernels lower natively.
    """
    return jax.default_backend() != "tpu"


def pad_to_block_1d(x: jax.Array, block: int, fill) -> tuple[jax.Array, int]:
    """Pad a 1-D array up to a multiple of ``block``; returns (padded, n_orig)."""
    n = x.shape[0]
    padded = cdiv(n, block) * block
    if padded != n:
        x = jnp.pad(x, (0, padded - n), constant_values=fill)
    return x, n


def as_lanes(x: jax.Array, lanes: int = 128) -> jax.Array:
    """Reshape a block-padded 1-D array to (rows, lanes) — TPU VPU layout."""
    return x.reshape(-1, lanes)
