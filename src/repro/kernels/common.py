"""Shared kernel helpers: interpret-mode selection, padding, block defaults."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.utils import cdiv

# Per-kernel ``block_rows`` defaults — ONE table instead of literals
# scattered through ``ops.py`` (murmur used to hard-code 64, every probe/
# gather kernel 8).  These are the fallbacks; the autotuner
# (``repro.kernels.autotune``) overrides them per (kernel, backend, width,
# size bucket) with measured winners.
DEFAULT_BLOCK_ROWS = {
    "murmur": 64,
    "bin_histogram": 8,
    "bucket_probe": 8,
    "csr_gather": 8,
    "csr_gather_batched": 8,
}


def resolve_block_rows(
    kernel: str,
    override: Optional[int] = None,
    *,
    n: Optional[int] = None,
    width: int = 1,
) -> int:
    """The ``block_rows`` an ops-layer wrapper should use for one call.

    Resolution order: explicit ``override`` → autotuned winner (in-process
    cache, seeded from the JSON artifact) → :data:`DEFAULT_BLOCK_ROWS`.
    ``n`` is the kernel's dominant size (queries, capacity, rows) and
    ``width`` its column/lane count — together they pick the autotune
    cache bucket.  This runs *outside* every jit boundary (the public
    wrappers resolve before calling their jitted inner function), so a
    freshly loaded or updated autotune cache takes effect on the next
    call instead of being baked stale into a jit cache entry.
    """
    if override is not None:
        return int(override)
    from repro.kernels import autotune  # local import — autotune times ops

    tuned = autotune.cached_block_rows(kernel, n=n, width=width)
    if tuned is not None:
        return int(tuned)
    return DEFAULT_BLOCK_ROWS[kernel]


def use_interpret_mode() -> bool:
    """Pallas TPU kernels run in interpret mode on non-TPU backends.

    This container is CPU-only: TPU is the *target*, interpret mode is the
    validation vehicle (assignment contract).  On a real TPU this returns
    False and the kernels lower natively.
    """
    return jax.default_backend() != "tpu"


def pad_to_block_1d(x: jax.Array, block: int, fill) -> tuple[jax.Array, int]:
    """Pad a 1-D array up to a multiple of ``block``; returns (padded, n_orig)."""
    n = x.shape[0]
    padded = cdiv(n, block) * block
    if padded != n:
        x = jnp.pad(x, (0, padded - n), constant_values=fill)
    return x, n


def as_lanes(x: jax.Array, lanes: int = 128) -> jax.Array:
    """Reshape a block-padded 1-D array to (rows, lanes) — TPU VPU layout."""
    return x.reshape(-1, lanes)
