"""Pallas TPU kernels for the paper's compute hot-spots.

Each subpackage follows ``<name>.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``ops.py`` (jit'd public wrapper, auto-interpret on CPU) and
``ref.py`` (pure-jnp oracle used by the allclose tests).

Kernels:

* ``murmur``          — fused MurmurHash3 + bucket/bin id (Alg. 1 l.2, Alg. 2 l.4-8).
* ``histogram``       — blocked compare-tile bin histogram (Phase 1 counters).
* ``bucket_probe``    — the paper's linear bucket scan for queries (§3.3),
  plus the CSR gather kernel (pass 2 of the retrieval pipeline).
* ``flash_attention`` — blockwise online-softmax attention for the LM stack
  (the framework's compute hot-spot; TPU target, validated in interpret mode).
"""

from repro.kernels.common import use_interpret_mode

__all__ = ["use_interpret_mode"]
