"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import hashing

_NEG_INF = -1e30


def hash_to_buckets_ref(keys: jax.Array, table_size: int, seed: int) -> jax.Array:
    """Oracle for the fused murmur+bucket kernel."""
    return hashing.hash_to_buckets(keys, table_size, seed=seed)


def histogram_ref(bins: jax.Array, num_bins: int) -> jax.Array:
    """Oracle for the compare-tile histogram; ids outside [0, num_bins) ignored."""
    b = bins.astype(jnp.int32)
    valid = (b >= 0) & (b < num_bins)
    b = jnp.where(valid, b, 0)
    ones = valid.astype(jnp.int32)
    return jnp.zeros((num_bins,), jnp.int32).at[b.reshape(-1)].add(ones.reshape(-1))


def bucket_probe_ref(
    starts: jax.Array,
    ends: jax.Array,
    q: jax.Array,
    table: jax.Array,
    max_probe: int,
) -> jax.Array:
    """Oracle for the linear bucket scan."""
    n = table.shape[0]
    idx = starts[:, None].astype(jnp.int32) + jnp.arange(max_probe, dtype=jnp.int32)
    valid = idx < ends[:, None]
    vals = table[jnp.clip(idx, 0, n - 1)]
    return jnp.sum(valid & (vals == q[:, None].astype(jnp.uint32)), axis=1).astype(
        jnp.int32
    )


def csr_gather_ref(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    capacity: int,
    fill: int = -1,
) -> tuple[jax.Array, jax.Array]:
    """Oracle for the CSR gather kernel: ``(values, row_idx)``, each (capacity,).

    Lane-aware: a multi-column ``(Tn, C)`` table yields ``(capacity, C)``
    values.  Deliberately *not* the kernel's searchsorted idiom (that lives
    in ``repro.core.hashgraph.csr_gather`` too): a plain numpy concatenation
    of the runs, so a bug in the shared idiom cannot hide in the comparison.
    """
    import numpy as np

    starts_n = np.asarray(starts).astype(np.int64)
    counts_n = np.asarray(counts).astype(np.int64)
    table_n = np.asarray(table)
    out_shape = (capacity,) + table_n.shape[1:]
    vals = np.full(out_shape, fill, dtype=np.int32)
    rows = np.full((capacity,), -1, dtype=np.int32)
    pos = 0
    for i, (s, c) in enumerate(zip(starts_n, counts_n)):
        for j in range(c):
            if pos >= capacity:
                break
            vals[pos] = table_n[min(max(s + j, 0), len(table_n) - 1)]
            rows[pos] = i
            pos += 1
    return jnp.asarray(vals), jnp.asarray(rows)


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_heads_per_kv: int = 1,
) -> jax.Array:
    """Oracle attention over (Hq, Sq, D) / (Hkv, Skv, D), f32 internals."""
    hq, sq, d = q.shape
    hkv, skv, _ = k.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if q_heads_per_kv > 1:
        k = jnp.repeat(k, q_heads_per_kv, axis=0)
        v = jnp.repeat(v, q_heads_per_kv, axis=0)
    s = jnp.einsum(
        "hqd,hkd->hqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        offset = skv - sq
        mask &= k_pos <= q_pos + offset
        if window is not None:
            mask &= k_pos > q_pos + offset - window
    elif window is not None:
        mask &= jnp.abs(k_pos - q_pos) < window
    s = jnp.where(mask[None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform garbage; zero them like the kernel.
    any_valid = mask.any(axis=1)[None, :, None]
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return jnp.where(any_valid, out, 0.0).astype(q.dtype)


def slstm_sequence_ref(pre, r, c0, n0, h0, m0):
    """Oracle for the sLSTM recurrence kernel (lax.scan over time).

    pre (B,H,S,4,hd) f32; r (H,4,hd,hd); state (B,H,hd) each.
    Returns (hs (B,H,S,hd), (c,n,h,m) finals).
    """

    def step(carry, xt):  # xt: (B,H,4,hd)
        c, n, h, m = carry
        rec = jnp.einsum("bhd,hgde->bhge", h, r)
        pre_t = xt + rec
        itil, ftil, ztil, otil = (pre_t[:, :, g] for g in range(4))
        m_new = jnp.maximum(ftil + m, itil)
        i = jnp.exp(itil - m_new)
        f = jnp.exp(ftil + m - m_new)
        z = jnp.tanh(ztil)
        o = jax.nn.sigmoid(otil)
        c2 = f * c + i * z
        n2 = f * n + i
        h2 = o * c2 / jnp.maximum(n2, 1.0)
        return (c2, n2, h2, m_new), h2

    (c, n, h, m), hs = jax.lax.scan(
        step, (c0, n0, h0, m0), pre.transpose(2, 0, 1, 3, 4)
    )
    return hs.transpose(1, 2, 0, 3), (c, n, h, m)
