"""Bucket-probe Pallas kernel — the paper's linear bucket scan (§3.3 query).

For each query key the CUDA code walks ``keys[offset[h] : offset[h+1]]``
counting matches.  The TPU kernel processes a ``(block_rows, 128)`` tile of
queries per grid step with the whole CSR ``keys`` array resident in VMEM
(one table shard per TensorCore — the distributed layer keeps shards small
enough; 2M keys = 8 MB of a 16 MB VMEM).  The probe loop is a fixed-trip
``fori_loop`` over ``max_probe`` steps of vectorized gathers — branchless,
no divergence, mask-terminated.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import cdiv


def _kernel(starts_ref, ends_ref, q_ref, table_ref, out_ref, *, max_probe: int):
    starts = starts_ref[...].astype(jnp.int32)
    ends = ends_ref[...].astype(jnp.int32)
    q = q_ref[...].astype(jnp.uint32)
    table = table_ref[...].reshape(-1)  # (Tn,) uint32, whole shard in VMEM
    tn = table.shape[0]

    def body(c, acc):
        idx = starts + c
        valid = idx < ends
        vals = jnp.take(table, jnp.clip(idx, 0, tn - 1), axis=0)
        return acc + (valid & (vals == q)).astype(jnp.int32)

    acc0 = jnp.zeros(starts.shape, jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, max_probe, body, acc0)


def bucket_probe_2d(
    starts2d: jax.Array,
    ends2d: jax.Array,
    q2d: jax.Array,
    table2d: jax.Array,
    *,
    max_probe: int = 64,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Count per-query matches in its bucket window.

    ``starts2d/ends2d/q2d``: ``(rows, 128)`` query tiles; ``table2d``:
    ``(t_rows, 128)`` uint32 CSR keys (flattened row-major).  Returns
    ``(rows, 128)`` int32 counts.
    """
    rows, lanes = q2d.shape
    if lanes != 128:
        raise ValueError(f"lane dim must be 128, got {lanes}")
    t_rows, t_lanes = table2d.shape
    if t_lanes != 128:
        raise ValueError("table lane dim must be 128")
    grid = (cdiv(rows, block_rows),)
    qspec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM)
    tspec = pl.BlockSpec((t_rows, t_lanes), lambda i: (0, 0), memory_space=pltpu.VMEM)
    return pl.pallas_call(
        partial(_kernel, max_probe=max_probe),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec],
        out_specs=qspec,
        interpret=interpret,
        name="bucket_probe",
    )(starts2d, ends2d, q2d, table2d)


# ---------------------------------------------------------------------------
# CSR gather — pass 2 of the count→prefix-sum→gather retrieval pipeline
# ---------------------------------------------------------------------------


def _gather_tile(offsets, starts, table, slot, *, num_rows: int, fill: int):
    """Resolve one tile of output slots to gathered table values.

    Slot ``s`` belongs to the source row found by binary search in the
    prefix-sum ``offsets`` (searchsorted side='right', branchless fixed-trip
    bisection — the same idiom as the query-side segment search), and reads
    ``table[starts[row] + (s - offsets[row])]``.  Shared by the single-CSR
    and the batched (one-CSR-per-source) kernels.
    """
    tn = table.shape[0]
    total = jnp.take(offsets, num_rows)

    # searchsorted(offsets, slot, side='right') via fixed-trip bisection.
    iters = max(1, int(num_rows + 1).bit_length())
    lo = jnp.zeros(slot.shape, jnp.int32)
    hi = jnp.full(slot.shape, num_rows + 1, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        v = jnp.take(offsets, jnp.clip(mid, 0, offsets.shape[0] - 1), axis=0)
        active = lo < hi
        go_right = v <= slot
        new_lo = jnp.where(active & go_right, mid + 1, lo)
        new_hi = jnp.where(active & ~go_right, mid, hi)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    row = jnp.clip(lo - 1, 0, num_rows - 1)
    src = jnp.take(starts, row, axis=0) + (slot - jnp.take(offsets, row, axis=0))
    vals = jnp.take(table, jnp.clip(src, 0, tn - 1), axis=0)
    valid = slot < total
    return jnp.where(valid, vals, jnp.int32(fill)), jnp.where(valid, row, jnp.int32(-1))


def _gather_kernel(
    offsets_ref, starts_ref, table_ref, vals_ref, rowidx_ref, *, num_rows: int, fill: int, block_rows: int
):
    """Single-CSR gather: ``offsets``/``starts``/``table`` are whole-array
    VMEM residents; only the output is tiled."""
    offsets = offsets_ref[...].reshape(-1)  # (num_rows+1 padded,) int32
    starts = starts_ref[...].reshape(-1)  # (num_rows padded,) int32
    table = table_ref[...].reshape(-1)  # (Tn,) int32
    i = pl.program_id(0)
    tile = (block_rows, 128)
    slot = (
        i * (block_rows * 128)
        + jax.lax.broadcasted_iota(jnp.int32, tile, 0) * 128
        + jax.lax.broadcasted_iota(jnp.int32, tile, 1)
    )
    vals, rows = _gather_tile(
        offsets, starts, table, slot, num_rows=num_rows, fill=fill
    )
    vals_ref[...] = vals
    rowidx_ref[...] = rows


def _gather_batched_kernel(
    offsets_ref, starts_ref, table_ref, vals_ref, rowidx_ref, *, num_rows: int, fill: int, block_rows: int
):
    """Batched gather: grid axis 0 picks the source CSR, axis 1 the output
    tile within that source's segment.  The table is shared by all sources
    (each source gathers different runs of the same owner shard)."""
    offsets = offsets_ref[...].reshape(-1)  # this source's prefix sums
    starts = starts_ref[...].reshape(-1)  # this source's run starts
    table = table_ref[...].reshape(-1)  # (Tn,) int32, shared
    i = pl.program_id(1)
    tile = (block_rows, 128)
    slot = (
        i * (block_rows * 128)
        + jax.lax.broadcasted_iota(jnp.int32, tile, 0) * 128
        + jax.lax.broadcasted_iota(jnp.int32, tile, 1)
    )
    vals, rows = _gather_tile(
        offsets, starts, table, slot, num_rows=num_rows, fill=fill
    )
    vals_ref[...] = vals.reshape(1, block_rows, 128)
    rowidx_ref[...] = rows.reshape(1, block_rows, 128)


def csr_gather_2d(
    offsets2d: jax.Array,
    starts2d: jax.Array,
    table2d: jax.Array,
    *,
    capacity_rows: int,
    num_rows: int,
    fill: int = -1,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Gather ``capacity_rows * 128`` output slots from CSR match runs.

    ``offsets2d``: ``(r_o, 128)`` int32 prefix sums (``num_rows + 1`` valid
    entries, padding must be ``> offsets[num_rows]``, e.g. INT32_MAX);
    ``starts2d``: ``(r_s, 128)`` int32 run starts per source row;
    ``table2d``: ``(r_t, 128)`` int32 values table.  Returns
    ``(values, row_idx)``, each ``(capacity_rows, 128)`` int32 with
    ``fill`` / ``-1`` in slots past the total run length.
    """
    for name, arr in (("offsets", offsets2d), ("starts", starts2d), ("table", table2d)):
        if arr.shape[1] != 128:
            raise ValueError(f"{name} lane dim must be 128, got {arr.shape[1]}")
    grid = (cdiv(capacity_rows, block_rows),)
    ospec = pl.BlockSpec((block_rows, 128), lambda i: (i, 0), memory_space=pltpu.VMEM)

    def whole(arr):
        return pl.BlockSpec(arr.shape, lambda i: (0, 0), memory_space=pltpu.VMEM)

    return pl.pallas_call(
        partial(
            _gather_kernel, num_rows=num_rows, fill=fill, block_rows=block_rows
        ),
        out_shape=[
            jax.ShapeDtypeStruct((capacity_rows, 128), jnp.int32),
            jax.ShapeDtypeStruct((capacity_rows, 128), jnp.int32),
        ],
        grid=grid,
        in_specs=[whole(offsets2d), whole(starts2d), whole(table2d)],
        out_specs=[ospec, ospec],
        interpret=interpret,
        name="csr_gather",
    )(offsets2d, starts2d, table2d)


def csr_gather_batched_2d(
    offsets3d: jax.Array,
    starts3d: jax.Array,
    table2d: jax.Array,
    *,
    capacity_rows: int,
    num_rows: int,
    fill: int = -1,
    block_rows: int = 8,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused per-source CSR gathers: one grid over (sources, output tiles).

    ``offsets3d``: ``(S, r_o, 128)`` int32 per-source prefix sums
    (``num_rows + 1`` valid entries each, padding ``> offsets[num_rows]``);
    ``starts3d``: ``(S, r_s, 128)`` per-source run starts; ``table2d``:
    ``(r_t, 128)`` shared values table.  Returns ``(values, row_idx)``,
    each ``(S, capacity_rows, 128)`` int32.  Replaces S separate
    ``csr_gather_2d`` launches (the ROADMAP owner-side per-source loop)
    with a single ``pallas_call``.
    """
    s_dim = offsets3d.shape[0]
    for name, arr in (("offsets", offsets3d), ("starts", starts3d)):
        if arr.ndim != 3 or arr.shape[2] != 128 or arr.shape[0] != s_dim:
            raise ValueError(f"{name} must be (S, rows, 128), got {arr.shape}")
    if table2d.shape[1] != 128:
        raise ValueError("table lane dim must be 128")
    grid = (s_dim, cdiv(capacity_rows, block_rows))
    ospec = pl.BlockSpec(
        (1, block_rows, 128), lambda s, i: (s, i, 0), memory_space=pltpu.VMEM
    )

    def per_source(arr):
        return pl.BlockSpec(
            (1, arr.shape[1], 128), lambda s, i: (s, 0, 0), memory_space=pltpu.VMEM
        )

    tspec = pl.BlockSpec(
        table2d.shape, lambda s, i: (0, 0), memory_space=pltpu.VMEM
    )
    return pl.pallas_call(
        partial(
            _gather_batched_kernel,
            num_rows=num_rows,
            fill=fill,
            block_rows=block_rows,
        ),
        out_shape=[
            jax.ShapeDtypeStruct((s_dim, capacity_rows, 128), jnp.int32),
            jax.ShapeDtypeStruct((s_dim, capacity_rows, 128), jnp.int32),
        ],
        grid=grid,
        in_specs=[per_source(offsets3d), per_source(starts3d), tspec],
        out_specs=[ospec, ospec],
        interpret=interpret,
        name="csr_gather_batched",
    )(offsets3d, starts3d, table2d)
