"""Bucket-probe Pallas kernel — the paper's linear bucket scan (§3.3 query).

For each query key the CUDA code walks ``keys[offset[h] : offset[h+1]]``
counting matches.  The TPU kernel processes a ``(block_rows, 128)`` tile of
queries per grid step with the whole CSR ``keys`` array resident in VMEM
(one table shard per TensorCore — the distributed layer keeps shards small
enough; 2M keys = 8 MB of a 16 MB VMEM).  The probe loop is a fixed-trip
``fori_loop`` over ``max_probe`` steps of vectorized gathers — branchless,
no divergence, mask-terminated.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import cdiv


def _kernel(starts_ref, ends_ref, q_ref, table_ref, out_ref, *, max_probe: int):
    starts = starts_ref[...].astype(jnp.int32)
    ends = ends_ref[...].astype(jnp.int32)
    q = q_ref[...].astype(jnp.uint32)
    table = table_ref[...].reshape(-1)  # (Tn,) uint32, whole shard in VMEM
    tn = table.shape[0]

    def body(c, acc):
        idx = starts + c
        valid = idx < ends
        vals = jnp.take(table, jnp.clip(idx, 0, tn - 1), axis=0)
        return acc + (valid & (vals == q)).astype(jnp.int32)

    acc0 = jnp.zeros(starts.shape, jnp.int32)
    out_ref[...] = jax.lax.fori_loop(0, max_probe, body, acc0)


def bucket_probe_2d(
    starts2d: jax.Array,
    ends2d: jax.Array,
    q2d: jax.Array,
    table2d: jax.Array,
    *,
    max_probe: int = 64,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Count per-query matches in its bucket window.

    ``starts2d/ends2d/q2d``: ``(rows, 128)`` query tiles; ``table2d``:
    ``(t_rows, 128)`` uint32 CSR keys (flattened row-major).  Returns
    ``(rows, 128)`` int32 counts.
    """
    rows, lanes = q2d.shape
    if lanes != 128:
        raise ValueError(f"lane dim must be 128, got {lanes}")
    t_rows, t_lanes = table2d.shape
    if t_lanes != 128:
        raise ValueError("table lane dim must be 128")
    grid = (cdiv(rows, block_rows),)
    qspec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM)
    tspec = pl.BlockSpec((t_rows, t_lanes), lambda i: (0, 0), memory_space=pltpu.VMEM)
    return pl.pallas_call(
        partial(_kernel, max_probe=max_probe),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        grid=grid,
        in_specs=[qspec, qspec, qspec, tspec],
        out_specs=qspec,
        interpret=interpret,
        name="bucket_probe",
    )(starts2d, ends2d, q2d, table2d)
