"""Blockwise online-softmax (flash) attention Pallas kernel.

The LM architectures' compute hot-spot.  Classic TPU tiling: grid is
``(heads, q_blocks, kv_blocks)`` with the kv axis innermost; VMEM scratch
holds the running max ``m``, normalizer ``l`` and the unnormalized
accumulator.  The MXU does the two GEMMs per step (``q·kᵀ`` and ``p·v``);
masking (causal and/or sliding-window) is applied in-register; fully-masked
kv blocks are predicated off with ``pl.when`` so causal attention does half
the FLOPs (and sliding-window does ``O(S·w)``).

GQA is handled in the BlockSpec index maps — query head ``h`` reads kv head
``h // group`` — so kv tiles are fetched once per group, not replicated.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import cdiv

_NEG_INF = -1e30


def _kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    seq_q: int,
    seq_kv: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    kv_start = ik * block_kv
    # Static-shape predication: a kv block is live unless causality or the
    # sliding window excludes it entirely.  Decode aligns the query block to
    # the suffix of the kv axis (offset = seq_kv - seq_q).
    offset = seq_kv - seq_q if causal else 0
    if causal:
        k_max = q_start + block_q - 1 + offset
    elif window is not None:
        k_max = q_start + block_q - 1 + window - 1
    else:
        k_max = seq_kv - 1
    if window is not None:
        k_min = q_start + offset - window + 1
    else:
        k_min = 0
    live = (kv_start <= k_max) & (kv_start + block_kv - 1 >= k_min)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (block_q, d)
        k = k_ref[0].astype(jnp.float32)  # (block_kv, d)
        v = v_ref[0].astype(jnp.float32)  # (block_kv, d)
        # Rows past seq_kv are block padding (undefined memory). Their score
        # columns are masked below, but 0 * garbage(NaN) in p·v still poisons
        # the accumulator — zero the padded value rows explicitly.
        col_valid = kv_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_kv, 1), 0
        ) < seq_kv
        v = jnp.where(col_valid, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_kv)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < seq_kv
        if causal:
            # decode offset: query row i sits at absolute position
            # seq_kv - seq_q + i (aligned suffix), standard causal otherwise.
            offset = seq_kv - seq_q
            mask &= k_pos <= q_pos + offset
            if window is not None:
                mask &= k_pos > q_pos + offset - window
        elif window is not None:
            mask &= jnp.abs(k_pos - q_pos) < window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[:, :1]  # (block_q, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        # Rows where every key is masked: exp(-inf - -inf) garbage — zero them.
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_fhsd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    q_heads_per_kv: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Attention over flattened-head layout.

    ``q``: (Hq, Sq, D), ``k``/``v``: (Hkv, Skv, D) with
    ``Hq == Hkv * q_heads_per_kv``.  Returns (Hq, Sq, D) in q's dtype.
    """
    hq, sq, d = q.shape
    hkv, skv, dk = k.shape
    if dk != d or v.shape != k.shape:
        raise ValueError("k/v shape mismatch")
    if hq != hkv * q_heads_per_kv:
        raise ValueError(f"GQA mismatch: {hq} != {hkv} * {q_heads_per_kv}")
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    nq = cdiv(sq, block_q)
    nkv = cdiv(skv, block_kv)
    grid = (hq, nq, nkv)
    group = q_heads_per_kv

    kernel = functools.partial(
        _kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        seq_q=sq,
        seq_kv=skv,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((hq, sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, block_q, d), lambda h, i, j: (h, i, 0), memory_space=pltpu.VMEM
            ),
            pl.BlockSpec(
                (1, block_kv, d),
                lambda h, i, j: (h // group, j, 0),
                memory_space=pltpu.VMEM,
            ),
            pl.BlockSpec(
                (1, block_kv, d),
                lambda h, i, j: (h // group, j, 0),
                memory_space=pltpu.VMEM,
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, d), lambda h, i, j: (h, i, 0), memory_space=pltpu.VMEM
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
