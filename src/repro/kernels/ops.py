"""Public jit'd wrappers around the Pallas kernels.

Each wrapper handles padding/reshaping to the TPU ``(rows, 128)`` lane
layout and chooses interpret mode automatically off-TPU (this container is
CPU-only; TPU is the lowering target, interpret mode the validator).

``block_rows`` left ``None`` resolves through
:func:`repro.kernels.common.resolve_block_rows` — autotuned winner if the
:mod:`repro.kernels.autotune` cache holds one for the call's (kernel,
backend, width, size) bucket, the ``common.DEFAULT_BLOCK_ROWS`` table
otherwise.  Resolution happens in the un-jitted public wrapper, *before*
the jitted inner function, so the jit cache is keyed on the resolved
integer: loading a new autotune cache changes subsequent calls without
invalidating or poisoning existing compiled programs.  Plans/AOT warmup
(``plans.py``/``warm_server``) trace through these wrappers, so executors
compiled after ``autotune.load_cache()`` bake the tuned shapes in.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashing
from repro.kernels import bucket_probe as _probe
from repro.kernels import common
from repro.kernels import flash_attention as _flash
from repro.kernels import histogram as _hist
from repro.kernels import murmur as _murmur
from repro.utils import cdiv

LANES = 128


def _auto(interpret: Optional[bool]) -> bool:
    return common.use_interpret_mode() if interpret is None else interpret


def hash_to_buckets(
    keys: jax.Array,
    table_size: int,
    seed: int = hashing.DEFAULT_SEED,
    *,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused murmur3+mod of a flat (N,) uint32 key array → (N,) int32."""
    block_rows = common.resolve_block_rows(
        "murmur", block_rows, n=keys.shape[0]
    )
    return _hash_to_buckets_jit(
        keys, table_size, seed, block_rows=block_rows, interpret=interpret
    )


@partial(jax.jit, static_argnames=("table_size", "seed", "block_rows", "interpret"))
def _hash_to_buckets_jit(
    keys: jax.Array,
    table_size: int,
    seed: int,
    *,
    block_rows: int,
    interpret: Optional[bool],
) -> jax.Array:
    n = keys.shape[0]
    padded, _ = common.pad_to_block_1d(keys.astype(jnp.uint32), LANES * block_rows, 0)
    out = _murmur.murmur_bucket_2d(
        common.as_lanes(padded, LANES),
        table_size,
        seed,
        block_rows=block_rows,
        interpret=_auto(interpret),
    )
    return out.reshape(-1)[:n]


def bin_histogram(
    bins: jax.Array,
    num_bins: int,
    *,
    block_rows: Optional[int] = None,
    bin_tile: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Histogram of (N,) int32 bin ids → (num_bins,) int32.

    ``num_bins`` is padded up to a multiple of ``bin_tile`` internally.
    """
    block_rows = common.resolve_block_rows(
        "bin_histogram", block_rows, n=bins.shape[0]
    )
    return _bin_histogram_jit(
        bins, num_bins, block_rows=block_rows, bin_tile=bin_tile, interpret=interpret
    )


@partial(
    jax.jit, static_argnames=("num_bins", "block_rows", "bin_tile", "interpret")
)
def _bin_histogram_jit(
    bins: jax.Array,
    num_bins: int,
    *,
    block_rows: int,
    bin_tile: int,
    interpret: Optional[bool],
) -> jax.Array:
    padded_bins = cdiv(num_bins, bin_tile) * bin_tile
    x, _ = common.pad_to_block_1d(bins.astype(jnp.int32), LANES * block_rows, -1)
    out = _hist.histogram_2d(
        common.as_lanes(x, LANES),
        padded_bins,
        block_rows=block_rows,
        bin_tile=bin_tile,
        interpret=_auto(interpret),
    )
    return out[:num_bins]


def bucket_probe(
    table_keys: jax.Array,
    starts: jax.Array,
    ends: jax.Array,
    queries: jax.Array,
    *,
    max_probe: int = 64,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Per-query match count by linear bucket scan (paper's query loop)."""
    block_rows = common.resolve_block_rows(
        "bucket_probe", block_rows, n=queries.shape[0]
    )
    return _bucket_probe_jit(
        table_keys,
        starts,
        ends,
        queries,
        max_probe=max_probe,
        block_rows=block_rows,
        interpret=interpret,
    )


@partial(jax.jit, static_argnames=("max_probe", "block_rows", "interpret"))
def _bucket_probe_jit(
    table_keys: jax.Array,
    starts: jax.Array,
    ends: jax.Array,
    queries: jax.Array,
    *,
    max_probe: int,
    block_rows: int,
    interpret: Optional[bool],
) -> jax.Array:
    nq = queries.shape[0]
    blk = LANES * block_rows
    s, _ = common.pad_to_block_1d(starts.astype(jnp.int32), blk, 0)
    e, _ = common.pad_to_block_1d(ends.astype(jnp.int32), blk, 0)  # empty window
    q, _ = common.pad_to_block_1d(queries.astype(jnp.uint32), blk, 0)
    t, _ = common.pad_to_block_1d(table_keys.astype(jnp.uint32), LANES, 0)
    out = _probe.bucket_probe_2d(
        common.as_lanes(s, LANES),
        common.as_lanes(e, LANES),
        common.as_lanes(q, LANES),
        common.as_lanes(t, LANES),
        max_probe=max_probe,
        block_rows=block_rows,
        interpret=_auto(interpret),
    )
    return out.reshape(-1)[:nq]


_INT32_MAX = jnp.iinfo(jnp.int32).max


def csr_gather(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    *,
    capacity: int,
    fill: int = -1,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """CSR match-run compaction (pass 2 of count→prefix-sum→gather retrieval).

    Concatenates ``table[starts[i] : starts[i]+counts[i]]`` row-major into a
    static ``(capacity,)`` buffer.  The prefix sum runs in XLA; the per-slot
    binary-search + gather runs in the Pallas kernel with ``offsets`` /
    ``starts`` / ``table`` resident in VMEM.  Returns
    ``(offsets, row_idx, gathered, num_dropped)`` — the same contract as
    ``repro.core.hashgraph.csr_gather`` for 32-bit tables: the kernel moves
    int32 lanes, so a uint32 ``table`` is bitcast through int32 and restored
    on output (``fill`` is likewise reinterpreted, e.g. ``-1`` → 0xFFFFFFFF);
    other dtypes are rejected.

    Lane-aware: for a multi-column ``(Tn, C)`` table the kernel resolves the
    per-slot binary search once (column 0); the remaining columns reuse the
    returned row indices with a plain XLA gather, so the bisection cost does
    not scale with ``C``.  ``gathered`` has shape ``(capacity, C)``.
    """
    block_rows = common.resolve_block_rows(
        "csr_gather",
        block_rows,
        n=capacity,
        width=1 if table.ndim == 1 else table.shape[-1],
    )
    return _csr_gather_jit(
        starts,
        counts,
        table,
        capacity=capacity,
        fill=fill,
        block_rows=block_rows,
        interpret=interpret,
    )


@partial(
    jax.jit, static_argnames=("capacity", "fill", "block_rows", "interpret")
)
def _csr_gather_jit(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    *,
    capacity: int,
    fill: int,
    block_rows: int,
    interpret: Optional[bool],
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    num_rows = counts.shape[0]
    counts = counts.astype(jnp.int32)
    out_dtype = table.dtype
    if out_dtype == jnp.uint32:
        table = jax.lax.bitcast_convert_type(table, jnp.int32)
    elif out_dtype != jnp.int32:
        raise ValueError(f"csr_gather kernel supports int32/uint32 tables, got {out_dtype}")
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)]
    )
    total = offsets[-1]
    # Offsets padding must exceed every real slot id so the bisection never
    # resolves into it.
    o, _ = common.pad_to_block_1d(offsets, LANES, _INT32_MAX)
    s, _ = common.pad_to_block_1d(starts.astype(jnp.int32), LANES, 0)
    cap_padded = cdiv(capacity, LANES * block_rows) * (LANES * block_rows)
    col0 = table if table.ndim == 1 else table[:, 0]
    t, _ = common.pad_to_block_1d(col0.astype(jnp.int32), LANES, fill)
    vals2d, rows2d = _probe.csr_gather_2d(
        common.as_lanes(o, LANES),
        common.as_lanes(s, LANES),
        common.as_lanes(t, LANES),
        capacity_rows=cap_padded // LANES,
        num_rows=num_rows,
        fill=fill,
        block_rows=block_rows,
        interpret=_auto(interpret),
    )
    row_idx = rows2d.reshape(-1)[:capacity]
    if table.ndim == 1:
        gathered = vals2d.reshape(-1)[:capacity]
    else:
        # Reuse the kernel's row resolution for the remaining columns: the
        # same src = starts[row] + (slot - offsets[row]) arithmetic, one
        # vectorized gather per column.
        slot = jnp.arange(capacity, dtype=jnp.int32)
        valid = row_idx >= 0
        rowc = jnp.clip(row_idx, 0, num_rows - 1)
        src = starts.astype(jnp.int32)[rowc] + (slot - offsets[rowc])
        srcc = jnp.clip(src, 0, table.shape[0] - 1)
        cols = [vals2d.reshape(-1)[:capacity]] + [
            jnp.where(valid, table[srcc, c], jnp.int32(fill))
            for c in range(1, table.shape[1])
        ]
        gathered = jnp.stack(cols, axis=-1)
    if out_dtype == jnp.uint32:
        gathered = jax.lax.bitcast_convert_type(gathered, jnp.uint32)
    num_dropped = jnp.maximum(total - capacity, 0).astype(jnp.int32)
    return jnp.minimum(offsets, capacity), row_idx, gathered, num_dropped


def csr_gather_batched(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    *,
    capacity: int,
    fill: int = -1,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused per-source CSR compaction: S gathers in one kernel launch.

    ``starts``/``counts`` are ``(S, N)`` — one CSR gather problem per source
    row, all reading the shared ``table`` — and every source gets its own
    static ``capacity``-slot output segment.  Equivalent to ``S`` calls of
    :func:`csr_gather` (or a vmap of ``hashgraph.csr_gather``) but with a
    single grid over ``(sources, capacity tiles)`` — the ROADMAP kernel
    fusion of the owner-side per-source loop in distributed retrieval.

    Returns ``(offsets, row_idx, gathered, num_dropped)``: ``offsets``
    ``(S, N+1)`` clamped per source, ``row_idx``/``gathered``
    ``(S, capacity[, C])``, and ``num_dropped`` the () int32 total overflow
    across sources.  Same dtype contract as :func:`csr_gather` (int32 lanes,
    uint32 bitcast through, multi-column tables resolve the bisection once).
    """
    block_rows = common.resolve_block_rows(
        "csr_gather_batched",
        block_rows,
        n=capacity,
        width=1 if table.ndim == 1 else table.shape[-1],
    )
    return _csr_gather_batched_jit(
        starts,
        counts,
        table,
        capacity=capacity,
        fill=fill,
        block_rows=block_rows,
        interpret=interpret,
    )


@partial(
    jax.jit, static_argnames=("capacity", "fill", "block_rows", "interpret")
)
def _csr_gather_batched_jit(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    *,
    capacity: int,
    fill: int,
    block_rows: int,
    interpret: Optional[bool],
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    s_dim, num_rows = counts.shape
    counts = counts.astype(jnp.int32)
    out_dtype = table.dtype
    if out_dtype == jnp.uint32:
        table = jax.lax.bitcast_convert_type(table, jnp.int32)
    elif out_dtype != jnp.int32:
        raise ValueError(
            f"csr_gather kernel supports int32/uint32 tables, got {out_dtype}"
        )
    starts = starts.astype(jnp.int32)
    offsets = jnp.concatenate(
        [
            jnp.zeros((s_dim, 1), jnp.int32),
            jnp.cumsum(counts, axis=1, dtype=jnp.int32),
        ],
        axis=1,
    )
    totals = offsets[:, -1]

    def pad_rows(x, fillv):
        n = x.shape[1]
        padded = cdiv(n, LANES) * LANES
        if padded != n:
            x = jnp.pad(x, ((0, 0), (0, padded - n)), constant_values=fillv)
        return x.reshape(s_dim, -1, LANES)

    cap_padded = cdiv(capacity, LANES * block_rows) * (LANES * block_rows)
    col0 = table if table.ndim == 1 else table[:, 0]
    t, _ = common.pad_to_block_1d(col0.astype(jnp.int32), LANES, fill)
    vals3, rows3 = _probe.csr_gather_batched_2d(
        pad_rows(offsets, _INT32_MAX),
        pad_rows(starts, 0),
        common.as_lanes(t, LANES),
        capacity_rows=cap_padded // LANES,
        num_rows=num_rows,
        fill=fill,
        block_rows=block_rows,
        interpret=_auto(interpret),
    )
    row_idx = rows3.reshape(s_dim, -1)[:, :capacity]
    if table.ndim == 1:
        gathered = vals3.reshape(s_dim, -1)[:, :capacity]
    else:
        # Reuse the kernel's row resolution for the remaining columns (same
        # contract as csr_gather, vectorized over the source axis).
        slot = jnp.arange(capacity, dtype=jnp.int32)[None, :]
        valid = row_idx >= 0
        rowc = jnp.clip(row_idx, 0, num_rows - 1)
        src = jnp.take_along_axis(starts, rowc, axis=1) + (
            slot - jnp.take_along_axis(offsets, rowc, axis=1)
        )
        srcc = jnp.clip(src, 0, table.shape[0] - 1)
        cols = [vals3.reshape(s_dim, -1)[:, :capacity]] + [
            jnp.where(valid, table[srcc, c], jnp.int32(fill))
            for c in range(1, table.shape[1])
        ]
        gathered = jnp.stack(cols, axis=-1)
    if out_dtype == jnp.uint32:
        gathered = jax.lax.bitcast_convert_type(gathered, jnp.uint32)
    num_dropped = jnp.sum(jnp.maximum(totals - capacity, 0)).astype(jnp.int32)
    return jnp.minimum(offsets, capacity), row_idx, gathered, num_dropped


def interleave_layer_runs(starts, counts, tables):
    """Slot-major/layer-minor interleave of per-layer CSR run descriptors.

    ``starts``/``counts`` are ``(L, S, N)`` with starts already offset into
    the concatenated layer address space; returns ``(starts_i, counts_i,
    table_cat)`` where the ``(S, N·L)`` descriptors place slot ``i``'s L
    runs adjacently in epoch order.  This packing order is load-bearing —
    the ragged return reconstructs segment offsets from per-slot totals
    assuming exactly it — so both the Pallas path
    (:func:`csr_gather_layers`) and the jnp reference in
    ``multi_hashgraph`` share this one definition.
    """
    l, s_dim, n = counts.shape
    table_cat = tables[0] if l == 1 else jnp.concatenate(tables, axis=0)
    starts_i = starts.astype(jnp.int32).transpose(1, 2, 0).reshape(s_dim, n * l)
    counts_i = counts.astype(jnp.int32).transpose(1, 2, 0).reshape(s_dim, n * l)
    return starts_i, counts_i, table_cat


def csr_gather_layers(
    starts: jax.Array,
    counts: jax.Array,
    tables,
    *,
    capacity: int,
    fill: int = -1,
    block_rows: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused owner-side gather across a layer stack: one launch for L·S CSRs.

    ``starts``/``counts`` are ``(L, S, N)`` — for each of ``L`` layers, one
    CSR gather problem per source device, with ``starts`` already offset
    into the concatenated layer address space — and ``tables`` is the
    per-layer tuple of value tables (``(T_l,)`` or ``(T_l, C)`` int32).
    The per-layer descriptors are interleaved slot-major/layer-minor per
    source (slot ``i``'s L runs are adjacent, epoch order), so each source's
    output segment holds every routed query's *merged* layer runs
    contiguously — exactly the packing a single ragged return trip needs.
    One :func:`csr_gather_batched` grid over ``(sources, capacity tiles)``
    with ``N·L`` rows per source replaces the L separate per-layer launch
    rounds of the unfused path.

    Returns ``(gathered, num_dropped)``: ``(S, capacity[, C])`` packed
    segments and the () int32 total overflow across sources.
    """
    starts_i, counts_i, table_cat = interleave_layer_runs(starts, counts, tables)
    _, _, gathered, num_dropped = csr_gather_batched(
        starts_i,
        counts_i,
        table_cat,
        capacity=capacity,
        fill=fill,
        block_rows=block_rows,
        interpret=interpret,
    )
    return gathered, num_dropped


@partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "scale",
        "block_q",
        "block_kv",
        "interpret",
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention over (B, Hq, S, D) with GQA kv (B, Hkv, Skv, D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)
    out = _flash.flash_attention_fhsd(
        qf,
        kf,
        vf,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_kv=block_kv,
        q_heads_per_kv=group,
        interpret=_auto(interpret),
    )
    return out.reshape(b, hq, sq, d)


@partial(jax.jit, static_argnames=("t_block", "interpret"))
def slstm_recurrence(
    pre: jax.Array,
    r: jax.Array,
    c0: jax.Array,
    n0: jax.Array,
    h0: jax.Array,
    m0: jax.Array,
    *,
    t_block: int = 256,
    interpret: Optional[bool] = None,
):
    """sLSTM recurrence with VMEM-pinned recurrent weights.

    pre (B,H,S,4,hd) f32, r (H,4,hd,hd) f32, state (B,H,hd) f32 each.
    S is padded to a multiple of ``t_block`` internally.
    """
    from repro.kernels import slstm as _slstm

    b, h, s, four, hd = pre.shape
    tb = min(t_block, s)
    pad = (-s) % tb
    if pad:
        pre = jnp.pad(pre, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    hs, finals = _slstm.slstm_sequence(
        pre.astype(jnp.float32),
        r.astype(jnp.float32),
        c0.astype(jnp.float32),
        n0.astype(jnp.float32),
        h0.astype(jnp.float32),
        m0.astype(jnp.float32),
        t_block=tb,
        seq_len=s,
        interpret=_auto(interpret),
    )
    return hs[:, :, :s], finals
