"""Pallas sLSTM recurrence kernel — recurrent weights pinned in VMEM.

The sLSTM step is inherently sequential (h_{t-1} feeds the gates), so the
XLA lowering is a length-S while loop whose body re-reads the per-head
recurrent matrix ``r`` (4·hd² f32 — 4 MB for xlstm-1.3b) from HBM **every
timestep**: 4096 steps × 48 layers × 8 microbatches ≈ 20 PB/device of pure
weight re-reads — the single largest term in the xlstm train_4k roofline.

TPU-native fix (this kernel): grid = (B, H, S/T); the time axis is the
innermost, sequentially-iterated grid dim, state (c, n, h, m) lives in VMEM
scratch across grid steps, and ``r_h`` is loaded ONCE per (b, h) — the
index_map ignores the time index, so Pallas keeps the block resident.
HBM traffic drops to streaming the pre-projected inputs once:
S·4·hd reads + S·hd writes per (b, h).

Validated in interpret mode against the lax.scan oracle
(``repro.models.ssm.slstm_block``) over shape/dtype sweeps.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

# Renamed TPUCompilerParams -> CompilerParams across pallas versions.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kernel(pre_ref, r_ref, c0_ref, n0_ref, h0_ref, m0_ref,
            hs_ref, cf_ref, nf_ref, hf_ref, mf_ref,
            c_s, n_s, h_s, m_s, *, t_block: int, seq_len: int):
    """One (b, h, t_chunk) grid step: ``t_block`` sequential sLSTM steps.

    pre_ref: (1, 1, T, 4, hd) input pre-activations (x·W + b), f32
    r_ref:   (1, 4, hd, hd) recurrent weights — resident across t
    state scratch c/n/h/m: (1, hd) f32, carried across the t grid dim.
    """
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _load_state():
        c_s[...] = c0_ref[0]
        n_s[...] = n0_ref[0]
        h_s[...] = h0_ref[0]
        m_s[...] = m0_ref[0]

    r = r_ref[0]  # (4, hd, hd)

    def step(i, carry):
        c, n, h, m = carry
        xt = pre_ref[0, 0, i]  # (4, hd)
        # recurrent contribution: h (1, hd) × r (4, hd, hd) → (4, hd)
        rec = jax.lax.dot_general(
            h, r, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (1, 4, hd)
        pre = xt[None] + rec  # (1, 4, hd)
        itil = pre[:, 0]
        ftil = pre[:, 1]
        ztil = pre[:, 2]
        otil = pre[:, 3]
        m_new = jnp.maximum(ftil + m, itil)
        ig = jnp.exp(itil - m_new)
        fg = jnp.exp(ftil + m - m_new)
        z = jnp.tanh(ztil)
        o = jax.nn.sigmoid(otil)
        c2 = fg * c + ig * z
        n2 = fg * n + ig
        h2 = o * c2 / jnp.maximum(n2, 1.0)
        hs_ref[0, 0, i] = h2[0]
        # steps beyond the true sequence length (t_block padding) are
        # no-ops on the carried state.
        live = (t * t_block + i) < seq_len
        keep = lambda new, old: jnp.where(live, new, old)
        return keep(c2, c), keep(n2, n), keep(h2, h), keep(m_new, m)

    carry = (c_s[...], n_s[...], h_s[...], m_s[...])
    c, n, h, m = jax.lax.fori_loop(0, t_block, step, carry)
    c_s[...], n_s[...], h_s[...], m_s[...] = c, n, h, m

    @pl.when(t == pl.num_programs(2) - 1)
    def _store_state():
        cf_ref[0] = c_s[...]
        nf_ref[0] = n_s[...]
        hf_ref[0] = h_s[...]
        mf_ref[0] = m_s[...]


def slstm_sequence(
    pre: jax.Array,  # (B, H, S, 4, hd) f32 pre-activations (x·W_in + b)
    r: jax.Array,  # (H, 4, hd, hd) f32 recurrent weights
    c0: jax.Array,  # (B, H, hd) f32
    n0: jax.Array,
    h0: jax.Array,
    m0: jax.Array,
    *,
    t_block: int = 256,
    seq_len: Optional[int] = None,
    interpret: bool = False,
):
    """Run the sLSTM recurrence. Returns (hs (B,H,S,hd), (c,n,h,m) finals).

    ``seq_len``: true length when the time axis carries t_block padding.
    """
    b, h, s, four, hd = pre.shape
    assert four == 4 and s % t_block == 0, (pre.shape, t_block)
    seq_len = seq_len if seq_len is not None else s
    grid = (b, h, s // t_block)
    out_shape = (
        jax.ShapeDtypeStruct((b, h, s, hd), jnp.float32),
        jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
    )
    state_spec = pl.BlockSpec(
        (1, 1, hd), lambda i, j, t: (i, j, 0), memory_space=pltpu.VMEM
    )
    outs = pl.pallas_call(
        partial(_kernel, t_block=t_block, seq_len=seq_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, t_block, 4, hd),
                lambda i, j, t: (i, j, t, 0, 0),
                memory_space=pltpu.VMEM,
            ),
            # r: index_map ignores t — resident across the time loop.
            pl.BlockSpec(
                (1, 4, hd, hd), lambda i, j, t: (j, 0, 0, 0),
                memory_space=pltpu.VMEM,
            ),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=(
            pl.BlockSpec(
                (1, 1, t_block, hd),
                lambda i, j, t: (i, j, t, 0),
                memory_space=pltpu.VMEM,
            ),
            state_spec, state_spec, state_spec, state_spec,
        ),
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="slstm_recurrence",
    )(pre, r, c0, n0, h0, m0)
    hs, cf, nf, hf, mf = outs
    return hs, (cf, nf, hf, mf)
