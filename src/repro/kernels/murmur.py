"""Fused MurmurHash3 + bucket-id Pallas kernel (Alg. 1 l.2 / Alg. 2 l.4).

Elementwise VPU kernel: each grid step hashes a ``(block_rows, 128)`` VMEM
tile of uint32 keys and reduces them modulo the table size.  Fusing the
hash with the modulo keeps the intermediate 32-bit hash out of HBM — on a
V100 the paper pays one full pass for ``H_A``; on TPU the fused tile stays
in registers/VMEM.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils import cdiv

_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_MIX1 = 0x85EBCA6B
_MIX2 = 0xC2B2AE35


def _rotl(x, r):
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def _murmur_tile(k: jax.Array, seed: int) -> jax.Array:
    """MurmurHash3_x86_32 of one uint32 word per lane (kernel-internal)."""
    k = k * jnp.uint32(_C1)
    k = _rotl(k, 15)
    k = k * jnp.uint32(_C2)
    h = jnp.uint32(seed) ^ k
    h = _rotl(h, 13)
    h = h * jnp.uint32(5) + jnp.uint32(0xE6546B64)
    h = h ^ jnp.uint32(4)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(_MIX1)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(_MIX2)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _kernel(keys_ref, out_ref, *, table_size: int, seed: int):
    k = keys_ref[...].astype(jnp.uint32)
    h = _murmur_tile(k, seed)
    out_ref[...] = (h % jnp.uint32(table_size)).astype(jnp.int32)


def murmur_bucket_2d(
    keys2d: jax.Array,
    table_size: int,
    seed: int,
    *,
    block_rows: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Hash+bucket a ``(rows, 128)`` uint32 array; returns int32 bucket ids."""
    rows, lanes = keys2d.shape
    if lanes != 128:
        raise ValueError(f"lane dim must be 128, got {lanes}")
    grid = (cdiv(rows, block_rows),)
    return pl.pallas_call(
        partial(_kernel, table_size=table_size, seed=seed),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_rows, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM
            )
        ],
        out_specs=pl.BlockSpec(
            (block_rows, lanes), lambda i: (i, 0), memory_space=pltpu.VMEM
        ),
        interpret=interpret,
        name="murmur_bucket",
    )(keys2d)
