"""Exporters — Prometheus text format and JSONL renderers for a registry.

Both operate on a :class:`~repro.obs.registry.RegistrySnapshot` (one
consistent sample), never on the live registry, so an export can never
tear across instruments.  :func:`parse_prometheus` is the inverse of
:func:`render_prometheus` for the simple subset emitted here — the CI
smoke gates *scrape* the rendered text and assert on the parsed values,
exercising the same path an external scraper would.
"""
from __future__ import annotations

import json
import math
from typing import Optional, Union

from repro.obs.registry import (
    HistogramSnapshot,
    MetricsRegistry,
    RegistrySnapshot,
)


def _snap(registry_or_snapshot) -> RegistrySnapshot:
    if isinstance(registry_or_snapshot, MetricsRegistry):
        return registry_or_snapshot.snapshot()
    return registry_or_snapshot


def _fmt_labels(lk: tuple, extra: Optional[dict] = None) -> str:
    pairs = [f'{k}="{v}"' for k, v in lk]
    if extra:
        pairs += [f'{k}="{v}"' for k, v in extra.items()]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _fmt_val(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    v = float(v)
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v)


def render_prometheus(registry_or_snapshot) -> str:
    """The snapshot in Prometheus text exposition format.

    Counters render as ``name`` totals, gauges as plain samples, and
    histograms as the standard cumulative ``_bucket{le=...}`` series plus
    ``_sum``/``_count``.
    """
    snap = _snap(registry_or_snapshot)
    by_name: dict = {}
    for (name, lk), v in snap.values.items():
        by_name.setdefault(name, []).append((lk, v))
    lines = []
    for name in sorted(by_name):
        help_txt = snap.helps.get(name)
        if help_txt:
            lines.append(f"# HELP {name} {help_txt}")
        lines.append(f"# TYPE {name} {snap.types.get(name, 'untyped')}")
        for lk, v in sorted(by_name[name]):
            if isinstance(v, HistogramSnapshot):
                cum = 0
                for bound, c in zip(v.bounds, v.counts):
                    cum += c
                    lines.append(
                        f"{name}_bucket"
                        f"{_fmt_labels(lk, {'le': _fmt_val(float(bound))})} {cum}"
                    )
                lines.append(
                    f"{name}_bucket{_fmt_labels(lk, {'le': '+Inf'})} {v.count}"
                )
                lines.append(f"{name}_sum{_fmt_labels(lk)} {_fmt_val(v.sum)}")
                lines.append(f"{name}_count{_fmt_labels(lk)} {v.count}")
            else:
                lines.append(f"{name}{_fmt_labels(lk)} {_fmt_val(v)}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Parse the exposition subset :func:`render_prometheus` emits.

    Returns ``{(name, labels_tuple): value}`` — histogram series appear
    under their ``_bucket``/``_sum``/``_count`` sample names.  The scrape
    half of the CI gates: assertions run against this dict.
    """
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        sample, _, val = line.rpartition(" ")
        if "{" in sample:
            name, _, rest = sample.partition("{")
            labels = []
            for pair in rest.rstrip("}").split(","):
                if not pair:
                    continue
                k, _, v = pair.partition("=")
                labels.append((k, v.strip('"')))
            key = (name, tuple(sorted(labels)))
        else:
            key = (sample, ())
        if val in ("+Inf", "-Inf"):
            out[key] = math.inf if val == "+Inf" else -math.inf
        else:
            f = float(val)
            out[key] = int(f) if f.is_integer() else f
    return out


def render_jsonl(registry_or_snapshot, **stamp) -> str:
    """One JSON line per metric: ``{"metric": name, "labels": {...}, ...}``.

    ``stamp`` keys (e.g. ``ts=...``, ``run="ycsb-A"``) are merged into
    every line, so streams from many runs concatenate into one greppable
    log.
    """
    snap = _snap(registry_or_snapshot)
    lines = []
    for (name, lk), v in sorted(snap.values.items()):
        rec = dict(stamp)
        rec["metric"] = name
        rec["type"] = snap.types.get(name, "untyped")
        if lk:
            rec["labels"] = dict(lk)
        if isinstance(v, HistogramSnapshot):
            rec.update(v.as_dict())
        else:
            rec["value"] = v
        lines.append(json.dumps(rec, sort_keys=True))
    return "\n".join(lines) + "\n"


def write_jsonl(path: Union[str, "object"], registry_or_snapshot, **stamp) -> None:
    """Append :func:`render_jsonl` output to ``path``."""
    with open(path, "a") as f:
        f.write(render_jsonl(registry_or_snapshot, **stamp))


__all__ = [
    "parse_prometheus",
    "render_jsonl",
    "render_prometheus",
    "write_jsonl",
]
