"""Request tracing — per-phase spans through the async serving pipeline.

Every ``AsyncFrontend`` submission can carry a :class:`Trace` that is
stamped at each pipeline boundary::

    admission -> linger -> dispatch -> device -> scatter

* **admission** — time spent inside ``submit_query`` getting the request
  into the deadline batcher (backpressure shows up here).
* **linger** — enqueue until the batcher flushed the request's batch
  (fill-triggered or deadline-triggered).
* **dispatch** — snapshot pin + bucket/pad + AOT executor launch.
* **device** — blocking on the device result (``block_until_ready``).
* **scatter** — host-side de-pad/slice and future resolution.

Phase durations aggregate into one registry histogram family
(``trace_phase_seconds{phase=...}``) plus an end-to-end
``request_latency_seconds``; the most recent completed traces are kept in
a bounded ring (constant memory) and can be dumped as JSONL for offline
timeline inspection.  A disabled tracer (``enabled=False``) costs one
attribute check per request and records nothing.
"""
from __future__ import annotations

import collections
import json
import threading
import time
from typing import Optional

from repro.obs.registry import MetricsRegistry

PHASES = ("admission", "linger", "dispatch", "device", "scatter")


class Trace:
    """One request's span: monotonic phase timestamps plus metadata.

    ``t0`` is the submission instant; ``marks[phase]`` is the *end* of that
    phase.  Phases are contiguous, so durations are successive differences.
    """

    __slots__ = ("trace_id", "t0", "marks", "size", "seqno", "bucket")

    def __init__(self, trace_id: int, t0: float, size: int):
        self.trace_id = trace_id
        self.t0 = t0
        self.marks: dict = {}
        self.size = size
        self.seqno = -1
        self.bucket = -1

    def mark(self, phase: str, t: float) -> None:
        self.marks[phase] = t

    def durations(self) -> dict:
        out = {}
        prev = self.t0
        for phase in PHASES:
            t = self.marks.get(phase)
            if t is None:
                continue
            out[phase] = max(0.0, t - prev)
            prev = t
        return out

    @property
    def total(self) -> float:
        last = max(self.marks.values()) if self.marks else self.t0
        return max(0.0, last - self.t0)

    def as_dict(self) -> dict:
        return {
            "trace_id": self.trace_id,
            "size": self.size,
            "seqno": self.seqno,
            "bucket": self.bucket,
            "total_seconds": self.total,
            "phases": self.durations(),
        }


class Tracer:
    """Factory + sink for :class:`Trace` spans, backed by a registry.

    ``start``/``finish`` bracket a request; in between the pipeline stamps
    phase marks directly on the trace object (no tracer lock touched).
    ``finish`` folds the phase durations into the registry histograms and
    appends the trace to the bounded ring.  ``live()`` counts traces
    started but not finished — the CI gate asserts it returns to zero
    after drain (a leak here means a request fell out of the pipeline).
    """

    def __init__(
        self,
        registry: Optional[MetricsRegistry] = None,
        *,
        ring: int = 256,
        enabled: bool = True,
        clock=time.perf_counter,
    ):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.enabled = enabled
        self.clock = clock
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=max(0, ring))
        self._next_id = 0
        self._started = 0
        self._finished = 0
        self._phase_hists = {
            phase: self.registry.histogram(
                "trace_phase_seconds",
                labels={"phase": phase},
                help="Per-phase request latency through the async pipeline.",
            )
            for phase in PHASES
        }
        self._total_hist = self.registry.histogram(
            "request_latency_seconds",
            help="End-to-end submit-to-result latency.",
        )
        self._recorded = self.registry.counter(
            "traces_recorded_total", help="Completed traces folded into histograms."
        )

    def start(self, size: int = 1) -> Optional[Trace]:
        if not self.enabled:
            return None
        with self._lock:
            tid = self._next_id
            self._next_id += 1
            self._started += 1
        return Trace(tid, self.clock(), size)

    def finish(self, trace: Optional[Trace]) -> None:
        if trace is None:
            return
        for phase, dur in trace.durations().items():
            self._phase_hists[phase].observe(dur)
        self._total_hist.observe(trace.total)
        self._recorded.inc()
        with self._lock:
            self._finished += 1
            if self._ring.maxlen:
                self._ring.append(trace)

    def abandon(self, trace: Optional[Trace]) -> None:
        """Drop a trace whose request failed — keeps ``live()`` honest
        without polluting the latency histograms with error paths."""
        if trace is None:
            return
        with self._lock:
            self._finished += 1

    def live(self) -> int:
        with self._lock:
            return self._started - self._finished

    def recent(self) -> list:
        """Most recent completed traces, oldest first."""
        with self._lock:
            return list(self._ring)

    def dump_jsonl(self, path: str) -> int:
        """Append the ring's traces to ``path`` as JSONL; returns count."""
        traces = self.recent()
        with open(path, "a") as f:
            for t in traces:
                f.write(json.dumps(t.as_dict(), sort_keys=True) + "\n")
        return len(traces)


__all__ = ["PHASES", "Trace", "Tracer"]
