"""Observability — one registry, request tracing, device-cost accounting.

The serving stack's single source of truth for measurement:

* :mod:`repro.obs.registry` — ``MetricsRegistry`` with counters, gauges,
  and log-bucketed latency histograms; one-lock-consistent snapshots.
* :mod:`repro.obs.tracing` — per-request spans through the async pipeline
  (admission → linger → dispatch → device → scatter) with a bounded ring
  of recent full traces.
* :mod:`repro.obs.profiling` — jaxpr-walking collective accountant plus
  XLA cost-analysis integration, one :class:`ExecutorCost` per compiled
  executor in the AOT grid.
* :mod:`repro.obs.export` — Prometheus-text and JSONL renderers (and the
  scrape-side parser the CI gates use).

Quickstart::

    from repro.obs import render_prometheus

    server = TableServer(table, keys, values)
    ...
    print(render_prometheus(server.metrics()))
"""
from repro.obs.export import (
    parse_prometheus,
    render_jsonl,
    render_prometheus,
    write_jsonl,
)
from repro.obs.profiling import (
    COLLECTIVE_PRIMITIVES,
    ExecutorCost,
    collective_profile,
    count_primitive,
    profile_executor,
)
from repro.obs.registry import (
    DEFAULT_BOUNDS,
    Counter,
    Gauge,
    Histogram,
    HistogramSnapshot,
    MetricsRegistry,
    RegistrySnapshot,
)
from repro.obs.tracing import PHASES, Trace, Tracer

__all__ = [
    "COLLECTIVE_PRIMITIVES",
    "Counter",
    "DEFAULT_BOUNDS",
    "ExecutorCost",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "MetricsRegistry",
    "PHASES",
    "RegistrySnapshot",
    "Trace",
    "Tracer",
    "collective_profile",
    "count_primitive",
    "parse_prometheus",
    "profile_executor",
    "render_jsonl",
    "render_prometheus",
    "write_jsonl",
]
