"""MetricsRegistry — counters, gauges, and log-bucketed latency histograms.

One registry instance is the single source of truth for every counter the
serving stack keeps.  Design constraints, in order:

* **snapshot-consistent**: every instrument shares the registry's one
  lock, so :meth:`MetricsRegistry.snapshot` is ONE lock acquisition that
  observes all instruments at the same instant — no field-by-field
  tearing.  The stat views (``ServerStats``/``FrontendStats``/
  ``BatcherStats``) are built from one snapshot each.
* **lock-cheap**: instrument updates are a single uncontended-lock
  increment (~100ns under CPython); every update site in the serving
  stack is per-request or per-batch, orders of magnitude above that.
  The registry lock is a *leaf* lock: no instrument ever calls out while
  holding it, so it composes under the server's writer mutex and the
  batchers' condition variables without ordering hazards.
* **quantile readout**: histograms are log-bucketed (geometric bounds,
  ``√2`` spacing by default) with p50/p99/p999 read off the bucket
  cumulative counts via within-bucket linear interpolation — constant
  memory per histogram regardless of observation count.

Instruments are get-or-create by ``(name, labels)``: asking twice returns
the same instrument, so components can re-bind to a shared registry (a
``MicroBatcher`` adopted by a ``TableServer``) without losing counts, and
sequential front ends over one server accumulate into one export stream
(per-instance views subtract a base snapshot).
"""
from __future__ import annotations

import bisect
import dataclasses
import math
import threading
from typing import Optional

# Default histogram bounds: geometric, factor sqrt(2), spanning ~1us to
# ~92s — latency-shaped.  Callers measuring non-latency quantities pass
# their own bounds.
_BASE = 1e-6
_FACTOR = math.sqrt(2.0)
DEFAULT_BOUNDS = tuple(_BASE * _FACTOR**i for i in range(54))


def _label_key(labels: Optional[dict]) -> tuple:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter.  ``inc`` under the registry lock; never decreases."""

    __slots__ = ("_lock", "_value", "name", "labels")

    def __init__(self, lock: threading.RLock, name: str, labels: tuple):
        self._lock = lock
        self._value = 0
        self.name = name
        self.labels = labels

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class Gauge:
    """Point-in-time value; ``set``/``add`` under the registry lock."""

    __slots__ = ("_lock", "_value", "name", "labels")

    def __init__(self, lock: threading.RLock, name: str, labels: tuple):
        self._lock = lock
        self._value = 0.0
        self.name = name
        self.labels = labels

    def set(self, v) -> None:
        with self._lock:
            self._value = v

    def add(self, v) -> None:
        with self._lock:
            self._value += v

    @property
    def value(self):
        with self._lock:
            return self._value


@dataclasses.dataclass(frozen=True)
class HistogramSnapshot:
    """Immutable histogram readout: totals + bucket counts + quantiles.

    ``bounds[i]`` is the inclusive upper edge of bucket ``i``; the last
    bucket (``counts[-1]``) is the overflow.  Quantiles interpolate
    linearly inside the target bucket, clamped to observed min/max, so a
    histogram that saw one value reports that value at every quantile.
    """

    count: int
    sum: float
    min: float
    max: float
    bounds: tuple
    counts: tuple

    def quantile(self, q: float) -> float:
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            if seen + c >= target:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                frac = (target - seen) / c
                v = lo + (hi - lo) * frac
                return min(max(v, self.min), self.max)
            seen += c
        return self.max

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    @property
    def p999(self) -> float:
        return self.quantile(0.999)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "mean": self.mean,
            "p50": self.p50,
            "p99": self.p99,
            "p999": self.p999,
        }


class Histogram:
    """Log-bucketed histogram with constant memory and quantile readout."""

    __slots__ = (
        "_lock", "_bounds", "_counts", "_count", "_sum", "_min", "_max",
        "name", "labels",
    )

    def __init__(
        self,
        lock: threading.RLock,
        name: str,
        labels: tuple,
        bounds: Optional[tuple] = None,
    ):
        self._lock = lock
        self._bounds = tuple(bounds) if bounds is not None else DEFAULT_BOUNDS
        if list(self._bounds) != sorted(self._bounds):
            raise ValueError("histogram bounds must be sorted ascending")
        self._counts = [0] * (len(self._bounds) + 1)  # +1 overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self.name = name
        self.labels = labels

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    def snapshot(self) -> HistogramSnapshot:
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> HistogramSnapshot:
        return HistogramSnapshot(
            count=self._count,
            sum=self._sum,
            min=self._min if self._count else 0.0,
            max=self._max if self._count else 0.0,
            bounds=self._bounds,
            counts=tuple(self._counts),
        )


@dataclasses.dataclass(frozen=True)
class RegistrySnapshot:
    """One atomic sample of every instrument in a registry.

    ``values`` maps ``(name, labels_tuple)`` to an int/float (counter,
    gauge) or a :class:`HistogramSnapshot`; ``types`` maps metric name to
    ``"counter" | "gauge" | "histogram"``; ``helps`` carries the help
    strings for the exporters.
    """

    values: dict
    types: dict
    helps: dict

    def value(self, name: str, labels: Optional[dict] = None, default=0):
        """The sampled value of one instrument (``default`` if absent)."""
        return self.values.get((name, _label_key(labels)), default)

    def histogram(
        self, name: str, labels: Optional[dict] = None
    ) -> Optional[HistogramSnapshot]:
        v = self.values.get((name, _label_key(labels)))
        return v if isinstance(v, HistogramSnapshot) else None

    def labels_of(self, name: str) -> list:
        """Every label set sampled under ``name`` (list of dicts)."""
        return [
            dict(lk) for (n, lk) in self.values.keys() if n == name
        ]

    def as_dict(self) -> dict:
        """JSON-able view: ``{name: value}`` or ``{name: {label-repr: value}}``."""
        out: dict = {}
        for (name, lk), v in sorted(self.values.items()):
            payload = v.as_dict() if isinstance(v, HistogramSnapshot) else v
            if not lk:
                out[name] = payload
            else:
                key = ",".join(f"{k}={val}" for k, val in lk)
                out.setdefault(name, {})[key] = payload
        return out


class MetricsRegistry:
    """Get-or-create instrument registry with one-lock-consistent snapshots."""

    def __init__(self):
        # RLock: Histogram.snapshot() may be called both standalone and
        # from within registry.snapshot()'s locked section.
        self._lock = threading.RLock()
        self._instruments: dict = {}  # (name, labels_key) -> instrument
        self._types: dict = {}  # name -> "counter"|"gauge"|"histogram"
        self._helps: dict = {}  # name -> help string

    def _get(self, cls, kind: str, name: str, labels, help, **kwargs):
        lk = _label_key(labels)
        with self._lock:
            existing = self._types.get(name)
            if existing is not None and existing != kind:
                raise ValueError(
                    f"metric {name!r} already registered as {existing}, "
                    f"requested {kind}"
                )
            inst = self._instruments.get((name, lk))
            if inst is None:
                inst = cls(self._lock, name, lk, **kwargs)
                self._instruments[(name, lk)] = inst
                self._types[name] = kind
                if help:
                    self._helps[name] = help
            return inst

    def counter(
        self, name: str, labels: Optional[dict] = None, help: Optional[str] = None
    ) -> Counter:
        return self._get(Counter, "counter", name, labels, help)

    def gauge(
        self, name: str, labels: Optional[dict] = None, help: Optional[str] = None
    ) -> Gauge:
        return self._get(Gauge, "gauge", name, labels, help)

    def histogram(
        self,
        name: str,
        labels: Optional[dict] = None,
        help: Optional[str] = None,
        bounds: Optional[tuple] = None,
    ) -> Histogram:
        return self._get(Histogram, "histogram", name, labels, help, bounds=bounds)

    def snapshot(self) -> RegistrySnapshot:
        """All instruments at one instant: a single lock acquisition."""
        with self._lock:
            values = {}
            for key, inst in self._instruments.items():
                if isinstance(inst, Histogram):
                    values[key] = inst._snapshot_locked()
                else:
                    values[key] = inst._value
            return RegistrySnapshot(
                values=values, types=dict(self._types), helps=dict(self._helps)
            )


__all__ = [
    "Counter",
    "DEFAULT_BOUNDS",
    "Gauge",
    "Histogram",
    "HistogramSnapshot",
    "MetricsRegistry",
    "RegistrySnapshot",
]
