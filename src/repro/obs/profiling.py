"""Device-cost accounting — a jaxpr-walking collective/kernel accountant.

Two complementary sources, combined per compiled executor:

* **jaxpr walk** (:func:`collective_profile`): trace the executor with
  ``jax.make_jaxpr`` and count every collective primitive (``all_to_all``,
  ``psum`` …) anywhere in the nested jaxpr, summing the output aval bytes
  of each — the bytes one device moves through that collective.  This is
  exact program structure, independent of the backend: it is how the CI
  gate *independently re-confirms* the fused routing budget (exactly two
  all-to-alls per query/retrieve at every delta depth).
* **XLA cost analysis** (via the :func:`~repro.utils.compat.
  compiled_cost_analysis` shim): FLOPs and bytes-accessed estimates from
  the compiled executable, giving a FLOP/byte arithmetic-intensity figure
  per executor.

``warm_server`` runs :func:`profile_executor` once per distinct program
structure in the AOT grid and stores the resulting
:class:`ExecutorCost` rows on the :class:`~repro.serve_table.aot.
ExecutorGrid`, where ``server.metrics()`` and the benches surface them.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.core as jcore

from repro.utils.compat import compiled_cost_analysis

# Cross-device data movement primitives to account for.  ``psum`` covers
# the replicated reductions (join_size, live counts); the all_to_alls are
# the routing rounds the paper's scalability argument rests on.
COLLECTIVE_PRIMITIVES = (
    "all_to_all",
    "all_gather",
    "psum",
    "ppermute",
    "reduce_scatter",
)


def _iter_jaxprs(v):
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _iter_jaxprs(x)


def _aval_bytes(var) -> int:
    aval = var.aval
    size = getattr(aval, "size", None)
    dtype = getattr(aval, "dtype", None)
    if size is None or dtype is None:
        return 0
    return int(size) * dtype.itemsize


def _walk(jaxpr, counts: dict, bytes_: dict) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in counts:
            counts[name] += 1
            bytes_[name] += sum(_aval_bytes(v) for v in eqn.outvars)
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                _walk(sub, counts, bytes_)


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in a (nested) jaxpr."""
    counts = {name: 0}
    bytes_ = {name: 0}
    _walk(jaxpr, counts, bytes_)
    return counts[name]


@dataclasses.dataclass(frozen=True)
class ExecutorCost:
    """Static device-cost profile of one compiled executor.

    Collective counts/bytes come from the jaxpr walk (bytes are per-device
    output payload of each collective, summed over occurrences); ``flops``
    and ``bytes_accessed`` come from XLA's cost analysis of the compiled
    executable (0.0 when the backend doesn't report them).
    """

    kind: str  # "query" | "retrieve" | ...
    bucket: int  # query batch size the executor was lowered for
    depth: int  # delta depth of the state structure
    collective_counts: dict  # primitive name -> occurrence count
    collective_bytes: dict  # primitive name -> summed output bytes
    flops: float = 0.0
    bytes_accessed: float = 0.0

    @property
    def all_to_alls(self) -> int:
        return self.collective_counts.get("all_to_all", 0)

    @property
    def all_to_all_bytes(self) -> int:
        return self.collective_bytes.get("all_to_all", 0)

    @property
    def total_collective_bytes(self) -> int:
        return sum(self.collective_bytes.values())

    @property
    def flop_per_byte(self) -> float:
        return self.flops / self.bytes_accessed if self.bytes_accessed else 0.0

    def as_dict(self) -> dict:
        return {
            "kind": self.kind,
            "bucket": self.bucket,
            "depth": self.depth,
            "all_to_alls": self.all_to_alls,
            "all_to_all_bytes": self.all_to_all_bytes,
            "collective_counts": dict(self.collective_counts),
            "collective_bytes": dict(self.collective_bytes),
            "total_collective_bytes": self.total_collective_bytes,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "flop_per_byte": self.flop_per_byte,
        }


def collective_profile(fn, *args) -> tuple:
    """``(counts, bytes)`` dicts for every collective in ``fn(*args)``.

    Traces with ``jax.make_jaxpr`` (abstract — no device execution) and
    walks the nested jaxpr.  Only primitives with nonzero occurrence are
    kept, so the dicts double as a compact "which collectives does this
    program use" fingerprint.
    """
    jx = jax.make_jaxpr(fn)(*args)
    counts = {p: 0 for p in COLLECTIVE_PRIMITIVES}
    bytes_ = {p: 0 for p in COLLECTIVE_PRIMITIVES}
    _walk(jx.jaxpr, counts, bytes_)
    counts = {k: v for k, v in counts.items() if v}
    bytes_ = {k: v for k, v in bytes_.items() if v}
    return counts, bytes_


def profile_executor(
    table,
    state,
    queries,
    *,
    kind: str,
    compiled=None,
    exec_kwargs: Optional[dict] = None,
) -> ExecutorCost:
    """Profile one executor structure: jaxpr walk + XLA cost analysis.

    ``kind`` selects the executor (``"query"`` / ``"retrieve"``);
    ``exec_kwargs`` carries its static capacities.  ``compiled`` (a
    ``jax.stages.Compiled``, e.g. out of the AOT grid) supplies the
    FLOP/bytes-accessed estimates when given.
    """
    from repro.core import plans

    kw = dict(exec_kwargs or {})
    if kind == "query":
        fn = lambda s, q: plans.exec_query(table, s, q, **kw)
    elif kind == "retrieve":
        fn = lambda s, q: plans.exec_retrieve(table, s, q, **kw)
    else:
        raise ValueError(f"unknown executor kind {kind!r}")
    counts, bytes_ = collective_profile(fn, state, queries)
    flops = 0.0
    bytes_accessed = 0.0
    if compiled is not None:
        try:
            cost = compiled_cost_analysis(compiled)
        except Exception:  # backend without cost analysis
            cost = {}
        flops = float(cost.get("flops", 0.0) or 0.0)
        bytes_accessed = float(cost.get("bytes accessed", 0.0) or 0.0)
    return ExecutorCost(
        kind=kind,
        bucket=int(queries.shape[0]),
        depth=max(0, len(state.deltas)),
        collective_counts=counts,
        collective_bytes=bytes_,
        flops=flops,
        bytes_accessed=bytes_accessed,
    )


__all__ = [
    "COLLECTIVE_PRIMITIVES",
    "ExecutorCost",
    "collective_profile",
    "count_primitive",
    "profile_executor",
]
