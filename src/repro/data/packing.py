"""Greedy sequence packing: variable-length documents → fixed-length rows.

Pure-jnp, shape-static: documents come as a (num_docs, max_doc_len) padded
matrix plus lengths; the packer lays docs head-to-tail into rows of
``seq_len`` and emits a segment-id mask so attention can stay per-document
(segment ids are consumed by the train step as an attention mask when
``pack_attention=True``; the default trainer treats rows as contiguous
streams, the common LM pretraining setup).
"""
from __future__ import annotations

import numpy as np


def pack_documents(
    docs: np.ndarray,  # (D, L) int32, padded with pad_id
    lengths: np.ndarray,  # (D,) int32
    seq_len: int,
    pad_id: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Host-side packing (data pipeline runs on CPU workers in production).

    Returns ``(rows, segment_ids)`` of shape (R, seq_len): token rows and
    1-based per-document segment ids (0 = padding).
    """
    rows, segs = [], []
    cur = np.full((seq_len,), pad_id, np.int32)
    cur_seg = np.zeros((seq_len,), np.int32)
    fill, seg = 0, 0
    for d in range(docs.shape[0]):
        ln = int(lengths[d])
        if ln <= 0:
            continue
        ln = min(ln, seq_len)  # over-long docs are truncated to one row
        if fill + ln > seq_len:
            rows.append(cur)
            segs.append(cur_seg)
            cur = np.full((seq_len,), pad_id, np.int32)
            cur_seg = np.zeros((seq_len,), np.int32)
            fill, seg = 0, 0
        seg += 1
        cur[fill : fill + ln] = docs[d, :ln]
        cur_seg[fill : fill + ln] = seg
        fill += ln
    if fill:
        rows.append(cur)
        segs.append(cur_seg)
    if not rows:
        return (
            np.zeros((0, seq_len), np.int32),
            np.zeros((0, seq_len), np.int32),
        )
    return np.stack(rows), np.stack(segs)


def packing_efficiency(segment_ids: np.ndarray) -> float:
    """Fraction of non-padding tokens in packed rows."""
    if segment_ids.size == 0:
        return 0.0
    return float((segment_ids > 0).mean())
