"""Deterministic synthetic token corpus.

Batches are a pure function of ``(seed, step)`` — the loader can resume at
any step with zero replayed state, which is what makes checkpoint/restart
and elastic re-sharding exact (the trainer stores only the step counter).

The stream is a Zipf-ish mixture over the vocab with injected duplicate
documents (rate ``dup_rate``) so the HashGraph dedup stage has real work,
mirroring the paper's duplicate-keys experiments at the data layer.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    vocab_size: int
    seq_len: int
    seed: int = 0
    dup_rate: float = 0.0  # fraction of documents that clone another doc
    zipf_alpha: float = 1.1

    def _doc_key(self, step: int):
        return jax.random.fold_in(jax.random.key(self.seed), step)

    def batch(self, step: int, batch_size: int) -> jax.Array:
        """(batch, seq_len+1) int32 tokens for ``step`` (labels = shift-by-1)."""
        key = self._doc_key(step)
        ku, kd, kc = jax.random.split(key, 3)
        # Zipf-like marginal: transform uniforms through a power law.
        u = jax.random.uniform(ku, (batch_size, self.seq_len + 1), minval=1e-6)
        ranks = jnp.power(u, -1.0 / self.zipf_alpha)
        toks = jnp.clip(ranks.astype(jnp.int32) % self.vocab_size, 0, self.vocab_size - 1)
        if self.dup_rate > 0.0:
            # clone row j into row i for a dup_rate fraction of rows
            src = jax.random.randint(kd, (batch_size,), 0, batch_size)
            is_dup = jax.random.uniform(kc, (batch_size,)) < self.dup_rate
            toks = jnp.where(is_dup[:, None], toks[src], toks)
        return toks
