"""Exact sequence dedup through the paper's hash table.

Every training row is fingerprinted with the streaming murmur3 (the same
hash the paper uses) and the fingerprints are fed to a HashGraph:

* single-device: build once, ``query_count_sorted`` gives multiplicities —
  a row is a duplicate iff an *earlier* row has the same fingerprint.
* distributed: the multi-GPU build (Alg. 2) runs over the mesh via
  ``DistributedHashTable``; the duplicate mask comes back with one extra
  query pass.  This is the hash table doing production work inside the
  training data pipeline — exactly the k-mer/join-style use the paper
  motivates.

Fingerprint collisions: 32-bit fingerprints collide at ~N²/2³² — for the
per-batch dedup window (N ≤ a few thousand) that's < 1e-5 per batch; the
stream variant folds the row index of first occurrence through ``values``
so exactness can be audited downstream.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import hashing, hashgraph
from repro.core.table import DistributedHashTable


def sequence_fingerprints(tokens: jax.Array, seed: int = hashing.DEFAULT_SEED) -> jax.Array:
    """murmur3 stream hash of each row.  tokens (B, S) int32 → (B,) uint32."""
    return hashing.murmur3_stream(tokens.astype(jnp.uint32), seed=seed)


def dedup_mask(tokens: jax.Array, seed: int = hashing.DEFAULT_SEED) -> jax.Array:
    """(B,) bool — True for rows to KEEP (first occurrence of each content).

    Single-device HashGraph: build over fingerprints with the row index as
    the payload; a row survives iff the smallest row index among equal
    fingerprints is its own (deterministic, order-stable).
    """
    fp = sequence_fingerprints(tokens, seed=seed)
    n = fp.shape[0]
    hg = hashgraph.build(fp, table_size=max(8, n), seed=seed)
    first = _min_value_per_key(hg, fp)
    return first == jnp.arange(n, dtype=jnp.int32)


def _narrow_by_fingerprint(hg, starts, ends, q):
    """Confine a bucket window to the query's fingerprint run.

    Fingerprint-laned tables sort buckets by (fingerprint, key), so the
    direct key bisection below is only valid inside the run of rows whose
    fingerprint matches.  No-op for plain tables (dedup's default: 1-lane
    fingerprint keys carry no probe lane).
    """
    if hg.fingerprints is None:
        return starts, ends
    qfp = hashing.fingerprint32(q)
    fl = hashgraph._segment_searchsorted(hg.fingerprints, starts, ends, qfp, side="left")
    fr = hashgraph._segment_searchsorted(hg.fingerprints, fl, ends, qfp, side="right")
    return fl, fr


def _min_value_per_key(hg: hashgraph.HashGraph, queries: jax.Array) -> jax.Array:
    """Smallest stored value among table keys equal to each query."""
    q = queries.astype(jnp.uint32)
    b = hg.bucket_of(q)
    starts = hg.offsets[b]
    ends = hg.offsets[b + 1]
    starts, ends = _narrow_by_fingerprint(hg, starts, ends, q)
    left = hashgraph._segment_searchsorted(hg.keys, starts, ends, q, side="left")
    right = hashgraph._segment_searchsorted(hg.keys, starts, ends, q, side="right")
    # keys equal to q occupy [left, right); values are not sorted within the
    # run, so scan a static window (duplicate runs in a dedup table are the
    # multiplicity of one batch row's content — bounded by batch size).
    max_run = min(64, hg.keys.shape[0])
    idx = left[:, None] + jnp.arange(max_run, dtype=jnp.int32)[None, :]
    in_run = idx < right[:, None]
    vals = hg.values[jnp.clip(idx, 0, hg.keys.shape[0] - 1)]
    vals = jnp.where(in_run, vals, jnp.iinfo(jnp.int32).max)
    return jnp.min(vals, axis=1)


def dedup_mask_distributed(
    table: DistributedHashTable,
    tokens: jax.Array,
    seed: Optional[int] = None,
) -> jax.Array:
    """Distributed exact dedup over a mesh-sharded (B, S) token batch.

    Builds the multi-device HashGraph (Alg. 2) from row fingerprints with
    global row ids as values, then queries ``lookup_first`` semantics via
    multiplicity + min-rowid reduction.  Returns a global (B,) keep-mask.
    """
    fp = sequence_fingerprints(tokens, seed=seed or table.seed)
    state = table.build(fp, values=jnp.arange(fp.shape[0], dtype=jnp.int32))
    counts = table.query(state, fp)
    # multiplicity == 1 → trivially keep; for duplicated content keep the
    # first global row.  The min-rowid pass reuses the query routing.
    firsts = _distributed_first_rowid(table, state, fp)
    return (counts <= 1) | (firsts == jnp.arange(fp.shape[0], dtype=jnp.int32))


def _distributed_first_rowid(table, state, fp):
    """Min stored value among matches, computed shard-side."""
    from jax.sharding import PartitionSpec as P
    from repro.utils.compat import shard_map
    from repro.core import multi_hashgraph

    def body(dhg, q):
        return _min_value_sharded(dhg, q)

    in_specs = (
        _state_specs(table),
        P(table.axis_names),
    )
    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=in_specs,
        out_specs=P(table.axis_names),
        check_vma=False,
    )(state, fp)


def _state_specs(table):
    from repro.core.table import _dhg_out_specs

    return _dhg_out_specs(
        table.axis_names,
        table.hash_range,
        table.local_range_cap,
        table.seed,
        fingerprint=table.use_fingerprint,
    )


def _min_value_sharded(dhg, queries):
    """Route queries to owning shards, min-reduce matching values, route back."""
    from repro.core import exchange, hashing as hmod, multi_hashgraph, partition

    queries = queries.astype(jnp.uint32)
    axis_names = dhg.axis_names
    num_devices = exchange.device_count(axis_names)
    h = hmod.hash_to_buckets(queries, dhg.hash_range, seed=dhg.seed)
    dest = partition.destination_of(h, dhg.hash_splits)
    capacity = multi_hashgraph.default_capacity(queries.shape[0], num_devices, 1.25)
    (rq,), route = exchange.dispatch(
        (queries,), dest, axis_names, capacity, fills=(jnp.uint32(hashgraph.EMPTY_KEY),)
    )
    rank = exchange.my_rank(axis_names)
    lo = dhg.hash_splits[rank]
    rbuckets = multi_hashgraph._local_buckets(
        rq, lo, dhg.hash_range, dhg.local_range_cap, dhg.seed
    )
    hg = dhg.local
    starts = hg.offsets[rbuckets]
    ends = hg.offsets[rbuckets + 1]
    starts, ends = _narrow_by_fingerprint(hg, starts, ends, rq)
    left = hashgraph._segment_searchsorted(hg.keys, starts, ends, rq, side="left")
    right = hashgraph._segment_searchsorted(hg.keys, starts, ends, rq, side="right")
    max_run = min(64, hg.keys.shape[0])
    idx = left[:, None] + jnp.arange(max_run, dtype=jnp.int32)[None, :]
    in_run = idx < right[:, None]
    vals = hg.values[jnp.clip(idx, 0, hg.keys.shape[0] - 1)]
    vals = jnp.where(in_run, vals, jnp.iinfo(jnp.int32).max)
    ans = jnp.min(vals, axis=1)
    return exchange.combine(ans, route, axis_names, fill=jnp.int32(jnp.iinfo(jnp.int32).max))
