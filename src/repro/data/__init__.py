"""Data substrate: synthetic corpus, packing, HashGraph dedup, loader."""
from repro.data.synthetic import SyntheticCorpus
from repro.data.packing import pack_documents
from repro.data.dedup import sequence_fingerprints, dedup_mask, dedup_mask_distributed
from repro.data.loader import ShardedLoader, LoaderState

__all__ = [
    "SyntheticCorpus",
    "pack_documents",
    "sequence_fingerprints",
    "dedup_mask",
    "dedup_mask_distributed",
    "ShardedLoader",
    "LoaderState",
]
