"""Sharded deterministic loader with O(1) skip/resume.

``ShardedLoader`` materializes the global batch for a step and places it on
the mesh with the dp-sharded layout (``jax.device_put`` with a
``NamedSharding``).  Because :class:`SyntheticCorpus` batches are pure
functions of ``(seed, step)``, resume-from-checkpoint is just "set the step
counter" — no iterator state, no replay, and elastic re-sharding to a new
mesh needs nothing from the data side.

Optionally applies HashGraph dedup per batch (``dedup="local"`` /
``"distributed"``): duplicate rows are *re-sampled* from a fold-in of the
step key rather than dropped, keeping the batch shape static.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.synthetic import SyntheticCorpus
from repro.data import dedup as dedup_mod


@dataclasses.dataclass
class LoaderState:
    step: int

    def checkpoint_payload(self) -> dict:
        return {"step": self.step}

    @staticmethod
    def restore(payload: dict) -> "LoaderState":
        return LoaderState(step=int(payload["step"]))


@dataclasses.dataclass
class ShardedLoader:
    corpus: SyntheticCorpus
    batch_size: int
    mesh: Optional[jax.sharding.Mesh] = None
    dp_axes: tuple = ("data",)
    dedup: Optional[str] = None  # None | "local" | "distributed"
    dedup_table: Optional[object] = None  # DistributedHashTable for "distributed"

    def __post_init__(self):
        self.state = LoaderState(step=0)

    def _sharding(self):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P(self.dp_axes, None))

    def next_batch(self) -> dict:
        step = self.state.step
        toks = self.corpus.batch(step, self.batch_size)
        if self.dedup is not None:
            toks = self._dedup(toks, step)
        self.state.step += 1
        sh = self._sharding()
        if sh is not None:
            toks = jax.device_put(toks, sh)
        return {"tokens": toks}

    def _dedup(self, toks: jax.Array, step: int) -> jax.Array:
        if self.dedup == "distributed" and self.dedup_table is not None:
            keep = dedup_mod.dedup_mask_distributed(self.dedup_table, toks[:, :-1])
        else:
            keep = dedup_mod.dedup_mask(toks[:, :-1])
        # re-sample dropped rows deterministically so shapes stay static
        key = jax.random.fold_in(jax.random.key(self.corpus.seed ^ 0x5EED), step)
        fresh = jax.random.randint(
            key, toks.shape, 0, self.corpus.vocab_size, dtype=jnp.int32
        )
        return jnp.where(keep[:, None], toks, fresh)

    # -- resume ----------------------------------------------------------------
    def skip_to(self, step: int) -> None:
        """O(1) resume: batches are pure functions of the step index."""
        self.state.step = step
