"""Shared neural-net building blocks (pure JAX, no framework deps)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    """He/Glorot-style init used across the stack."""
    stddev = scale / math.sqrt(shape[0] if len(shape) > 1 else 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    return truncated_normal_init(key, (d_in, d_out), 1.0, dtype)


def rmsnorm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm in f32 with cast back to input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: silu(x·Wg) * (x·Wu) · Wd — the LM-family FFN."""
    dtype = x.dtype
    g = jnp.dot(x, w_gate.astype(dtype))
    u = jnp.dot(x, w_up.astype(dtype))
    return jnp.dot(jax.nn.silu(g) * u, w_down.astype(dtype))


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in, w_out: jax.Array, b_out) -> jax.Array:
    """GELU MLP (whisper-style, with biases)."""
    dtype = x.dtype
    h = jnp.dot(x, w_in.astype(dtype)) + b_in.astype(dtype)
    h = jax.nn.gelu(h)
    return jnp.dot(h, w_out.astype(dtype)) + b_out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Rotate pairs of channels. ``x``: (..., S, head_dim); positions (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_at(pos: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal embedding of arbitrary integer positions. pos (...,) → (..., d)."""
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    angle = pos.astype(jnp.float32)[..., None] / jnp.power(10000.0, dim / d_model)
    out = jnp.zeros(pos.shape + (d_model,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(angle))
    out = out.at[..., 1::2].set(jnp.cos(angle))
    return out


def sinusoidal_positions(length: int, d_model: int) -> jax.Array:
    """Fixed sin/cos table (whisper encoder)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((length, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def softmax_cross_entropy_logits(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None
) -> jax.Array:
    """Mean next-token CE in f32. logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
