"""Grouped-query attention with KV cache, SWA/local windows and qk_norm.

Two implementations behind ``cfg.attention_impl``:

* ``xla`` — grouped einsum with online masks; GSPMD-partitioned. Default on
  CPU (smoke tests, dry-run lowering).
* ``flash_pallas`` — the Pallas flash kernel (TPU target; interpret-mode on
  CPU).  Selected for real-TPU runs.

The KV cache layout is ``(B, KV_heads, S_max, head_dim)``; decode writes one
token at ``cache_pos`` with ``dynamic_update_slice`` (the serve layer shards
B over the dp axes and KV/S over ``model`` — see repro/serve/kvcache.py).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers

_NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, KV, S_max, hd)
    v: jax.Array  # (B, KV, S_max, hd)


class RingKVCache(NamedTuple):
    """Fixed-window ring buffer for SWA/local-attention decode.

    Keeps the cache O(window) instead of O(seq_len) — this is what makes
    long_500k decode sub-quadratic for the hybrid archs and shrinks
    mixtral's decode_32k cache 8×.
    """

    k: jax.Array  # (B, KV, W, hd)
    v: jax.Array  # (B, KV, W, hd)
    kpos: jax.Array  # (B, W) int32 absolute positions, -1 = empty


def init_attention(key, cfg: ArchConfig, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    hd = cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(k1, d, cfg.num_heads * hd),
        "wk": layers.dense_init(k2, d, cfg.num_kv_heads * hd),
        "wv": layers.dense_init(k3, d, cfg.num_kv_heads * hd),
        "wo": layers.dense_init(k4, cfg.num_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(hd)
        p["k_norm"] = layers.rmsnorm_init(hd)
    return p


def _project_qkv(params, x, cfg: ArchConfig, positions):
    """x (B,S,d) → q (B,KV,G,S,hd), k/v (B,KV,S,hd) with rope + qk_norm."""
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kv = cfg.num_kv_heads
    g = cfg.q_per_kv
    dtype = x.dtype
    q = jnp.dot(x, params["wq"].astype(dtype)).reshape(b, s, kv, g, hd)
    k = jnp.dot(x, params["wk"].astype(dtype)).reshape(b, s, kv, hd)
    v = jnp.dot(x, params["wv"].astype(dtype)).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = layers.rmsnorm(q, params["q_norm"])
        k = layers.rmsnorm(k, params["k_norm"])
    if positions is not None:  # rope (None for whisper-style abs pos)
        q = layers.apply_rope(q, positions[:, :, None, None], cfg.rope_theta)
        k = layers.apply_rope(k, positions[:, :, None], cfg.rope_theta)
    q = q.transpose(0, 2, 3, 1, 4)  # (B, KV, G, S, hd)
    k = k.transpose(0, 2, 1, 3)  # (B, KV, S, hd)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v


def _masked_attention(q, k, v, *, causal, window, q_offset, kv_len_mask=None):
    """Grouped einsum attention.  q (B,KV,G,Sq,hd), k/v (B,KV,Skv,hd).

    ``q_offset``: absolute position of q row 0 minus kv row 0 (decode offset).
    ``kv_len_mask``: optional (B, Skv) bool — live cache entries.

    The ``flash_fusable`` named scope marks the q·kᵀ→softmax→·v region the
    Pallas flash kernel (kernels/flash_attention.py) keeps in VMEM: the
    roofline's HBM model (analysis/hlo_cost.py) treats the scope as one
    fused kernel — S² score tensors never touch HBM on the TPU target.
    """
    *_, sq, hd = q.shape
    skv = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("flash_fusable"):
        s = jnp.einsum(
            "bkgsd,bktd->bkgst", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
        )
        q_pos = q_offset + jnp.arange(sq)[:, None]
        k_pos = jnp.arange(skv)[None, :]
        mask = jnp.ones((sq, skv), bool)
        if causal:
            mask &= k_pos <= q_pos
            if window is not None:
                mask &= k_pos > q_pos - window
        elif window is not None:
            mask &= jnp.abs(k_pos - q_pos) < window
        m = mask[None, None, None]
        if kv_len_mask is not None:
            m = m & kv_len_mask[:, None, None, None, :]
        s = jnp.where(m, s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def _flash_attention(q, k, v, *, causal, window):
    """Pallas flash kernel path (TPU target). q (B,KV,G,S,hd)."""
    from repro.kernels import ops as kops

    b, kvh, g, s, hd = q.shape
    qf = q.reshape(b, kvh * g, s, hd)
    out = kops.flash_attention(qf, k, v, causal=causal, window=window)
    return out.reshape(b, kvh, g, s, hd)


def attention(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    positions: Optional[jax.Array],
    *,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jax.Array] = None,
    return_cache: bool = False,
    cache_len: Optional[int] = None,
) -> tuple[jax.Array, Optional[KVCache]]:
    """Self-attention over ``x`` (B, S, d).

    Modes:
      * train:            cache=None, return_cache=False
      * prefill:          cache=None, return_cache=True (cache_len sizes it)
      * decode (S == 1):  cache=KVCache, cache_pos = absolute position (B,)
    """
    b, s, _ = x.shape
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    if cache is not None:
        # decode: write the new token at cache_pos, attend over the cache.
        k_cache, v_cache = cache
        pos = cache_pos.reshape(b)  # (B,)

        def upd(c, new):
            return jax.vmap(
                lambda cb, nb, pb: jax.lax.dynamic_update_slice(
                    cb, nb, (0, pb, 0)
                )
            )(c, new, pos)

        k_all = upd(k_cache, k_new)
        v_all = upd(v_cache, v_new)
        kv_len_mask = (
            jnp.arange(k_all.shape[2])[None, :] <= pos[:, None]
        )  # (B, S_max)
        # window masking happens relative to absolute positions:
        out = _masked_attention_decode(
            q, k_all, v_all, pos, window=window, kv_len_mask=kv_len_mask
        )
        new_cache = KVCache(k_all, v_all)
    else:
        if cfg.attention_impl == "flash_pallas" and s > 1:
            out = _flash_attention(q, k_new, v_new, causal=causal, window=window)
        else:
            out = _masked_attention(
                q, k_new, v_new, causal=causal, window=window, q_offset=0
            )
        new_cache = None
        if return_cache:
            smax = cache_len or s
            pad = smax - s
            k_c = jnp.pad(k_new, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v_c = jnp.pad(v_new, ((0, 0), (0, 0), (0, pad), (0, 0)))
            new_cache = KVCache(k_c, v_c)

    b_, kv, g, s_, hd = out.shape
    merged = out.transpose(0, 3, 1, 2, 4).reshape(b, s, kv * g * hd)
    return jnp.dot(merged, params["wo"].astype(x.dtype)), new_cache


def _masked_attention_decode(q, k, v, pos, *, window, kv_len_mask):
    """Decode attention: q (B,KV,G,1,hd) vs full cache (B,KV,Smax,hd).

    ``flash_fusable``: the flash-decode kernel streams the cache once and
    keeps scores in VMEM (see _masked_attention docstring).
    """
    hd = q.shape[-1]
    skv = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("flash_fusable"):
        s = jnp.einsum(
            "bkgsd,bktd->bkgst", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
        )
        k_pos = jnp.arange(skv)[None, :]
        m = kv_len_mask  # (B, Smax): k_pos <= pos
        if window is not None:
            m = m & (k_pos > pos[:, None] - window)
        s = jnp.where(m[:, None, None, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgst,bktd->bkgsd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ring_prefill_cache(
    k: jax.Array, v: jax.Array, seq_len: int, window: int
) -> RingKVCache:
    """Build a ring cache from full prefill k/v (B, KV, S, hd)."""
    b = k.shape[0]
    w = window
    if seq_len >= w:
        pos = jnp.arange(seq_len - w, seq_len, dtype=jnp.int32)
        slots = pos % w
        rk = jnp.zeros(k.shape[:2] + (w,) + k.shape[3:], k.dtype)
        rv = jnp.zeros_like(rk)
        rk = rk.at[:, :, slots].set(k[:, :, -w:])
        rv = rv.at[:, :, slots].set(v[:, :, -w:])
        kpos = jnp.full((b, w), -1, jnp.int32).at[:, slots].set(pos[None, :])
    else:
        pad = w - seq_len
        rk = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        rv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kpos = jnp.concatenate(
            [
                jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32), (b, seq_len)),
                jnp.full((b, pad), -1, jnp.int32),
            ],
            axis=1,
        )
    return RingKVCache(rk, rv, kpos)


def ring_decode_attention(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    cache: RingKVCache,
    pos: jax.Array,
    window: int,
) -> tuple[jax.Array, RingKVCache]:
    """One-token decode against a ring cache.  x (B,1,d), pos (B,)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg, pos.reshape(b, 1))
    slot = (pos % window).astype(jnp.int32)

    def upd(c, new):
        return jax.vmap(
            lambda cb, nb, sb: jax.lax.dynamic_update_slice(cb, nb, (0, sb, 0))
        )(c, new, slot)

    k_all = upd(cache.k, k_new)
    v_all = upd(cache.v, v_new)
    kpos = jax.vmap(lambda kp, sb, pb: jax.lax.dynamic_update_slice(kp, pb[None], (sb,)))(
        cache.kpos, slot, pos.astype(jnp.int32)
    )
    valid = (kpos >= 0) & (kpos <= pos[:, None]) & (kpos > pos[:, None] - window)
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    with jax.named_scope("flash_fusable"):
        s = jnp.einsum(
            "bkgsd,bktd->bkgst",
            q.astype(jnp.float32) * scale,
            k_all.astype(jnp.float32),
        )
        s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgst,bktd->bkgsd", p, v_all.astype(jnp.float32)).astype(
            x.dtype
        )
    kv, g = out.shape[1], out.shape[2]
    merged = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, kv * g * hd)
    proj = jnp.dot(merged, params["wo"].astype(x.dtype))
    return proj, RingKVCache(k_all, v_all, kpos)


def cross_attention(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    enc_k: jax.Array,
    enc_v: jax.Array,
) -> jax.Array:
    """Cross-attention (whisper decoder): kv precomputed from the encoder.

    ``enc_k``/``enc_v``: (B, KV, T_enc, hd).
    """
    b, s, _ = x.shape
    hd = cfg.head_dim_
    kv = cfg.num_kv_heads
    g = cfg.q_per_kv
    dtype = x.dtype
    q = (
        jnp.dot(x, params["wq"].astype(dtype))
        .reshape(b, s, kv, g, hd)
        .transpose(0, 2, 3, 1, 4)
    )
    out = _masked_attention(q, enc_k, enc_v, causal=False, window=None, q_offset=0)
    merged = out.transpose(0, 3, 1, 2, 4).reshape(b, s, kv * g * hd)
    return jnp.dot(merged, params["wo"].astype(dtype))


def encoder_kv(params: dict, enc_out: jax.Array, cfg: ArchConfig):
    """Precompute cross-attention k/v from encoder output (B, T, d)."""
    b, t, _ = enc_out.shape
    hd = cfg.head_dim_
    kv = cfg.num_kv_heads
    dtype = enc_out.dtype
    k = (
        jnp.dot(enc_out, params["wk"].astype(dtype))
        .reshape(b, t, kv, hd)
        .transpose(0, 2, 1, 3)
    )
    v = (
        jnp.dot(enc_out, params["wv"].astype(dtype))
        .reshape(b, t, kv, hd)
        .transpose(0, 2, 1, 3)
    )
    return k, v
