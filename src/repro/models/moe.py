"""Mixture-of-Experts layer (grok-1 / mixtral: 8 experts, top-2).

Two dispatch implementations:

* ``dense`` — every expert computed for every token, combined with the
  (sparse) router weights.  Exact reference; used on one device and as the
  oracle the EP path is tested against.
* ``ep``    — expert parallelism through the **paper's technique**: tokens
  are binned by expert and exchanged with the capacity-padded hierarchical
  all-to-all of ``repro.core.exchange`` (Alg. 2 Phases 2-3, with experts
  playing the role of hash ranges).  Runs inside a partial-manual
  ``shard_map`` over the EP axes; the tensor-parallel axis stays automatic.

Router: softmax over all experts, top-k selection, renormalized weights;
Switch-style load-balance aux loss is returned as a metric.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import exchange
from repro.distributed.parallel import ParallelConfig
from repro.models import layers
from repro.utils import cdiv
from repro.utils.compat import shard_map


def init_moe(key, cfg: ArchConfig, d_in: Optional[int] = None) -> dict:
    d = d_in or cfg.d_model
    e, f = cfg.num_experts, cfg.d_ff
    kr, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": layers.dense_init(kr, d, e),
        "w_gate": jax.vmap(lambda k: layers.dense_init(k, d, f))(
            jax.random.split(k1, e)
        ),
        "w_up": jax.vmap(lambda k: layers.dense_init(k, d, f))(
            jax.random.split(k2, e)
        ),
        "w_down": jax.vmap(lambda k: layers.dense_init(k, f, d))(
            jax.random.split(k3, e)
        ),
    }


def _route(params, x2d: jax.Array, cfg: ArchConfig):
    """Top-k routing. x2d (T, d) → (weights (T,k), ids (T,k), aux_loss)."""
    logits = jnp.dot(x2d.astype(jnp.float32), params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    w, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balance: E * Σ_e (token_frac_e · mean_prob_e)
    e = cfg.num_experts
    onehot = jax.nn.one_hot(ids[:, 0], e)  # primary-expert assignment
    frac = jnp.mean(onehot, axis=0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return w.astype(x2d.dtype), ids.astype(jnp.int32), aux


def _expert_ffn(x, wg, wu, wd):
    dtype = x.dtype
    return jnp.dot(
        jax.nn.silu(jnp.dot(x, wg.astype(dtype))) * jnp.dot(x, wu.astype(dtype)),
        wd.astype(dtype),
    )


# ---------------------------------------------------------------------------
# dense reference
# ---------------------------------------------------------------------------
def moe_dense(params, x: jax.Array, cfg: ArchConfig):
    """All experts for all tokens; exact. x (B,S,d) → (out, aux)."""
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, ids, aux = _route(params, x2, cfg)
    dtype = x.dtype
    # (T, E, f) intermediate — reference path, smoke-scale only.
    g = jnp.einsum("td,edf->tef", x2, params["w_gate"].astype(dtype))
    u = jnp.einsum("td,edf->tef", x2, params["w_up"].astype(dtype))
    o = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, params["w_down"].astype(dtype))
    # combine: sum over the k selected experts
    sel = jnp.take_along_axis(o, ids[:, :, None], axis=1)  # (T, k, d)
    out = jnp.sum(sel * w[:, :, None], axis=1)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# expert-parallel via the paper's exchange
# ---------------------------------------------------------------------------
def _ep_body(params, x_local, cfg: ArchConfig, ep_axes: tuple, capacity: int):
    """shard_map body: x_local (t, d) on each EP device."""
    t, d = x_local.shape
    e = cfg.num_experts
    k = cfg.experts_per_token
    dvs = exchange.device_count(ep_axes)
    rank = exchange.my_rank(ep_axes)

    w, ids, aux = _route(params, x_local, cfg)

    # duplicate each token k times; destination device owns the expert.
    xk = jnp.repeat(x_local, k, axis=0)  # (t*k, d)
    idsk = ids.reshape(-1)  # (t*k,)
    if dvs >= e:
        # one expert per device; groups of E devices; stay in-group.
        group_base = (rank // e) * e
        dest = group_base + idsk
        my_experts = [rank % e]
        n_owned = 1
    else:
        # several experts per device: expert eid lives on device eid % dvs.
        dest = idsk % dvs
        n_owned = e // dvs
        my_experts = None  # dynamic below

    (rx, rids), route = exchange.dispatch(
        (xk, idsk),
        dest,
        ep_axes,
        capacity,
        fills=(jnp.zeros((), x_local.dtype), jnp.int32(-1)),
    )

    # compute owned experts on received tokens
    out = jnp.zeros_like(rx)
    if dvs >= e:
        eid = rank % e
        wg = jax.lax.dynamic_index_in_dim(params["w_gate"], eid, 0, keepdims=False)
        wu = jax.lax.dynamic_index_in_dim(params["w_up"], eid, 0, keepdims=False)
        wd = jax.lax.dynamic_index_in_dim(params["w_down"], eid, 0, keepdims=False)
        mask = (rids == eid)[:, None]
        out = jnp.where(mask, _expert_ffn(rx, wg, wu, wd), 0.0)
    else:
        for j in range(n_owned):
            eid = rank + j * dvs  # experts owned by this device
            wg = jax.lax.dynamic_index_in_dim(params["w_gate"], eid, 0, keepdims=False)
            wu = jax.lax.dynamic_index_in_dim(params["w_up"], eid, 0, keepdims=False)
            wd = jax.lax.dynamic_index_in_dim(params["w_down"], eid, 0, keepdims=False)
            mask = (rids == eid)[:, None]
            out = out + jnp.where(mask, _expert_ffn(rx, wg, wu, wd), 0.0)

    back = exchange.combine(out, route, ep_axes, fill=jnp.zeros((), out.dtype))
    back = back.reshape(t, k, d)
    combined = jnp.sum(back * w[:, :, None].astype(back.dtype), axis=1)
    dropped = jax.lax.psum(route.num_dropped, ep_axes)
    return combined, jax.lax.pmean(aux, ep_axes), dropped


def moe_ep(params, x: jax.Array, cfg: ArchConfig, parallel: ParallelConfig):
    """Expert-parallel MoE. x (B,S,d) global → (out, aux)."""
    ep_axes = parallel.ep_axes_
    dvs = parallel.num_devices(ep_axes)
    b, s, d = x.shape
    t_local = (b * s) // dvs
    capacity = cdiv(t_local * cfg.experts_per_token, cfg.num_experts)
    capacity = int(capacity * cfg.moe_capacity_factor) + 8
    capacity = cdiv(capacity, 8) * 8

    def body(p, xl):
        t_l = xl.shape[0] * xl.shape[1]
        x2 = xl.reshape(t_l, d)
        out, aux, dropped = _ep_body(p, x2, cfg, ep_axes, capacity)
        return out.reshape(xl.shape), aux, dropped

    out, aux, dropped = shard_map(
        body,
        mesh=parallel.mesh,
        in_specs=(P(), P(ep_axes)),
        out_specs=(P(ep_axes), P(), P()),
        axis_names=set(ep_axes),
        check_vma=False,
    )(params, x)
    del dropped  # surfaced via metrics in the trainer when needed
    return out, aux


def moe(params, x: jax.Array, cfg: ArchConfig, parallel: Optional[ParallelConfig]):
    if (
        parallel is not None
        and parallel.moe_impl == "ep"
        and parallel.mesh is not None
        and parallel.num_devices(parallel.ep_axes_) > 1
    ):
        dvs = parallel.num_devices(parallel.ep_axes_)
        e = cfg.num_experts
        if dvs % e == 0 or e % dvs == 0:
            return moe_ep(params, x, cfg, parallel)
    return moe_dense(params, x, cfg)
