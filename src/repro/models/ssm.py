"""xLSTM blocks: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM (matrix LSTM) maintains a per-head matrix state
``C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ`` with read-out
``h_t = (C_t q_t) / max(|n_t·q_t|, 1)``.  We implement the **exact chunkwise
factorization** (GLA-style): within a chunk of Q tokens the contribution is
a decay-weighted causal attention; across chunks only the (dk × dv) state is
carried — so training is parallel over the sequence and the lax.scan is
over S/Q chunk summaries, not S tokens.  Deviation from the paper noted in
DESIGN.md: sigmoid input/forget gates (instead of exp-with-stabilizer),
which keeps the decay ratios in (0,1] and the chunkwise form numerically
stable in bf16.

sLSTM has recurrent state feedback (h_{t-1} enters the gates), which is
inherently sequential — implemented as a lax.scan over time with per-head
block-diagonal recurrent weights, exactly as the paper describes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
class MLSTMState(NamedTuple):
    c: jax.Array  # (B, H, dk, dv)
    n: jax.Array  # (B, H, dk)


def init_mlstm(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_inner = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    dv = d_inner // h
    dk = max(16, dv // 2)
    ks = jax.random.split(key, 8)
    return {
        "norm": layers.rmsnorm_init(d),
        "w_up": layers.dense_init(ks[0], d, d_inner),
        "w_gate": layers.dense_init(ks[1], d, d_inner),
        "wq": layers.dense_init(ks[2], d_inner, h * dk),
        "wk": layers.dense_init(ks[3], d_inner, h * dk),
        "wv": layers.dense_init(ks[4], d_inner, h * dv),
        "w_if": layers.dense_init(ks[5], d_inner, 2 * h),  # input+forget gates
        "out_norm": layers.rmsnorm_init(d_inner),
        "w_down": layers.dense_init(ks[6], d_inner, d),
    }


def mlstm_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    d_inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    dv = d_inner // h
    dk = max(16, dv // 2)
    return h, dk, dv


def _mlstm_chunk(q, k, v, log_f, i_gate, state: MLSTMState):
    """Exact chunkwise mLSTM over one chunk.

    q/k: (B,H,Q,dk), v: (B,H,Q,dv), log_f/i_gate: (B,H,Q).
    Returns (h (B,H,Q,dv), new_state).
    """
    bq = q.shape[2]
    # cumulative decay within the chunk: F_t = Π_{u<=t} f_u
    cum = jnp.cumsum(log_f, axis=-1)  # (B,H,Q) = log F_t
    total = cum[..., -1]
    # inter-chunk: contribution of carried state, decayed to each position.
    decay_to_t = jnp.exp(cum)[..., None]  # (B,H,Q,1)
    h_inter = jnp.einsum("bhqk,bhkv->bhqv", q, state.c) * decay_to_t
    n_inter = jnp.einsum("bhqk,bhk->bhq", q, state.n) * decay_to_t[..., 0]
    # intra-chunk: decay-weighted causal attention.
    # ratio[t,s] = exp(logF_t - logF_s) for s <= t  (in (0,1], stable)
    ratio = jnp.exp(cum[..., :, None] - cum[..., None, :])  # (B,H,Q,Q)
    causal = jnp.tril(jnp.ones((bq, bq), bool))
    gate = jnp.where(causal, ratio * i_gate[..., None, :], 0.0)
    scores = jnp.einsum("bhqk,bhsk->bhqs", q, k) * gate
    h_intra = jnp.einsum("bhqs,bhsv->bhqv", scores, v)
    # normalizer q_t·n_t = Σ_{s<=t} ratio·i_s·(q_t·k_s) — exactly Σ_s scores.
    qn = jnp.sum(scores, axis=-1) + n_inter  # (B,H,Q)
    denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    h = (h_intra + h_inter) / denom
    # state update: C' = F_Q·C + Σ_s (F_Q/F_s) i_s k_s v_sᵀ
    carry_decay = jnp.exp(total)[..., None, None]
    tail = jnp.exp(total[..., None] - cum) * i_gate  # (B,H,Q)
    c_new = state.c * carry_decay + jnp.einsum(
        "bhsk,bhsv->bhkv", k * tail[..., None], v
    )
    n_new = state.n * carry_decay[..., 0] + jnp.sum(k * tail[..., None], axis=2)
    return h, MLSTMState(c_new, n_new)


def mlstm_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    state: Optional[MLSTMState] = None,
    *,
    chunk: int = 256,
    return_state: bool = False,
):
    """Full mLSTM residual block. x (B,S,d) → (out, new_state)."""
    b, s, d = x.shape
    h, dk, dv = mlstm_dims(cfg)
    d_inner = h * dv
    dtype = x.dtype
    xin = layers.rmsnorm(x, params["norm"])
    z = jax.nn.silu(jnp.dot(xin, params["w_gate"].astype(dtype)))
    u = jnp.dot(xin, params["w_up"].astype(dtype))
    q = jnp.dot(u, params["wq"].astype(dtype)).reshape(b, s, h, dk)
    k = jnp.dot(u, params["wk"].astype(dtype)).reshape(b, s, h, dk) / jnp.sqrt(
        jnp.float32(dk)
    ).astype(dtype)
    v = jnp.dot(u, params["wv"].astype(dtype)).reshape(b, s, h, dv)
    gates = jnp.dot(u, params["w_if"].astype(dtype)).reshape(b, s, 2, h)
    i_gate = jax.nn.sigmoid(gates[:, :, 0].astype(jnp.float32))  # (B,S,H)
    f_gate = jax.nn.sigmoid(gates[:, :, 1].astype(jnp.float32))
    log_f = jnp.log(jnp.maximum(f_gate, 1e-6))

    # (B,H,S,*) layout, f32 recurrence internals
    qt = q.transpose(0, 2, 1, 3).astype(jnp.float32)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    ig = i_gate.transpose(0, 2, 1)
    lf = log_f.transpose(0, 2, 1)

    if state is None:
        state = MLSTMState(
            c=jnp.zeros((b, h, dk, dv), jnp.float32),
            n=jnp.zeros((b, h, dk), jnp.float32),
        )

    chunk = min(chunk, s)
    if s % chunk != 0:
        pad = chunk - s % chunk
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        ig = jnp.pad(ig, ((0, 0), (0, 0), (0, pad)))
        lf = jnp.pad(lf, ((0, 0), (0, 0), (0, pad)))
    nchunks = qt.shape[2] // chunk

    def body(st, xs):
        qc, kc, vc, ic, fc = xs
        hc, st2 = _mlstm_chunk(qc, kc, vc, fc, ic, st)
        return st2, hc

    xs = (
        qt.reshape(b, h, nchunks, chunk, dk).transpose(2, 0, 1, 3, 4),
        kt.reshape(b, h, nchunks, chunk, dk).transpose(2, 0, 1, 3, 4),
        vt.reshape(b, h, nchunks, chunk, dv).transpose(2, 0, 1, 3, 4),
        ig.reshape(b, h, nchunks, chunk).transpose(2, 0, 1, 3),
        lf.reshape(b, h, nchunks, chunk).transpose(2, 0, 1, 3),
    )
    state_f, hs = jax.lax.scan(body, state, xs)
    hs = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, nchunks * chunk, dv)[:, :, :s]
    hs = hs.transpose(0, 2, 1, 3).reshape(b, s, d_inner).astype(dtype)
    hs = layers.rmsnorm(hs, params["out_norm"]) * z
    out = x + jnp.dot(hs, params["w_down"].astype(dtype))
    return out, (state_f if return_state else None)


def mlstm_decode_step(params, x, cfg: ArchConfig, state: MLSTMState):
    """Single-token mLSTM step. x (B,1,d)."""
    out, st = mlstm_block(params, x, cfg, state, chunk=1, return_state=True)
    return out, st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d)
    n: jax.Array  # (B, d)
    h: jax.Array  # (B, d)
    m: jax.Array  # (B, d) stabilizer


def init_slstm(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 6)
    return {
        "norm": layers.rmsnorm_init(d),
        # input projections for gates i, f, z, o
        "w_in": layers.dense_init(ks[0], d, 4 * d),
        # block-diagonal recurrent weights per head: (H, 4, hd, hd)
        "r": (
            jax.random.normal(ks[1], (h, 4, hd, hd), jnp.float32)
            / jnp.sqrt(jnp.float32(hd))
        ),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": layers.rmsnorm_init(d),
        "w_down": layers.dense_init(ks[2], d, d),
    }


def _slstm_step(params, cfg: ArchConfig, xt: jax.Array, st: SLSTMState) -> tuple:
    """One sLSTM timestep. xt: (B, 4d) preprojected input contribution."""
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    b = xt.shape[0]
    # recurrent contribution: per-head block-diagonal matmul of h_{t-1}
    hprev = st.h.reshape(b, h, hd)
    rec = jnp.einsum("bhd,hgde->bhge", hprev, params["r"])  # (B,H,4,hd)
    rec = rec.transpose(0, 2, 1, 3).reshape(b, 4 * d)
    pre = xt + rec + params["b"]
    itil, ftil, ztil, otil = jnp.split(pre, 4, axis=-1)
    # exponential gating with stabilizer (paper eq. sLSTM)
    m_new = jnp.maximum(ftil + st.m, itil)
    i = jnp.exp(itil - m_new)
    f = jnp.exp(ftil + st.m - m_new)
    z = jnp.tanh(ztil)
    o = jax.nn.sigmoid(otil)
    c = f * st.c + i * z
    n = f * st.n + i
    hnew = o * c / jnp.maximum(n, 1.0)
    return hnew, SLSTMState(c, n, hnew, m_new)


def slstm_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    state: Optional[SLSTMState] = None,
    *,
    return_state: bool = False,
):
    """Recurrent sLSTM residual block. x (B,S,d)."""
    b, s, d = x.shape
    dtype = x.dtype
    xin = layers.rmsnorm(x, params["norm"])
    pre = jnp.dot(xin, params["w_in"].astype(dtype)).astype(jnp.float32)  # (B,S,4d)
    if state is None:
        z = jnp.zeros((b, d), jnp.float32)
        state = SLSTMState(z, z, z, jnp.full((b, d), -1e30, jnp.float32))

    def body(st, xt):
        hnew, st2 = _slstm_step(params, cfg, xt, st)
        return st2, hnew

    state_f, hs = jax.lax.scan(body, state, pre.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(dtype)  # (B,S,d)
    hs = layers.rmsnorm(hs, params["out_norm"])
    out = x + jnp.dot(hs, params["w_down"].astype(dtype))
    return out, (state_f if return_state else None)


def slstm_decode_step(params, x, cfg: ArchConfig, state: SLSTMState):
    out, st = slstm_block(params, x, cfg, state, return_state=True)
    return out, st
