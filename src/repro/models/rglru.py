"""RG-LRU / Griffin recurrent block (RecurrentGemma).

Block: x → {gate branch: linear→gelu} ⊗ {rec branch: linear → causal
depthwise conv (width 4) → RG-LRU} → linear out (+ residual).

RG-LRU (Real-Gated Linear Recurrent Unit):
    r_t = σ(W_a x_t + b_a)                    (recurrence gate)
    i_t = σ(W_x x_t + b_x)                    (input gate)
    a_t = exp(-c · softplus(Λ) · r_t),  c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is diagonal-linear, so training uses
``jax.lax.associative_scan`` (parallel over the sequence, O(log S) depth);
decode is a single fused step.  State per token: (B, rnn_width) — O(1)
memory per decode step, which is what qualifies this arch for long_500k.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, d_rnn) recurrent state
    conv: jax.Array  # (B, conv_width-1, d_rnn) trailing conv inputs


def init_rglru(key, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dr = cfg.rnn_width
    cw = cfg.conv_width
    ks = jax.random.split(key, 8)
    # Λ init so that a ∈ (0.9, 0.999) at r=1 (paper's init range)
    u = jax.random.uniform(ks[5], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "norm": layers.rmsnorm_init(d),
        "w_gate": layers.dense_init(ks[0], d, dr),
        "w_rec": layers.dense_init(ks[1], d, dr),
        "conv_w": (
            jax.random.normal(ks[2], (cw, dr), jnp.float32) / jnp.sqrt(float(cw))
        ),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_a": layers.dense_init(ks[3], dr, dr),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": layers.dense_init(ks[4], dr, dr),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "lambda": lam,
        "w_out": layers.dense_init(ks[6], dr, d),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array, prev=None):
    """x (B,S,d), w (cw,d). ``prev`` (B,cw-1,d) carries decode history."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B, S+cw-1, d)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(cw)
    )
    tail = xp[:, -(cw - 1) :] if cw > 1 else jnp.zeros_like(prev)
    return out + b.astype(x.dtype), tail


def _rglru_scan(a: jax.Array, bterm: jax.Array, h0: jax.Array):
    """h_t = a_t h_{t-1} + b_t via associative scan. a/b: (B,S,d) f32."""
    # fold h0 into the first step
    bterm = bterm.at[:, 0].add(a[:, 0] * h0)

    def op(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(op, (a, bterm), axis=1)
    return h


def rglru_block(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    state: Optional[RGLRUState] = None,
    *,
    return_state: bool = False,
):
    """Griffin recurrent residual block. x (B,S,d) → (out, new_state)."""
    b, s, d = x.shape
    dr = cfg.rnn_width
    dtype = x.dtype
    xin = layers.rmsnorm(x, params["norm"])
    gate = jax.nn.gelu(jnp.dot(xin, params["w_gate"].astype(dtype)))
    u = jnp.dot(xin, params["w_rec"].astype(dtype))
    prev = state.conv if state is not None else None
    u, conv_tail = _causal_depthwise_conv(u, params["conv_w"], params["conv_b"], prev)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(
        jnp.dot(uf, params["w_a"].astype(jnp.float32)) + params["b_a"]
    )
    i = jax.nn.sigmoid(
        jnp.dot(uf, params["w_x"].astype(jnp.float32)) + params["b_x"]
    )
    log_a = -_C * jax.nn.softplus(params["lambda"])[None, None, :] * r
    a = jnp.exp(log_a)
    bterm = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    h0 = state.h if state is not None else jnp.zeros((b, dr), jnp.float32)
    if s == 1:  # decode fast path — no scan
        h = (a[:, 0] * h0 + bterm[:, 0])[:, None, :]
    else:
        h = _rglru_scan(a, bterm, h0)
    hseq = h.astype(dtype) * gate
    out = x + jnp.dot(hseq, params["w_out"].astype(dtype))
    new_state = None
    if return_state:
        new_state = RGLRUState(h=h[:, -1], conv=conv_tail)
    return out, new_state


def rglru_decode_step(params, x, cfg: ArchConfig, state: RGLRUState):
    return rglru_block(params, x, cfg, state, return_state=True)


def rglru_init_state(cfg: ArchConfig, batch: int) -> RGLRUState:
    return RGLRUState(
        h=jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width), jnp.float32),
    )
