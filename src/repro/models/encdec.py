"""Encoder-decoder model (whisper-base backbone).

Per assignment the conv/mel frontend is a STUB: the model consumes
precomputed frame embeddings (B, T_frames, d_model) from ``input_specs``.
Encoder: non-causal attention + GELU MLP (biases), sinusoidal positions.
Decoder: causal self-attention (+cache), cross-attention over encoder
output, GELU MLP.  Embedding weights are tied with the LM head (whisper).

RMSNorm is used in place of LayerNorm throughout the framework (noted in
DESIGN.md §deviations — a norm-flavor swap, not a structural change).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import layers


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_mlp(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": layers.dense_init(k1, cfg.d_model, cfg.d_ff),
        "b_in": jnp.zeros((cfg.d_ff,), jnp.float32),
        "w_out": layers.dense_init(k2, cfg.d_ff, cfg.d_model),
        "b_out": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _init_enc_layer(key, cfg: ArchConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layers.rmsnorm_init(cfg.d_model),
        "attn": attn.init_attention(k1, cfg),
        "norm2": layers.rmsnorm_init(cfg.d_model),
        "mlp": _init_mlp(k2, cfg),
    }


def _init_dec_layer(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layers.rmsnorm_init(cfg.d_model),
        "self_attn": attn.init_attention(k1, cfg),
        "norm_x": layers.rmsnorm_init(cfg.d_model),
        "cross_attn": attn.init_attention(k2, cfg),
        "norm2": layers.rmsnorm_init(cfg.d_model),
        "mlp": _init_mlp(k3, cfg),
    }


def init_params(key, cfg: ArchConfig) -> dict:
    ke, k1, k2 = jax.random.split(key, 3)
    ekeys = jax.random.split(k1, cfg.encoder_layers)
    dkeys = jax.random.split(k2, cfg.num_layers)
    return {
        "embed": layers.truncated_normal_init(ke, (cfg.vocab_size, cfg.d_model), 1.0),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(ekeys),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dkeys),
        "enc_norm": layers.rmsnorm_init(cfg.d_model),
        "dec_norm": layers.rmsnorm_init(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------
def encode(params, frames: jax.Array, cfg: ArchConfig, parallel=None) -> jax.Array:
    """frames (B, T, d) — precomputed frontend embeddings (stub)."""
    b, t, d = frames.shape
    x = frames.astype(_dtype(cfg)) + layers.sinusoidal_positions(t, d).astype(
        _dtype(cfg)
    )
    if parallel is not None:
        x = parallel.shard_act(x)

    def step(x, p):
        xin = layers.rmsnorm(x, p["norm1"])
        out, _ = attn.attention(p["attn"], xin, cfg, None, causal=False)
        x = x + out
        xin = layers.rmsnorm(x, p["norm2"])
        x = x + layers.gelu_mlp(
            xin, p["mlp"]["w_in"], p["mlp"]["b_in"], p["mlp"]["w_out"], p["mlp"]["b_out"]
        )
        if parallel is not None:
            x = parallel.shard_act(x)
        return x, None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return layers.rmsnorm(x, params["enc_norm"])


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------
def _dec_block_train(p, x, enc_out, cfg):
    xin = layers.rmsnorm(x, p["norm1"])
    out, _ = attn.attention(p["self_attn"], xin, cfg, None, causal=True)
    x = x + out
    xin = layers.rmsnorm(x, p["norm_x"])
    ek, ev = attn.encoder_kv(p["cross_attn"], enc_out, cfg)
    x = x + attn.cross_attention(p["cross_attn"], xin, cfg, ek, ev)
    xin = layers.rmsnorm(x, p["norm2"])
    x = x + layers.gelu_mlp(
        xin, p["mlp"]["w_in"], p["mlp"]["b_in"], p["mlp"]["w_out"], p["mlp"]["b_out"]
    )
    return x


def forward_train(params, tokens: jax.Array, frames: jax.Array, cfg: ArchConfig,
                  parallel=None):
    """tokens (B, S+1), frames (B, T, d) → logits (B, S, V)."""
    enc_out = encode(params, frames, cfg, parallel)
    inputs = tokens[:, :-1]
    b, s = inputs.shape
    x = jnp.take(params["embed"], inputs, axis=0).astype(_dtype(cfg))
    x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    if parallel is not None:
        x = parallel.shard_act(x)

    def step(x, p):
        x = _dec_block_train(p, x, enc_out, cfg)
        if parallel is not None:
            x = parallel.shard_act(x)
        return x, None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = layers.rmsnorm(x, params["dec_norm"])
    return jnp.dot(x, params["embed"].T.astype(x.dtype))


def loss_fn(params, batch: dict, cfg: ArchConfig, parallel=None, aux_coef=0.0):
    logits = forward_train(params, batch["tokens"], batch["frames"], cfg, parallel)
    labels = batch["tokens"][:, 1:]
    ce = layers.softmax_cross_entropy_logits(logits, labels)
    return ce, {"loss": ce, "ce": ce, "moe_aux": jnp.zeros(())}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def prefill(
    params,
    tokens: jax.Array,
    frames: jax.Array,
    cfg: ArchConfig,
    cache_len: Optional[int] = None,
):
    """Encode audio + consume prompt tokens; returns (logits, caches)."""
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    cache_len = cache_len or s
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)

    def step(x, p):
        xin = layers.rmsnorm(x, p["norm1"])
        out, cache = attn.attention(
            p["self_attn"], xin, cfg, None, causal=True,
            return_cache=True, cache_len=cache_len,
        )
        x = x + out
        xin = layers.rmsnorm(x, p["norm_x"])
        ek, ev = attn.encoder_kv(p["cross_attn"], enc_out, cfg)
        x = x + attn.cross_attention(p["cross_attn"], xin, cfg, ek, ev)
        xin = layers.rmsnorm(x, p["norm2"])
        x = x + layers.gelu_mlp(
            xin, p["mlp"]["w_in"], p["mlp"]["b_in"], p["mlp"]["w_out"], p["mlp"]["b_out"]
        )
        return x, {"self": cache, "cross_k": ek, "cross_v": ev}

    x, caches = jax.lax.scan(step, x, params["dec_layers"])
    x = layers.rmsnorm(x[:, -1:], params["dec_norm"])
    logits = jnp.dot(x, params["embed"].T.astype(x.dtype))[:, 0]
    return logits, caches


def decode_step(params, caches, token: jax.Array, pos: jax.Array, cfg: ArchConfig):
    """One decode token. token (B,1), pos (B,)."""
    x = jnp.take(params["embed"], token, axis=0).astype(_dtype(cfg))
    x = x + layers.sinusoidal_at(pos, cfg.d_model)[:, None, :].astype(x.dtype)

    def step(x, pc):
        p, c = pc
        xin = layers.rmsnorm(x, p["norm1"])
        out, self_cache = attn.attention(
            p["self_attn"], xin, cfg, None, causal=True,
            cache=c["self"], cache_pos=pos,
        )
        x = x + out
        xin = layers.rmsnorm(x, p["norm_x"])
        x = x + attn.cross_attention(
            p["cross_attn"], xin, cfg, c["cross_k"], c["cross_v"]
        )
        xin = layers.rmsnorm(x, p["norm2"])
        x = x + layers.gelu_mlp(
            xin, p["mlp"]["w_in"], p["mlp"]["b_in"], p["mlp"]["w_out"], p["mlp"]["b_out"]
        )
        return x, {"self": self_cache, "cross_k": c["cross_k"], "cross_v": c["cross_v"]}

    x, new_caches = jax.lax.scan(step, x, (params["dec_layers"], caches))
    x = layers.rmsnorm(x, params["dec_norm"])
    logits = jnp.dot(x, params["embed"].T.astype(x.dtype))[:, 0]
    return logits, new_caches


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    """Zero self caches + zero cross kv (stacked over decoder layers)."""
    dt = _dtype(cfg)
    hd = cfg.head_dim_
    kv = cfg.num_kv_heads
    z = jnp.zeros((cfg.num_layers, batch, kv, cache_len, hd), dt)
    ck = jnp.zeros((cfg.num_layers, batch, kv, cfg.frontend_len, hd), dt)
    return {"self": attn.KVCache(z, z), "cross_k": ck, "cross_v": ck}
