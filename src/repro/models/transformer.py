"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM archs.

Layers are stacked per *pattern period* (``cfg.block_pattern``) and driven
with ``lax.scan`` so the HLO is O(period), not O(num_layers) — llama3-405b's
126 layers lower as one scanned period.  Block types:

  attn | swa | local  → pre-norm GQA attention (+ SwiGLU MLP or MoE)
  mlstm | slstm       → xLSTM residual blocks (self-contained)
  rglru               → Griffin recurrent block (+ MLP when d_ff > 0)

Three modes share the block code: ``train`` (no caches), ``prefill``
(returns caches), ``decode`` (one token against caches; ring buffers for
windowed attention).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.parallel import ParallelConfig
from repro.models import attention as attn
from repro.models import layers, moe as moe_mod, rglru, ssm

ATTN_TYPES = ("attn", "swa", "local")
RECURRENT_TYPES = ("mlstm", "slstm", "rglru")


def _act_seq_dim(cfg: ArchConfig):
    """Sequence-parallel residuals are wrong for recurrent blocks: the time
    scan is sequential, so a seq-sharded residual forces GSPMD to all-gather
    the sequence and run the recurrence redundantly (measured: per-step
    weight-grad all-reduces).  SP only for pure-attention stacks."""
    return None if any(bt in RECURRENT_TYPES for bt in cfg.block_pattern) else 1


def block_window(cfg: ArchConfig, bt: str) -> Optional[int]:
    if bt == "swa":
        return cfg.sliding_window
    if bt == "local":
        return cfg.local_window
    return None


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ArchConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": layers.dense_init(k1, cfg.d_model, cfg.d_ff),
        "w_up": layers.dense_init(k2, cfg.d_model, cfg.d_ff),
        "w_down": layers.dense_init(k3, cfg.d_ff, cfg.d_model),
    }


def init_block(key, cfg: ArchConfig, bt: str) -> dict:
    ka, kb = jax.random.split(key)
    if bt in ATTN_TYPES:
        p: dict[str, Any] = {
            "norm1": layers.rmsnorm_init(cfg.d_model),
            "attn": attn.init_attention(ka, cfg),
        }
    elif bt == "mlstm":
        return {"mixer": ssm.init_mlstm(ka, cfg)}
    elif bt == "slstm":
        return {"mixer": ssm.init_slstm(ka, cfg)}
    elif bt == "rglru":
        p = {"mixer": rglru.init_rglru(ka, cfg)}
    else:
        raise ValueError(f"unknown block type {bt}")
    if cfg.d_ff > 0:
        p["norm2"] = layers.rmsnorm_init(cfg.d_model)
        p["mlp"] = init_moe_or_mlp(kb, cfg)
    return p


def init_moe_or_mlp(key, cfg: ArchConfig) -> dict:
    if cfg.is_moe:
        return {"moe": moe_mod.init_moe(key, cfg)}
    return init_mlp(key, cfg)


def init_period(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, len(cfg.block_pattern))
    return {f"b{j}": init_block(ks[j], cfg, bt) for j, bt in enumerate(cfg.block_pattern)}


def init_params(key, cfg: ArchConfig) -> dict:
    ke, kl, kh = jax.random.split(key, 3)
    pkeys = jax.random.split(kl, cfg.num_periods)
    params = {
        "embed": layers.truncated_normal_init(ke, (cfg.vocab_size, cfg.d_model), 1.0),
        "layers": jax.vmap(lambda k: init_period(k, cfg))(pkeys),
        "final_norm": layers.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(kh, cfg.d_model, cfg.vocab_size)
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ArchConfig, bt: str, batch: int, cache_len: int):
    dt = _dtype(cfg)
    hd = cfg.head_dim_
    kv = cfg.num_kv_heads
    if bt == "attn":
        z = jnp.zeros((batch, kv, cache_len, hd), dt)
        return attn.KVCache(z, z)
    if bt in ("swa", "local"):
        w = min(block_window(cfg, bt), cache_len)
        z = jnp.zeros((batch, kv, w, hd), dt)
        return attn.RingKVCache(z, z, jnp.full((batch, w), -1, jnp.int32))
    if bt == "mlstm":
        h, dk, dv = ssm.mlstm_dims(cfg)
        return ssm.MLSTMState(
            c=jnp.zeros((batch, h, dk, dv), jnp.float32),
            n=jnp.zeros((batch, h, dk), jnp.float32),
        )
    if bt == "slstm":
        z = jnp.zeros((batch, cfg.d_model), jnp.float32)
        return ssm.SLSTMState(z, z, z, jnp.full((batch, cfg.d_model), -1e30, jnp.float32))
    if bt == "rglru":
        return rglru.rglru_init_state(cfg, batch)
    raise ValueError(bt)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int):
    """Zero caches for all layers: leaves have leading dim num_periods."""
    per = {
        f"b{j}": init_block_cache(cfg, bt, batch, cache_len)
        for j, bt in enumerate(cfg.block_pattern)
    }
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.num_periods,) + a.shape), per
    )


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _apply_mlp(p, x, cfg, parallel):
    """Post-mixer MLP/MoE residual. Returns (x, aux)."""
    if "mlp" not in p:
        return x, jnp.zeros((), jnp.float32)
    xin = layers.rmsnorm(x, p["norm2"])
    if cfg.is_moe:
        out, aux = moe_mod.moe(p["mlp"]["moe"], xin, cfg, parallel)
        return x + out, aux.astype(jnp.float32)
    out = layers.swiglu(xin, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x + out, jnp.zeros((), jnp.float32)


def apply_block_train(bt, p, x, positions, cfg, parallel):
    if bt in ATTN_TYPES:
        xin = layers.rmsnorm(x, p["norm1"])
        out, _ = attn.attention(
            p["attn"], xin, cfg, positions, causal=True, window=block_window(cfg, bt)
        )
        x = x + out
    elif bt == "mlstm":
        x, _ = ssm.mlstm_block(p["mixer"], x, cfg)
    elif bt == "slstm":
        x, _ = ssm.slstm_block(p["mixer"], x, cfg)
    elif bt == "rglru":
        x, _ = rglru.rglru_block(p["mixer"], x, cfg)
    return _apply_mlp(p, x, cfg, parallel)


def apply_block_prefill(bt, p, x, positions, cfg, parallel, cache_len):
    if bt in ATTN_TYPES:
        w = block_window(cfg, bt)
        xin = layers.rmsnorm(x, p["norm1"])
        if bt == "attn":
            out, cache = attn.attention(
                p["attn"], xin, cfg, positions, causal=True, window=w,
                return_cache=True, cache_len=cache_len,
            )
        else:
            out, full_cache = attn.attention(
                p["attn"], xin, cfg, positions, causal=True, window=w,
                return_cache=True, cache_len=x.shape[1],
            )
            ring_w = min(w, cache_len)
            cache = attn.ring_prefill_cache(
                full_cache.k[:, :, : x.shape[1]],
                full_cache.v[:, :, : x.shape[1]],
                x.shape[1],
                ring_w,
            )
        x = x + out
    elif bt == "mlstm":
        x, cache = ssm.mlstm_block(p["mixer"], x, cfg, return_state=True)
    elif bt == "slstm":
        x, cache = ssm.slstm_block(p["mixer"], x, cfg, return_state=True)
    elif bt == "rglru":
        x, cache = rglru.rglru_block(p["mixer"], x, cfg, return_state=True)
    x, _ = _apply_mlp(p, x, cfg, parallel)
    return x, cache


def apply_block_decode(bt, p, x, cache, pos, cfg, parallel):
    if bt in ATTN_TYPES:
        xin = layers.rmsnorm(x, p["norm1"])
        if bt == "attn":
            out, cache = attn.attention(
                p["attn"], xin, cfg, pos.reshape(-1, 1), causal=True,
                cache=cache, cache_pos=pos,
            )
        else:
            w = block_window(cfg, bt)
            out, cache = attn.ring_decode_attention(p["attn"], xin, cfg, cache, pos, w)
        x = x + out
    elif bt == "mlstm":
        x, cache = ssm.mlstm_decode_step(p["mixer"], x, cfg, cache)
    elif bt == "slstm":
        x, cache = ssm.slstm_decode_step(p["mixer"], x, cfg, cache)
    elif bt == "rglru":
        x, cache = rglru.rglru_decode_step(p["mixer"], x, cfg, cache)
    x, _ = _apply_mlp(p, x, cfg, parallel)
    return x, cache


# ---------------------------------------------------------------------------
# model-level forward passes
# ---------------------------------------------------------------------------
def _embed(params, tokens, cfg: ArchConfig, parallel=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    if parallel is not None:
        x = parallel.shard_act(x, seq_dim=_act_seq_dim(cfg))
    return x


def _head(params, x, cfg: ArchConfig):
    xf = layers.rmsnorm(x, params["final_norm"])
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.dot(xf, w.astype(xf.dtype))


def forward_train(
    params,
    tokens: jax.Array,
    cfg: ArchConfig,
    parallel: Optional[ParallelConfig] = None,
    prefix_emb: Optional[jax.Array] = None,
):
    """Full teacher-forced pass.  tokens (B, S+1) → (logits (B,S,V), aux)."""
    inputs, _ = tokens[:, :-1], tokens[:, 1:]
    x = _embed(params, inputs, cfg, parallel)
    p_len = 0
    if prefix_emb is not None:  # VLM: precomputed patch embeddings (stub)
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
        p_len = prefix_emb.shape[1]
        if parallel is not None:
            x = parallel.shard_act(x)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    remat = parallel.remat if parallel is not None else True

    def period_step(carry, pp):
        x, aux = carry
        for j, bt in enumerate(cfg.block_pattern):
            x, a = apply_block_train(bt, pp[f"b{j}"], x, positions, cfg, parallel)
            if parallel is not None:
                # keep batch-DP through the scan (seq-dim SP when legal)
                x = parallel.shard_act(x, seq_dim=_act_seq_dim(cfg))
            aux = aux + a
        return (x, aux), None

    fn = jax.checkpoint(period_step) if remat else period_step
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    if p_len:
        x = x[:, p_len:]
    logits = _head(params, x, cfg)
    return logits, aux / cfg.num_layers


def loss_fn(
    params,
    batch: dict,
    cfg: ArchConfig,
    parallel: Optional[ParallelConfig] = None,
    aux_coef: float = 0.01,
):
    """Next-token CE (+ MoE load-balance aux).  Returns (loss, metrics)."""
    tokens = batch["tokens"]
    prefix = batch.get("patch_emb")
    logits, aux = forward_train(params, tokens, cfg, parallel, prefix_emb=prefix)
    labels = tokens[:, 1:]
    ce = layers.softmax_cross_entropy_logits(logits, labels)
    loss = ce + aux_coef * aux
    return loss, {"loss": loss, "ce": ce, "moe_aux": aux}


def prefill(
    params,
    tokens: jax.Array,
    cfg: ArchConfig,
    parallel: Optional[ParallelConfig] = None,
    cache_len: Optional[int] = None,
    prefix_emb: Optional[jax.Array] = None,
):
    """Process the prompt, return (last-token logits, caches).

    ``cache_len`` sizes the decode KV caches (≥ prompt length).
    """
    x = _embed(params, tokens, cfg, parallel)
    if prefix_emb is not None:
        x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
        if parallel is not None:
            x = parallel.shard_act(x)
    b, s, _ = x.shape
    # the cache must cover the whole processed prompt (incl. any VLM prefix)
    cache_len = max(cache_len or s, s)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def period_step(x, pp):
        caches = {}
        for j, bt in enumerate(cfg.block_pattern):
            x, c = apply_block_prefill(
                bt, pp[f"b{j}"], x, positions, cfg, parallel, cache_len
            )
            if parallel is not None:
                x = parallel.shard_act(x, seq_dim=_act_seq_dim(cfg))
            caches[f"b{j}"] = c
        return x, caches

    x, caches = jax.lax.scan(period_step, x, params["layers"])
    logits = _head(params, x[:, -1:], cfg)[:, 0]
    return logits, caches


def decode_step(
    params,
    caches,
    token: jax.Array,  # (B, 1) int32
    pos: jax.Array,  # (B,) absolute position of `token`
    cfg: ArchConfig,
    parallel: Optional[ParallelConfig] = None,
):
    """One decode step: returns (logits (B,V), new caches)."""
    x = _embed(params, token, cfg, parallel)

    def period_step(x, pc):
        pp, cc = pc
        new = {}
        for j, bt in enumerate(cfg.block_pattern):
            x, c2 = apply_block_decode(
                bt, pp[f"b{j}"], x, cc[f"b{j}"], pos, cfg, parallel
            )
            if parallel is not None:
                x = parallel.shard_act(x, seq_dim=None)
            new[f"b{j}"] = c2
        return x, new

    x, new_caches = jax.lax.scan(period_step, x, (params["layers"], caches))
    logits = _head(params, x, cfg)[:, 0]
    return logits, new_caches
