"""Unified model API: build init/loss/prefill/decode closures per arch.

``build_model(cfg, parallel)`` returns a :class:`ModelBundle` whose members
are pure functions over parameter pytrees.  ``input_specs(cell)`` produces
``ShapeDtypeStruct`` stand-ins for every model input of an assigned shape
cell — the dry-run lowers against these without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed.parallel import ParallelConfig
from repro.models import encdec, transformer


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ArchConfig
    parallel: Optional[ParallelConfig]
    init: Callable[[jax.Array], Any]
    loss: Callable[..., tuple]
    prefill: Callable[..., tuple]
    decode_step: Callable[..., tuple]
    init_cache: Callable[[int, int], Any]

    # -- dry-run inputs --------------------------------------------------------
    def param_shapes(self, seed: int = 0):
        return jax.eval_shape(self.init, jax.random.key(seed))

    def train_input_specs(self, cell: ShapeCell) -> dict:
        b, s = cell.global_batch, cell.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        specs.update(self._frontend_specs(b))
        return specs

    def prefill_input_specs(self, cell: ShapeCell) -> dict:
        b, s = cell.global_batch, cell.seq_len
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        specs.update(self._frontend_specs(b))
        return specs

    def decode_input_specs(self, cell: ShapeCell) -> dict:
        b, s = cell.global_batch, cell.seq_len
        cache_shapes = jax.eval_shape(lambda: self.init_cache(b, s))
        return {
            "token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
            "caches": cache_shapes,
        }

    def _frontend_specs(self, b: int) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        if cfg.frontend == "patch_stub":
            return {
                "patch_emb": jax.ShapeDtypeStruct(
                    (b, cfg.frontend_len, cfg.d_model), dt
                )
            }
        if cfg.frontend == "audio_stub":
            return {
                "frames": jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.d_model), dt)
            }
        return {}


def build_model(
    cfg: ArchConfig, parallel: Optional[ParallelConfig] = None
) -> ModelBundle:
    cfg.validate()
    if cfg.is_encoder_decoder:

        def init(key):
            return encdec.init_params(key, cfg)

        def loss(params, batch):
            return encdec.loss_fn(params, batch, cfg, parallel)

        def prefill_fn(params, batch, cache_len=None):
            return encdec.prefill(
                params, batch["tokens"], batch["frames"], cfg, cache_len=cache_len
            )

        def decode_fn(params, caches, token, pos):
            return encdec.decode_step(params, caches, token, pos, cfg)

        def init_cache(batch, cache_len):
            return encdec.init_cache(cfg, batch, cache_len)

    else:

        def init(key):
            return transformer.init_params(key, cfg)

        def loss(params, batch):
            return transformer.loss_fn(params, batch, cfg, parallel)

        def prefill_fn(params, batch, cache_len=None):
            return transformer.prefill(
                params,
                batch["tokens"],
                cfg,
                parallel,
                cache_len=cache_len,
                prefix_emb=batch.get("patch_emb"),
            )

        def decode_fn(params, caches, token, pos):
            return transformer.decode_step(params, caches, token, pos, cfg, parallel)

        def init_cache(batch, cache_len):
            return transformer.init_cache(cfg, batch, cache_len)

    return ModelBundle(
        cfg=cfg,
        parallel=parallel,
        init=init,
        loss=loss,
        prefill=prefill_fn,
        decode_step=decode_fn,
        init_cache=init_cache,
    )
