"""Model zoo: composable JAX blocks for the assigned architectures.

Decoder-only transformers (dense GQA, MoE, sliding-window), xLSTM blocks,
RG-LRU/Griffin hybrid blocks, encoder-decoder (whisper), and VLM prefix
models (pixtral).  Everything is pure-functional: ``build_model(cfg)``
returns init/loss/prefill/decode closures over parameter pytrees, with
``lax.scan`` over stacked layer parameters so the HLO stays compact at 126
layers.
"""
from repro.models.api import build_model, ModelBundle

__all__ = ["build_model", "ModelBundle"]
