"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

``Compiled.cost_analysis()`` counts each while-loop body **once**; a
scan-over-layers train step under-reports FLOPs by ~num_layers ×
microbatches (verified: a 10-iteration scanned matmul reports 1 matmul of
FLOPs).  The roofline would be garbage without correcting this, so this
module re-derives costs from the HLO text, propagating loop multipliers:

* ``while`` trip counts come from ``backend_config known_trip_count``
  (XLA annotates counted loops), falling back to the ``constant(N)``
  compared in the loop condition;
* **FLOPs**: every ``dot`` (2 · prod(out_dims) · prod(lhs contracting
  dims)), walked through fusion/call/conditional/while bodies;
* **HBM bytes**: per *top-level* instruction in each executed computation
  (entry + loop bodies + branches): Σ operand bytes + output bytes —
  fusions count as one instruction (their internals stay in registers /
  VMEM), matching XLA's own bytes-accessed model;
* **wire bytes**: collective ops weighted by replica-group size:
  all-gather out·(g-1)/g, reduce-scatter out·(g-1), all-reduce
  out·2(g-1)/g, all-to-all out·(g-1)/g, collective-permute out.

Shapes in a post-partitioning SPMD module are per-device, so every number
reported here is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# ops whose operands/outputs move no real bytes
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _parse_shapes(text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    out_shapes: list  # [(dtype, dims)]
    operands: list  # operand %names
    attrs: str  # raw remainder (attributes)
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    table: dict  # name -> Instr (including parameters w/ shapes)


_KNOWN_OPCODES = None


def _split_instr(rest: str) -> Optional[tuple]:
    """'<shape> opcode(operands), attrs' → (shapes, opcode, operands, attrs)."""
    m = re.match(r"^\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)\((.*)$",
                 rest)
    if not m:
        return None
    shape_txt, opcode, tail = m.groups()
    # operands run to the matching close paren of the opcode call
    depth = 1
    for i, ch in enumerate(tail):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
    operand_txt, attrs = tail[:i], tail[i + 1:]
    shapes = _parse_shapes(shape_txt)
    operands = _OPERAND_RE.findall(operand_txt)
    return shapes, opcode, operands, attrs


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and ("->" in line) and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.groups()
        parsed = _split_instr(rest)
        if parsed is None:
            continue
        shapes, opcode, operands, attrs = parsed
        ins = Instr(name, opcode, shapes, operands, attrs, line)
        cur.instrs.append(ins)
        cur.table[name] = ins
    return comps


def _called_comps(ins: Instr) -> list[str]:
    names = []
    for key in ("calls=", "body=", "condition=", "branch_computations={",
                "to_apply="):
        idx = ins.attrs.find(key)
        while idx >= 0:
            seg = ins.attrs[idx + len(key):]
            names += _OPERAND_RE.findall(seg.split("}", 1)[0] if "{" in key else
                                         seg.split(",", 1)[0])
            idx = -1
    return names


def _trip_count(ins: Instr, comps: dict) -> int:
    m = _TRIP_RE.search(ins.attrs)
    if m:
        return int(m.group(1))
    # fallback: largest integer constant in the condition computation
    cond = None
    mc = re.search(r"condition=%([\w.\-]+)", ins.attrs)
    if mc and mc.group(1) in comps:
        consts = []
        for i2 in comps[mc.group(1)].instrs:
            cm = _CONST_RE.search(i2.raw)
            if cm:
                consts.append(int(cm.group(1)))
        if consts:
            return max(consts)
    return 1


def _group_size(ins: Instr, default: int) -> int:
    m = _GROUPS_RE.search(ins.attrs)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_RE.search(ins.attrs)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_n = 1
    for _, dims in ins.out_shapes:
        for d in dims:
            out_n *= d
    contract = 1
    m = _LHS_CONTRACT_RE.search(ins.attrs)
    if m and ins.operands:
        lhs = comp.table.get(ins.operands[0])
        if lhs is not None and lhs.out_shapes:
            dims = lhs.out_shapes[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    contract *= dims[idx]
    return 2.0 * out_n * contract


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: dict = dataclasses.field(default_factory=dict)
    collective_counts: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0
    fused_region_bytes_saved: float = 0.0  # flash-fusable HBM traffic avoided

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _operand_bytes(ins: Instr, comp: Computation, loop_trips: int = 1) -> int:
    """Σ operand bytes.  ``loop_trips``: trip count of the enclosing while —
    an operand whose leading dim equals it is a scan-xs stack consumed one
    slice per iteration (XLA fuses the dynamic-slice into the consumer, so
    the raw operand shape is the FULL stack); charge one slice."""
    total = 0
    for op in ins.operands:
        ref = comp.table.get(op)
        if ref is None:
            continue
        b = _shape_bytes(ref.out_shapes)
        if (
            loop_trips > 1
            and ref.out_shapes
            and ref.out_shapes[0][1]
            and ref.out_shapes[0][1][0] == loop_trips
        ):
            b //= loop_trips
        total += b
    return total


def _score_shaped(ins: Instr) -> bool:
    """Attention score/probability tensors: rank ≥ 4 with a long trailing
    (kv-sequence) dim.  q/k/v/out end in head_dim ≤ 256; the residual
    stream is rank-3 — only flash-kernel-internal tensors match."""
    for _, dims in ins.out_shapes:
        if len(dims) >= 4 and dims[-1] >= 512:
            return True
    return False


_PIN_MIN = 1 << 20  # 1 MiB — below this, re-reads are noise
_PIN_MAX = 64 << 20  # 64 MiB — VMEM-pinnable budget (v5e: 128 MiB VMEM)


def _invariant_slots(comp: Computation) -> set:
    """Tuple indices the while body passes through unchanged (x → x).

    The body ROOT tuple's operand j being ``get-tuple-element(param),
    index=j`` marks slot j loop-invariant — weights re-read every
    iteration.  The Pallas recurrence kernels (kernels/slstm.py) pin such
    blocks in VMEM, so the roofline charges them once per loop.
    """
    if not comp.instrs:
        return set()
    root = comp.instrs[-1]
    if root.opcode != "tuple":
        return set()
    out = set()
    for j, op in enumerate(root.operands):
        ref = comp.table.get(op)
        if ref is None or ref.opcode != "get-tuple-element":
            continue
        m = re.search(r"index=(\d+)", ref.attrs)
        if m and int(m.group(1)) == j:
            out.add(j)
    return out


def analyze(
    hlo: str,
    num_devices: int,
    entry: Optional[str] = None,
    *,
    fused_attention_shapes: bool = False,
    pin_loop_invariants: bool = False,
) -> CostSummary:
    """``fused_attention_shapes``: also classify score-shaped tensors as
    flash-kernel-internal.  Autodiff drops named scopes from backward op
    metadata (``transpose(jvp())``), so the attention backward — an equally
    standard VMEM-resident kernel — needs the shape rule.  Callers enable
    it only for attention-family archs (never for mLSTM, whose quadratic
    gate matrices must be fixed by chunking, not accounting)."""
    comps = parse_module(hlo)
    if not comps:
        return CostSummary()
    if entry is None:
        m = re.search(r"^ENTRY\s+%([\w.\-]+)", hlo, re.M)
        entry = m.group(1) if m else next(iter(comps))
    summary = CostSummary()

    def _elems(shapes) -> int:
        n = 0
        for _, dims in shapes:
            e = 1
            for d in dims:
                e *= d
            n += e
        return n

    def _is_rs_pattern(ins: Instr, comp: Computation, g: int) -> bool:
        """all-reduce fully consumed by per-device slices == the
        reduce-scatter the TPU backend forms (XLA:CPU lacks the
        reduce-scatter-creation pass, so the dry-run HLO shows AR+slice;
        charging AR bytes would double-count the wire).  Variadic ARs are
        followed through their get-tuple-element consumers."""
        if ins.opcode != "all-reduce":
            return False

        def consumers_of(name: str):
            return [o for o in comp.instrs if name in o.operands]

        frontier = [(ins.name, _elems(ins.out_shapes))]
        checked = 0
        while frontier:
            name, elems = frontier.pop()
            for c in consumers_of(name):
                if c.opcode == "get-tuple-element":
                    frontier.append((c.name, _elems(c.out_shapes)))
                    continue
                if c.opcode == "tuple":
                    return False  # escapes via loop carry — keep AR cost
                checked += 1
                if _elems(c.out_shapes) * g > elems:
                    return False
        return checked > 0

    def flops_walk(comp_name: str, mult: float, seen: tuple):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                tc = _trip_count(ins, comps)
                mb = re.search(r"body=%([\w.\-]+)", ins.attrs)
                if _TRIP_RE.search(ins.attrs) is None:
                    summary.unknown_trip_loops += 1
                if mb:
                    flops_walk(mb.group(1), mult * tc, seen + (comp_name,))
            elif ins.opcode in ("fusion", "call", "conditional", "map",
                                "reduce", "reduce-window", "sort", "scatter",
                                "select-and-scatter", "custom-call"):
                for sub in _called_comps(ins):
                    if "condition" not in sub:
                        flops_walk(sub, mult, seen + (comp_name,))
            elif ins.opcode == "dot":
                summary.flops += mult * _dot_flops(ins, comp)
            kind = (
                ins.opcode[: -len("-start")]
                if ins.opcode.endswith("-start")
                else ins.opcode
            )
            if kind in _COLLECTIVE_KINDS:
                g = _group_size(ins, num_devices)
                if g <= 1:
                    continue
                out_b = _shape_bytes(ins.out_shapes)
                if ins.opcode.endswith("-start"):
                    # async start shapes repeat (operand, result); halve.
                    out_b //= 2
                if kind == "all-gather":
                    wire = out_b * (g - 1) / g
                elif kind == "reduce-scatter":
                    wire = out_b * (g - 1)
                elif kind == "all-reduce":
                    if _is_rs_pattern(ins, comp, g):
                        kind = "all-reduce(rs)"  # TPU backend forms RS here
                        wire = out_b * (g - 1) / g
                    else:
                        wire = out_b * 2 * (g - 1) / g
                elif kind == "all-to-all":
                    wire = out_b * (g - 1) / g
                else:
                    wire = out_b
                summary.wire_bytes += mult * wire
                summary.wire_by_kind[kind] = summary.wire_by_kind.get(kind, 0.0) + mult * wire
                summary.collective_counts[kind] = summary.collective_counts.get(kind, 0) + 1

    def bytes_walk(comp_name: str, mult: float, seen: tuple, trips: int = 1):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen:
            return
        scoped = {
            i.name
            for i in comp.instrs
            if "flash_fusable" in i.attrs
            or (fused_attention_shapes and _score_shaped(i))
        }
        pinned: set = set()
        if pin_loop_invariants and trips > 1:
            inv = _invariant_slots(comp)
            for i2 in comp.instrs:
                if i2.opcode != "get-tuple-element":
                    continue
                m2 = re.search(r"index=(\d+)", i2.attrs)
                if m2 and int(m2.group(1)) in inv:
                    b2 = _shape_bytes(i2.out_shapes)
                    if _PIN_MIN <= b2 <= _PIN_MAX:
                        pinned.add(i2.name)
        for ins in comp.instrs:
            if ins.opcode == "while":
                tc = _trip_count(ins, comps)
                mb = re.search(r"body=%([\w.\-]+)", ins.attrs)
                mc = re.search(r"condition=%([\w.\-]+)", ins.attrs)
                if mb:
                    bytes_walk(mb.group(1), mult * tc, seen + (comp_name,), tc)
                if mc:
                    bytes_walk(mc.group(1), mult * tc, seen + (comp_name,), tc)
                continue
            if ins.opcode == "conditional":
                for sub in _called_comps(ins):
                    bytes_walk(sub, mult, seen + (comp_name,), trips)
                continue
            if ins.opcode in _FREE_OPS:
                continue
            if ins.name in scoped:
                # fused-kernel region (validated Pallas flash attention):
                # internals stay in VMEM on the TPU target — only bytes
                # entering the region from outside count here; region
                # outputs are charged at their unscoped consumers.
                ext = 0
                for op in ins.operands:
                    ref = comp.table.get(op)
                    if ref is not None and op not in scoped:
                        b = _shape_bytes(ref.out_shapes)
                        if (
                            trips > 1
                            and ref.out_shapes
                            and ref.out_shapes[0][1]
                            and ref.out_shapes[0][1][0] == trips
                        ):
                            b //= trips
                        elif op in pinned:
                            b //= trips
                        ext += b
                summary.hbm_bytes += mult * ext
                summary.fused_region_bytes_saved += mult * (
                    _operand_bytes(ins, comp, trips)
                    + _shape_bytes(ins.out_shapes)
                    - ext
                )
                continue
            ob = 0
            for op in ins.operands:
                ref = comp.table.get(op)
                if ref is None:
                    continue
                b = _shape_bytes(ref.out_shapes)
                if (
                    trips > 1
                    and ref.out_shapes
                    and ref.out_shapes[0][1]
                    and ref.out_shapes[0][1][0] == trips
                ):
                    b //= trips
                elif op in pinned:
                    # VMEM-pinned loop-invariant (Pallas recurrence kernel
                    # contract): one HBM read per loop, not per iteration.
                    b //= trips
                    summary.fused_region_bytes_saved += mult * b * (trips - 1)
                ob += b
            out_b = _shape_bytes(ins.out_shapes)
            if (
                trips > 1
                and ins.out_shapes
                and ins.out_shapes[0][1]
                and ins.out_shapes[0][1][0] == trips
            ):
                # scan-ys stacking: dynamic-update-slice writes ONE slice
                # per iteration into the (trips, ...) buffer.
                out_b //= trips
            summary.hbm_bytes += mult * (ob + out_b)

    flops_walk(entry, 1.0, ())
    bytes_walk(entry, 1.0, ())
    return summary
