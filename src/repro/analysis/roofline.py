"""Roofline table from dry-run JSON records.

Terms per (arch × shape × mesh), all **seconds per step, per device**
(the SPMD module is per-device; wire bytes are per-device):

    compute    = HLO_FLOPs / 197e12            (TPU v5e bf16 peak)
    memory     = HLO_bytes / 819e9             (HBM bandwidth)
    collective = wire_bytes / 50e9             (ICI link bandwidth)

The *step-time estimate* is ``max`` of the three (no-overlap roofline);
``roofline fraction`` = compute / max — 1.0 means compute-bound at peak,
the score the perf loop drives up.  ``MFU_est`` uses the 6·N·D (train) /
2·N·D (inference) convention over the same step time:

    MFU = MODEL_FLOPS / (chips · 197e12 · step_time)

``useful`` = MODEL_FLOPS / (HLO_FLOPs · chips): how much compiled compute
is model math (catches remat recompute, dense-MoE waste, attention not in
the 6ND convention — useful > 1 is possible for long-seq attention-heavy
cells where 6ND undercounts).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def load_records(directory: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def derive(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    t = rec["terms_s"]
    step = max(t.values())
    chips = rec["chips"]
    mf = rec["model_flops_global"]
    return {
        "arch": rec["arch"],
        "cell": rec["cell"],
        "mesh": "2x16x16" if rec["multi_pod"] else "16x16",
        "compute_s": t["compute_s"],
        "memory_s": t["memory_s"],
        "collective_s": t["collective_s"],
        "step_s": step,
        "bottleneck": rec["bottleneck"].replace("_s", ""),
        "fraction": t["compute_s"] / step if step else 0.0,
        "mfu": mf / (chips * PEAK_FLOPS * step) if step else 0.0,
        "useful": rec.get("useful_flops_ratio", 0.0),
        "temp_gib": rec.get("memory_analysis", {}).get("temp_size_in_bytes", 0)
        / 2**30,
        "arg_gib": rec.get("memory_analysis", {}).get("argument_size_in_bytes", 0)
        / 2**30,
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | cell | mesh | compute (s) | memory (s) | collective (s) | "
        "step est (s) | bottleneck | roofline frac | MFU est | useful | temp GiB |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|---|"
    )
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['step_s']:.3e} "
            f"| {r['bottleneck']} | {r['fraction']:.3f} | {r['mfu']:.3f} "
            f"| {r['useful']:.2f} | {r['temp_gib']:.1f} |"
        )
    return "\n".join(lines)


def summarize(directory: str, mesh: Optional[str] = None) -> list[dict]:
    rows = [d for d in (derive(r) for r in load_records(directory)) if d]
    if mesh:
        rows = [r for r in rows if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["cell"], r["mesh"]))
    return rows


def worst_cells(rows: list[dict], n: int = 5) -> list[dict]:
    return sorted(rows, key=lambda r: r["fraction"])[:n]


def most_collective_bound(rows: list[dict], n: int = 5) -> list[dict]:
    return sorted(
        rows, key=lambda r: r["collective_s"] / max(r["step_s"], 1e-30), reverse=True
    )[:n]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--pick", action="store_true", help="print hillclimb candidates")
    args = ap.parse_args()
    rows = summarize(args.dir, args.mesh)
    print(markdown_table(rows))
    skipped = [r for r in load_records(args.dir) if r.get("status") == "skipped"]
    errored = [r for r in load_records(args.dir) if r.get("status") == "error"]
    print(f"\nok={len(rows)} skipped={len(skipped)} error={len(errored)}")
    for r in errored:
        print(f"  ERROR {r['arch']}.{r['cell']}.{r['multi_pod']}: {r['error'][:140]}")
    if args.pick:
        print("\nworst roofline fraction:")
        for r in worst_cells(rows):
            print(f"  {r['arch']}.{r['cell']}.{r['mesh']} frac={r['fraction']:.3f}")
        print("\nmost collective-bound:")
        for r in most_collective_bound(rows):
            print(
                f"  {r['arch']}.{r['cell']}.{r['mesh']} "
                f"coll={r['collective_s']/max(r['step_s'],1e-30):.2f} of step"
            )


if __name__ == "__main__":
    main()
