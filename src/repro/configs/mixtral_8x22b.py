"""mixtral-8x22b — MoE LM, 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] 56L d_model=6144 48H (kv=8) d_ff=16384 vocab=32768, MoE 8e top-2, SWA.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    rope_theta=1_000_000.0,
    block_pattern=("swa",),
    sliding_window=4096,
    num_experts=8,
    experts_per_token=2,
    moe_capacity_factor=1.25,
)

SMOKE_CONFIG = ArchConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    block_pattern=("swa",),
    sliding_window=32,
    num_experts=4,
    experts_per_token=2,
    moe_capacity_factor=2.0,
)
