"""whisper-base — encoder-decoder audio model, conv frontend STUBBED.

[arXiv:2212.04356] 6L (enc) + 6L (dec) d_model=512 8H d_ff=2048 vocab=51865.
Per assignment the conv frontend is a stub: ``input_specs()`` provides
precomputed mel-frame embeddings (1500 frames after the conv stride).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    block_pattern=("attn",),
    is_encoder_decoder=True,
    encoder_layers=6,
    frontend="audio_stub",
    frontend_len=1500,
    notes="Encoder-decoder: decode shapes run (self-attn cache + cross-attn); "
    "long_500k skipped (full attention).",
)

SMOKE_CONFIG = ArchConfig(
    name="whisper-base-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    block_pattern=("attn",),
    is_encoder_decoder=True,
    encoder_layers=2,
    frontend="audio_stub",
    frontend_len=64,
)
