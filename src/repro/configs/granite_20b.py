"""granite-20b — dense code LM, llama-arch, MQA (GQA kv=1).

[arXiv:2405.04324; hf] 52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=10_000.0,
    block_pattern=("attn",),
    notes="MQA (single kv head) — decode KV cache cannot head-shard; "
    "uses the sequence-sharded distributed-decode path.",
)

SMOKE_CONFIG = ArchConfig(
    name="granite-20b-smoke",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    head_dim=16,
    block_pattern=("attn",),
)
