"""qwen3-14b — dense LM with qk_norm, GQA kv=8.

[hf:Qwen/Qwen3-8B family; hf] 40L d_model=5120 40H (kv=8) d_ff=17408 vocab=151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
)

SMOKE_CONFIG = ArchConfig(
    name="qwen3-14b-smoke",
    family="dense",
    num_layers=4,
    d_model=160,
    num_heads=10,
    num_kv_heads=2,
    d_ff=320,
    vocab_size=512,
    head_dim=16,
    qk_norm=True,
    block_pattern=("attn",),
)
