"""xlstm-1.3b — recurrent LM of alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517] 48L d_model=2048 4H d_ff=0 vocab=50304 (blocks integrate
their own projections; no separate MLP).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    notes="Recurrent state is O(1) per token — runs the long_500k cell.",
)

SMOKE_CONFIG = ArchConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    block_pattern=("mlstm", "slstm"),
    mlstm_proj_factor=2.0,
)
