"""pixtral-12b — VLM: pixtral-ViT frontend (STUB) + mistral-nemo backbone.

[hf:mistralai/Pixtral-12B-2409] 40L d_model=5120 32H (kv=8) d_ff=14336 vocab=131072.
Per assignment the vision frontend is a stub: ``input_specs()`` provides
precomputed patch embeddings that are prepended to the token sequence.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    frontend="patch_stub",
    frontend_len=256,  # precomputed patch embeddings per sample
)

SMOKE_CONFIG = ArchConfig(
    name="pixtral-12b-smoke",
    family="vlm",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    block_pattern=("attn",),
    frontend="patch_stub",
    frontend_len=16,
)
