"""qwen3-4b — dense LM with qk_norm and GQA kv=8.

[hf:Qwen/Qwen3-8B family; hf] 36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,  # decoupled from d_model (HF config)
    qk_norm=True,
    rope_theta=1_000_000.0,
    block_pattern=("attn",),
    tie_embeddings=True,
)

SMOKE_CONFIG = ArchConfig(
    name="qwen3-4b-smoke",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    qk_norm=True,
    tie_embeddings=True,
    block_pattern=("attn",),
)
