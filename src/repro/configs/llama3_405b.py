"""llama3-405b — dense frontier LM, GQA kv=8, 128k vocab.

[arXiv:2407.21783] 126L d_model=16384 128H (kv=8) d_ff=53248 vocab=128256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    block_pattern=("attn",),
)

SMOKE_CONFIG = ArchConfig(
    name="llama3-405b-smoke",
    family="dense",
    num_layers=6,
    d_model=256,
    num_heads=8,
    num_kv_heads=2,
    d_ff=768,
    vocab_size=512,
    head_dim=32,
    rope_theta=500_000.0,
    block_pattern=("attn",),
)
