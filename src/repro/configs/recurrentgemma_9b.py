"""recurrentgemma-9b — Griffin hybrid: RG-LRU blocks + local attention, 2:1.

[arXiv:2402.19427] 38L d_model=4096 16H (kv=1) d_ff=12288, local window 2048.
Pattern (rglru, rglru, local) — two recurrent blocks per local-attention block.
38 layers = 12 periods of 3 + ... → paper uses 38; we need divisibility, so the
pattern is applied as 12 periods (36 layers) + 1 extra (rglru, rglru) pair is
not representable with a fixed period — we follow the published block ratio
with 39 layers rounded down to 36? No: we keep EXACTLY 38 layers using period
(rglru, rglru, local) × 12 + (rglru, rglru) tail, encoded as pattern length 19
applied twice: (r,r,l, r,r,l, r,r,l, r,r,l, r,r,l, r,r,l, r) — see PATTERN.
"""
from repro.configs.base import ArchConfig

# 38 layers, ratio 2 recurrent : 1 local-attn (Griffin). Period of 19 applied
# twice keeps the exact layer count and the published ratio (13 recurrent + 6
# local per period → 26 + 12 + ... = 38 total with the tail recurrent block).
_PERIOD = (
    "rglru", "rglru", "local",
    "rglru", "rglru", "local",
    "rglru", "rglru", "local",
    "rglru", "rglru", "local",
    "rglru", "rglru", "local",
    "rglru", "rglru", "local",
    "rglru",
)

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=_PERIOD,
    local_window=2048,
    rnn_width=4096,
    conv_width=4,
    notes="Local attention window 2048 + RG-LRU ⇒ O(window) decode state; "
    "runs long_500k. kv=1 local attention uses the seq-sharded decode path.",
)

SMOKE_CONFIG = ArchConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=3,
    d_model=128,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    head_dim=32,
    block_pattern=("rglru", "rglru", "local"),
    local_window=32,
    rnn_width=128,
    conv_width=4,
)
