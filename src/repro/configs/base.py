"""Architecture config schema, input-shape suite, and the arch registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input-shape suite (assigned): every LM arch is paired with these four cells.
# train_* lowers train_step; prefill_* lowers prefill_step; decode_*/long_*
# lower serve_step (1 new token against a seq_len-sized KV cache).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPE_SUITE: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)


def shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_SUITE:
        if c.name == name:
            return c
    raise KeyError(f"unknown shape cell {name!r}")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact public-literature config)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # SWA width ("swa" blocks)
    local_window: Optional[int] = None  # local-attention width ("local" blocks)
    # Block pattern cycled over num_layers. Entries:
    #   attn | swa | local | mlstm | slstm | rglru
    block_pattern: Tuple[str, ...] = ("attn",)
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # Recurrent widths
    rnn_width: int = 0  # RG-LRU recurrence width
    conv_width: int = 4  # temporal conv in the Griffin block
    mlstm_proj_factor: float = 2.0  # xLSTM up-projection
    # Encoder-decoder / modality frontend (STUB per assignment: input_specs
    # provide precomputed frame/patch embeddings)
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    frontend: Optional[str] = None  # audio_stub | patch_stub
    frontend_len: int = 0
    # Numerics / impl
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    attention_impl: str = "xla"  # xla | flash_pallas (TPU target)
    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if serve_step cost per token is o(seq_len) state reads —
        the long_500k eligibility criterion (ssm / hybrid-with-local-attn)."""
        return self.family in ("ssm", "hybrid")

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        assert self.num_layers % self.pattern_period == 0, (
            f"{self.name}: num_layers {self.num_layers} not divisible by "
            f"pattern period {self.pattern_period}"
        )
        return self.num_layers // self.pattern_period

    def supports_cell(self, cell: ShapeCell) -> tuple[bool, str]:
        """Whether this (arch × shape) cell runs, and why not if skipped."""
        if cell.name == "long_500k" and not self.sub_quadratic:
            return False, (
                "long_500k needs sub-quadratic attention; "
                f"{self.name} is full-attention ({self.family}) — skipped per assignment"
            )
        return True, ""

    def validate(self) -> None:
        assert self.num_heads % self.num_kv_heads == 0, self.name
        assert self.num_layers % len(self.block_pattern) == 0, self.name
        if self.is_moe:
            assert self.experts_per_token in (1, 2), self.name
        if "rglru" in self.block_pattern:
            assert self.rnn_width > 0, self.name
        if self.is_encoder_decoder:
            assert self.encoder_layers > 0, self.name


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
ARCH_IDS = (
    "granite_20b",
    "qwen3_4b",
    "llama3_405b",
    "qwen3_14b",
    "grok_1_314b",
    "mixtral_8x22b",
    "xlstm_1_3b",
    "recurrentgemma_9b",
    "pixtral_12b",
    "whisper_base",
)


def get_config(arch: str) -> ArchConfig:
    """Load ``src/repro/configs/<arch>.py`` and return its CONFIG."""
    arch = arch.replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def get_smoke_config(arch: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    arch = arch.replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg: ArchConfig = mod.SMOKE_CONFIG
    cfg.validate()
    return cfg


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
