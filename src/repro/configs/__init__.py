"""Architecture configs (one module per assigned arch) + shape suite."""
from repro.configs.base import (
    ARCH_IDS,
    SHAPE_SUITE,
    ArchConfig,
    ShapeCell,
    all_configs,
    get_config,
    get_smoke_config,
    shape_cell,
)

__all__ = [
    "ARCH_IDS",
    "SHAPE_SUITE",
    "ArchConfig",
    "ShapeCell",
    "all_configs",
    "get_config",
    "get_smoke_config",
    "shape_cell",
]
