"""grok-1-314b — MoE LM, 8 experts top-2, GQA kv=8.

[hf:xai-org/grok-1] 64L d_model=6144 48H (kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    block_pattern=("attn",),
    num_experts=8,
    experts_per_token=2,
    moe_capacity_factor=1.25,
    notes="MoE dispatch reuses the paper's binned capacity all-to-all "
    "(repro.core.exchange) for expert parallelism.",
)

SMOKE_CONFIG = ArchConfig(
    name="grok-1-314b-smoke",
    family="moe",
    num_layers=4,
    d_model=128,
    num_heads=8,
    num_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    block_pattern=("attn",),
    num_experts=4,
    experts_per_token=2,
    moe_capacity_factor=2.0,
)
