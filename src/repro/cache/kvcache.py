"""KVCache — an eager KV-cache facade over the versioned distributed table.

The table core is a *multiset* (insert adds occurrences); a cache wants a
*map* with lifetimes.  This facade closes the gap with the three pieces
the core already grew for it:

* **put** is ``DistributedHashTable.upsert`` — prior versions tombstoned
  at the current epoch, the new row in a fresh delta, so every read
  resolves the newest value through the same fused 2-all-to-all plan as a
  plain query (no read-path branching for cache semantics).
* **TTL** rides the tombstone ``expires`` lane against the state's
  logical clock: ``advance(now)`` is O(1) and purely functional; expiry
  is resolved at read time, so a row ages out of *every* snapshot that
  advances past its deadline.
* **Eviction** is the :class:`~repro.core.maintenance.CompactionPolicy`
  eviction trigger: expired rows are invisible the moment the clock
  passes them, but their capacity is only returned by a fold/compact —
  :meth:`maintain` runs the policy (stats-driven cold-first folds,
  escalation to the live-count-sized full rebuild under expired/tombstone
  pressure) so a steady upsert+expire stream holds live capacity flat.

Eager/host-driven by design (each call syncs a few scalars): this is the
single-process counterpart of the ``repro.serve_table`` server, which
applies the same ops through its shadow-state writer loop for
snapshot-swapped concurrent serving.
"""
from __future__ import annotations

import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import maintenance, plans
from repro.core.hashgraph import EMPTY_KEY
from repro.core.maintenance import CompactionPolicy
from repro.core.state import TableState
from repro.core.table import DistributedHashTable, retrieval_to_lists
from repro.obs.registry import MetricsRegistry, RegistrySnapshot


class KVCache:
    """Insert-or-replace cache with TTL/eviction over one ``TableState``.

    ``table`` owns the mesh and jitted executors; ``keys``/``values``
    (optional) pre-load the cache through one bulk build.  ``default_ttl``
    applies to every :meth:`put` that does not pass its own; ``policy``
    defaults to stats-driven folds (``fold_k=None``), ring-full folding,
    and the eviction escalation at 25% expired tombstone load.

    All methods are eager (host-driven); the state is functional
    underneath, so :attr:`state` at any moment is an immutable snapshot
    that stays valid across later mutations.
    """

    def __init__(
        self,
        table: DistributedHashTable,
        keys=None,
        values=None,
        *,
        default_ttl: Optional[int] = None,
        policy: Optional[CompactionPolicy] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.table = table
        self.default_ttl = default_ttl
        self.metrics_registry = metrics if metrics is not None else MetricsRegistry()
        reg = self.metrics_registry
        self._c_puts = reg.counter(
            "kvcache_puts_total", help="put() batches applied."
        )
        self._c_gets = reg.counter(
            "kvcache_gets_total", help="get()/contains() batches served."
        )
        self._c_deletes = reg.counter(
            "kvcache_deletes_total", help="delete() batches applied."
        )
        self._c_evictions = reg.counter(
            "kvcache_evictions_total", help="Full compacts run by maintenance."
        )
        self._c_folds = reg.counter(
            "kvcache_folds_total", help="Incremental folds run by maintenance."
        )
        self._h_put = reg.histogram(
            "kvcache_put_seconds", help="put() wall-clock latency."
        )
        self._h_get = reg.histogram(
            "kvcache_get_seconds", help="get() wall-clock latency."
        )
        self.policy = policy or CompactionPolicy(
            max_delta_depth=table.max_deltas,
            fold_k=None,
            expired_load=0.25,
        )
        if keys is None:
            # Empty cache: a base of EMPTY sentinel rows (zero live keys).
            n = 8 * table.num_devices
            keys = np.full(
                (n,) if table.schema.key_lanes == 1 else (n, table.schema.key_lanes),
                EMPTY_KEY,
                np.uint32,
            )
            values = np.full((n,), -1, np.int32)
            if table.schema.value_cols > 1:
                values = np.stack([values] * table.schema.value_cols, axis=1)
        self.state: TableState = table.init(keys, values)
        self.evictions = 0  # full compacts run by maintain()
        self.folds = 0  # incremental folds run by maintain()

    # -- clock ---------------------------------------------------------------
    @property
    def now(self) -> int:
        """The logical clock TTLs expire against."""
        return int(self.state.now)

    def advance(self, now: int) -> None:
        """Advance the logical clock (monotone); expiry is read-resolved."""
        self.state = self.state.advance(now)

    def tick(self, dt: int = 1) -> None:
        """Advance the clock by ``dt``."""
        self.advance(self.now + int(dt))

    # -- writes --------------------------------------------------------------
    def put(self, keys, values=None, *, ttl: Optional[int] = None) -> None:
        """Insert-or-replace ``keys`` -> ``values``; optional per-call TTL.

        Runs the compaction policy first (the server's per-op discipline:
        neither the delta ring nor the tombstone buffer can overflow
        mid-stream while the policy triggers are on), then one
        ``table.upsert``.  ``ttl=None`` falls back to ``default_ttl``;
        pass ``ttl=0`` for an immediately-expired (inert) write.
        """
        t0 = time.perf_counter()
        stats = self.state.stats()
        if self.policy.due(stats):
            self.maintain(stats=stats, force=True)
        if ttl is None:
            ttl = self.default_ttl
        self.state = self.table.upsert(self.state, keys, values, ttl=ttl)
        self._c_puts.inc()
        self._h_put.observe(time.perf_counter() - t0)

    def delete(self, keys) -> None:
        """Drop ``keys`` from every later read (tombstoned immediately)."""
        stats = self.state.stats()
        if self.policy.due(stats):
            self.maintain(stats=stats, force=True)
        self.state = self.table.delete(self.state, keys)
        self._c_deletes.inc()

    # -- reads ---------------------------------------------------------------
    def _pad_queries(self, keys) -> tuple[jnp.ndarray, int]:
        q = self.table.schema.pack_keys(keys)
        n = q.shape[0]
        pad = (-n) % self.table.num_devices
        if pad:
            q = jnp.concatenate(
                [q, jnp.full((pad,) + q.shape[1:], EMPTY_KEY, jnp.uint32)]
            )
        return q, n

    def contains(self, keys) -> np.ndarray:
        """Boolean per key: live (unexpired) entry present?"""
        q, n = self._pad_queries(keys)
        self._c_gets.inc()
        return np.asarray(self.table.query(self.state, q))[:n] > 0

    def get(self, keys, *, fill: int = -1) -> np.ndarray:
        """Current value per key; ``fill`` where missing or expired.

        Returns ``(N,)`` int32 for 1-column schemas, ``(N, C)`` otherwise.
        Under the KV discipline every present key has exactly one live
        row, so the per-key value list is its single element.
        """
        t0 = time.perf_counter()
        q, n = self._pad_queries(keys)
        res = self.table.retrieve(self.state, q)
        per_key = retrieval_to_lists(res)[:n]
        self._c_gets.inc()
        self._h_get.observe(time.perf_counter() - t0)
        cols = self.table.schema.value_cols
        out = np.full((n,) if cols == 1 else (n, cols), fill, np.int32)
        for i, vals in enumerate(per_key):
            if len(vals):
                out[i] = vals[0]
        return out

    # -- maintenance / eviction ----------------------------------------------
    def live_count(self) -> int:
        """Global live (visible at the current clock) row count."""
        return int(plans.exec_live_count(self.table, self.state))

    def stats(self):
        """The underlying ``TableStats`` (includes ``tombstone_expired``)."""
        return self.state.stats()

    def metrics(self, refresh: bool = True) -> RegistrySnapshot:
        """One atomic sample of the cache's metrics registry.

        With ``refresh`` (default) the state-derived gauges — delta depth,
        tombstone load/expired, logical clock — are re-read first.
        """
        if refresh:
            st = self.state.stats()
            reg = self.metrics_registry
            reg.gauge("kvcache_delta_depth", help="Live delta layers.").set(
                st.delta_depth
            )
            reg.gauge(
                "kvcache_tombstone_load", help="Tombstone fill fraction."
            ).set(st.tombstone_load)
            reg.gauge(
                "kvcache_expired_load", help="Expired tombstone fraction."
            ).set(st.expired_load)
            reg.gauge("kvcache_now", help="Logical clock TTLs expire on.").set(
                self.now
            )
        return self.metrics_registry.snapshot()

    def maintain(self, *, stats=None, force: bool = False) -> bool:
        """Run one policy-driven fold/evict pass; True iff anything ran.

        Escalations (tombstone pressure, dropped rows, the ``expired_load``
        eviction trigger) run the full live-count-sized ``compact()`` —
        the pass that returns expired/superseded capacity.  Otherwise a
        stats-driven ``fold_oldest`` merges the cold prefix.  ``force``
        skips the ``due`` check (the ``put`` path pre-checked it).
        """
        if stats is None:
            stats = self.state.stats()
        if not force and not self.policy.due(stats):
            return False
        escalate = self.policy.escalates(stats)
        if escalate or not self.state.coherent:
            self._run_fold(full=True)
            return True
        layer_live = None
        if self.policy.fold_k is None and stats.delta_depth:
            layer_live = maintenance.collect_layer_live(self.state)
        k = self.policy.fold_amount(stats, layer_live)
        if not k:
            return False
        self._run_fold(full=k >= stats.delta_depth, k=k)
        return True

    def _run_fold(self, *, full: bool, k: int = 0) -> None:
        """One timed fold/compact with the shared metrics recording."""
        t0 = time.perf_counter()
        rows_before = maintenance.allocated_rows(self.state)
        if full:
            self.state = self.state.compact()
            self.evictions += 1
            self._c_evictions.inc()
        else:
            self.state = maintenance.fold_oldest(self.state, k)
            self.folds += 1
            self._c_folds.inc()
        maintenance.record_fold(
            self.metrics_registry,
            kind="full" if full else "fold",
            seconds=time.perf_counter() - t0,
            rows_before=rows_before,
            rows_after=maintenance.allocated_rows(self.state),
        )

    def evict_expired(self) -> int:
        """Force a full compact; returns rows reclaimed (allocated delta).

        The explicit eviction pass: expired rows (and superseded versions)
        are dropped from the rebuilt base, tombstone slots free, and the
        base arrays re-flatten to the live-count size.
        """
        before = self.state.stats()
        alloc_before = before.base_rows + before.delta_rows
        self._run_fold(full=True)
        after = self.state.stats()
        return alloc_before - (after.base_rows + after.delta_rows)
