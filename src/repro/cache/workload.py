"""YCSB-style mixed-workload generators (zipfian keys, A–F op mixes).

The canonical cloud-serving benchmark shapes (Cooper et al., SoCC'10),
host-side and numpy-only — the generator produces *op batches* (grouped
by kind so each maps to one table/server call) and the driver decides how
to execute them (``benchmarks/bench_ycsb.py`` runs them through
``TableServer``/``AsyncFrontend``; tests run them against the eager
:class:`~repro.cache.kvcache.KVCache`).

Workload letters::

    A  update-heavy   50% read / 50% update        zipfian
    B  read-heavy     95% read /  5% update        zipfian
    C  read-only     100% read                     zipfian
    D  read-latest    95% read /  5% insert        latest (recency-skewed)
    E  short-ranges   95% scan /  5% insert        zipfian (scan = multiget)
    F  read-mod-write 50% read / 50% RMW           zipfian

``scan`` is a contiguous multiget over insertion-order key indices — the
table is a hash table, so "range" means the loader's key sequence, which
is what YCSB-E measures on hashed stores too.  RMW ops read a key and
write it back in the same batch (the driver issues the read first).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

# Affine bijection modulo the Mersenne prime 2^31 - 1: spreads insertion
# order over the key space deterministically (YCSB's hashed-key idiom)
# while staying injective and never producing the EMPTY sentinel.
_KEY_P = (1 << 31) - 1
_KEY_A = 1103515245
_KEY_B = 12345


def key_of(index) -> np.ndarray:
    """Key id for insertion-order ``index`` (vectorized, uint32, never EMPTY)."""
    idx = np.asarray(index, dtype=np.uint64)
    return ((idx * _KEY_A + _KEY_B) % _KEY_P).astype(np.uint32)


class ZipfianGenerator:
    """Bounded zipfian ranks: ``P(rank=i) ∝ 1 / (i+1)^theta``, rank 0 hottest.

    CDF-inversion sampling (exact, vectorized) — the precomputed CDF is
    O(n) floats, fine for the benchmark-scale key counts this drives.
    ``theta=0.99`` is the YCSB default skew.
    """

    def __init__(self, n: int, theta: float = 0.99, seed: int = 0):
        if n < 1:
            raise ValueError("need at least one key")
        self.n = int(n)
        self.theta = float(theta)
        w = 1.0 / np.arange(1, self.n + 1, dtype=np.float64) ** self.theta
        self._cdf = np.cumsum(w)
        self._cdf /= self._cdf[-1]
        self.rng = np.random.default_rng(seed)

    def sample(self, size: int) -> np.ndarray:
        """``size`` ranks in ``[0, n)``; rank 0 is the hottest."""
        return np.searchsorted(
            self._cdf, self.rng.random(size), side="left"
        ).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Op mix of one workload letter (fractions sum to 1)."""

    name: str
    read: float = 0.0
    update: float = 0.0
    insert: float = 0.0
    scan: float = 0.0
    rmw: float = 0.0
    request_distribution: str = "zipfian"  # or "latest"

    def __post_init__(self):
        total = self.read + self.update + self.insert + self.scan + self.rmw
        if abs(total - 1.0) > 1e-9:
            raise ValueError(f"workload {self.name}: mix sums to {total}, not 1")


WORKLOADS = {
    "A": WorkloadSpec("A", read=0.5, update=0.5),
    "B": WorkloadSpec("B", read=0.95, update=0.05),
    "C": WorkloadSpec("C", read=1.0),
    "D": WorkloadSpec("D", read=0.95, insert=0.05, request_distribution="latest"),
    "E": WorkloadSpec("E", scan=0.95, insert=0.05),
    "F": WorkloadSpec("F", read=0.5, rmw=0.5),
}


class YCSBWorkload:
    """Batched op stream for one workload letter.

    Yields ``(kind, keys, values)`` tuples — ``kind`` in ``{"read",
    "update", "insert", "scan", "rmw"}``, ``keys`` uint32, ``values``
    int32 (None for reads/scans).  Ops are drawn per-batch from the mix
    and grouped by kind, so each tuple maps to exactly one batched
    table/server call; ``scan`` keys are the flattened contiguous
    multigets (``scan_len`` per scan op).

    ``num_keys`` is the *loaded* population (insert via :meth:`load_keys`
    / :meth:`load_values`); D/E-style inserts append fresh keys after it
    and the "latest" distribution re-skews toward them as they land.
    """

    def __init__(
        self,
        spec: WorkloadSpec,
        num_keys: int,
        *,
        theta: float = 0.99,
        batch: int = 256,
        scan_len: int = 16,
        seed: int = 0,
    ):
        self.spec = spec
        self.num_keys = int(num_keys)
        self.batch = int(batch)
        self.scan_len = int(scan_len)
        self.zipf = ZipfianGenerator(num_keys, theta=theta, seed=seed)
        self.rng = np.random.default_rng(seed + 1)
        self.inserted = self.num_keys  # insertion cursor (D/E fresh keys)
        self._value_seq = 0

    # -- load phase ----------------------------------------------------------
    def load_keys(self) -> np.ndarray:
        """The initial key population, insertion order."""
        return key_of(np.arange(self.num_keys))

    def load_values(self) -> np.ndarray:
        """Initial values: the insertion index (so reads are checkable)."""
        return np.arange(self.num_keys, dtype=np.int32)

    # -- run phase -----------------------------------------------------------
    def _ranks_to_indices(self, ranks: np.ndarray) -> np.ndarray:
        if self.spec.request_distribution == "latest":
            # Rank 0 = newest inserted key, recency-skewed like YCSB-D.
            return (self.inserted - 1 - ranks) % self.inserted
        return ranks

    def _next_values(self, n: int) -> np.ndarray:
        v = np.arange(self._value_seq, self._value_seq + n, dtype=np.int64)
        self._value_seq += n
        return (v % (1 << 31)).astype(np.int32)

    def batches(self, num_ops: int) -> Iterator[tuple]:
        """Yield grouped op batches totalling ``num_ops`` ops."""
        mix = self.spec
        kinds = np.array(["read", "update", "insert", "scan", "rmw"])
        probs = np.array([mix.read, mix.update, mix.insert, mix.scan, mix.rmw])
        remaining = int(num_ops)
        while remaining > 0:
            b = min(self.batch, remaining)
            remaining -= b
            draw = self.rng.choice(len(kinds), size=b, p=probs)
            counts = np.bincount(draw, minlength=len(kinds))
            for kind, count in zip(kinds, counts):
                if not count:
                    continue
                if kind == "insert":
                    idx = np.arange(self.inserted, self.inserted + count)
                    self.inserted += int(count)
                    yield ("insert", key_of(idx), self._next_values(count))
                    continue
                ranks = self.zipf.sample(count)
                idx = self._ranks_to_indices(ranks)
                if kind == "scan":
                    spans = idx[:, None] + np.arange(self.scan_len)[None, :]
                    spans %= self.inserted
                    yield ("scan", key_of(spans.reshape(-1)), None)
                elif kind == "read":
                    yield ("read", key_of(idx), None)
                else:  # update / rmw — rmw's read half is the driver's job
                    yield (kind, key_of(idx), self._next_values(count))
