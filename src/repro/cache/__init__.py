"""KV-cache subsystem — serving-cache semantics over the versioned table.

The memcached/online-cache scenario on top of ``repro.core``: upsert
(insert-or-replace) resolved through the delta/tombstone machinery,
per-row TTLs on the state's logical clock, policy-driven eviction that
actually reclaims capacity, and a YCSB-style mixed-workload generator to
drive it all through the serving stack.

* :class:`KVCache` — the eager cache facade (put/get/delete/advance/
  maintain) over one ``TableState``.
* :mod:`repro.cache.workload` — zipfian YCSB-A–F op-stream generators.
"""
from repro.cache.kvcache import KVCache
from repro.cache.workload import (
    WORKLOADS,
    WorkloadSpec,
    YCSBWorkload,
    ZipfianGenerator,
    key_of,
)

__all__ = [
    "KVCache",
    "WORKLOADS",
    "WorkloadSpec",
    "YCSBWorkload",
    "ZipfianGenerator",
    "key_of",
]
