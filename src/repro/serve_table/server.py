"""TableServer — snapshot-swapped reads over a mutating distributed table.

The serving loop the ROADMAP's "background compaction" item asks for:

* **Readers** always execute against the last *published*
  :class:`~repro.serve_table.snapshot.Snapshot` — an immutable
  ``TableState`` behind a wait-free reference read — through the
  :class:`~repro.serve_table.batcher.MicroBatcher` (pow2-bucketed static
  shapes, cached plan executors).  Reads never block on mutation or
  compaction: a fold can take as long as it likes, the read path keeps
  hitting the previous snapshot until the new one is swapped in.
* A **writer loop** pops queued insert/delete batches, applies them to a
  private *shadow* state (``TableState`` mutations are functional — the
  published snapshot is never touched), and publishes the result with a
  fresh seqno.
* **Incremental background compaction**: between write batches the writer
  evaluates a :class:`~repro.core.maintenance.CompactionPolicy` against
  the shadow's stats and runs :func:`~repro.core.maintenance.fold_oldest`
  — a layer-local, zero-collective fold of the oldest deltas — either
  inline (``maintain()``) or on a worker thread (``fold_async()``) while
  reads keep flowing.  Policy escalations (tombstone pressure) run the
  full live-count-sized ``compact()`` instead, which also re-flattens the
  base arrays that incremental folds let grow.

Threading contract: one writer driver (either the embedded ``start()``
thread or an external caller invoking ``step()``/``maintain()``) plus any
number of reader threads.  Readers never wait on writers or folds: the
snapshot fetch is a wait-free reference read, and the only reader-side
lock is the micro-batcher's own batch lock (readers serialize against
*each other* for the duration of a fused batch — shared plan caches —
which costs nothing real since jax execution is dispatch-serialized
anyway).  Writer state (shadow, queue) is mutex-guarded; while a
background fold is in flight the writer defers new applications (writes
queue up) so the fold's rebase is trivially consistent.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import maintenance, plans
from repro.core.hashgraph import EMPTY_KEY
from repro.core.maintenance import CompactionPolicy, TableStats
from repro.core.state import empty_tombstones
from repro.obs.registry import MetricsRegistry, RegistrySnapshot
from repro.serve_table.batcher import BatcherStats, MicroBatcher
from repro.serve_table.snapshot import Snapshot, SnapshotRegistry


@dataclasses.dataclass(frozen=True)
class ServerStats:
    """One coherent sample of the server's counters and state signals."""

    seqno: int  # last published snapshot
    pending_writes: int  # queued, not yet applied
    writes_applied: int  # insert/delete batches applied to the shadow
    reads: int  # individual read requests served
    read_batches: int  # coalesced read executions
    folds: int  # incremental fold_oldest passes
    full_compacts: int  # full compact() escalations
    fold_seconds_total: float
    last_fold_seconds: float
    fold_in_flight: bool  # a background fold is currently running
    skew_fallbacks: int  # inserts routed incoherent by the skew guard
    last_error: Optional[str]  # last write-application failure (None = healthy)
    batcher: BatcherStats
    shadow: TableStats  # maintenance signals of the writer's state
    warmup: Optional[object] = None  # WarmupStats once warm() ran, else None


class TableServer:
    """Serve reads from published snapshots while a writer loop mutates.

    ``keys``/``values`` build the initial table (the ``table.init``
    contract).  ``policy`` defaults to folding ``fold_k`` oldest layers
    whenever the delta ring reaches ``table.max_deltas`` (so an insert can
    never hit the ring-full error) or tombstone pressure escalates to a
    full compaction.  ``window`` is the latency/throughput knob: the
    writer applies at most ``window`` queued mutation batches per step
    before publishing, and readers using :meth:`query_many` /
    :meth:`retrieve_many` choose their own coalescing width.
    """

    def __init__(
        self,
        table,
        keys,
        values=None,
        *,
        policy: Optional[CompactionPolicy] = None,
        batcher: Optional[MicroBatcher] = None,
        window: int = 8,
        write_bucket: Optional[int] = None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        self.table = table
        self.write_bucket: Optional[int] = None
        if write_bucket is not None:
            wb = int(write_bucket)
            if wb < 1 or wb & (wb - 1):
                raise ValueError("write_bucket must be a power of two")
            if wb % table.num_devices:
                raise ValueError(
                    "write_bucket must be a multiple of the device count"
                )
            self.write_bucket = wb
        state = table.init(*self._pad_insert(keys, values))
        if self.write_bucket is not None:
            # Shape-stable serving pre-grows the tombstone buffer (init
            # leaves it at zero capacity until the first delete): one
            # tombstone structure for the state's whole life means one AOT
            # executor per (bucket, depth) instead of two.
            state = dataclasses.replace(
                state,
                tombstones=empty_tombstones(
                    table.tombstone_capacity, table.schema.key_lanes
                ),
            )
        self.registry = SnapshotRegistry(state)
        self.policy = policy or CompactionPolicy(
            max_delta_depth=table.max_deltas
        )
        # ONE MetricsRegistry per server: the batcher, the AOT grid, any
        # front ends, and the maintenance recorder all write here, so
        # metrics()/render_prometheus export the whole stack coherently.
        # (Attribute named metrics_registry because metrics() is the
        # snapshot API.)
        self.metrics_registry = metrics if metrics is not None else MetricsRegistry()
        self.batcher = batcher or MicroBatcher(table)
        self.batcher.bind_registry(self.metrics_registry)
        self.window = max(1, int(window))
        self._shadow = state
        self._writes: deque = deque()
        self._lock = threading.Lock()  # queue + shadow swaps
        # Serializes every shadow mutation (step application vs background
        # fold): a fold holds it for its whole duration, so a step that was
        # already mid-application when fold_async was called finishes first
        # and the fold reads the post-step shadow — applied writes are never
        # discarded.  Readers never touch it.
        self._writer_mutex = threading.Lock()
        self._fold_thread: Optional[threading.Thread] = None
        self._writer_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._last_error: Optional[str] = None
        self._fold_error: Optional[str] = None
        self._skew_base = table.skew_fallbacks
        reg = self.metrics_registry
        self._c_reads = reg.counter(
            "serve_reads_total", help="Individual read requests served."
        )
        self._c_read_batches = reg.counter(
            "serve_read_batches_total", help="Coalesced read executions."
        )
        self._c_writes_applied = reg.counter(
            "serve_writes_applied_total",
            help="Insert/delete/upsert batches applied to the shadow.",
        )
        # Same instruments maintenance.record_fold targets (get-or-create).
        self._c_folds = reg.counter(
            "maintenance_folds_total", labels={"kind": "fold"}
        )
        self._c_full_compacts = reg.counter(
            "maintenance_folds_total", labels={"kind": "full"}
        )
        self._g_last_fold = reg.gauge(
            "serve_last_fold_seconds", help="Duration of the most recent fold."
        )

    # -- write path (admission) ----------------------------------------------
    def _pad_insert(self, keys, values, bucket: Optional[int] = None):
        """Device-align one mutation batch: EMPTY-pad keys, -1-pad values.

        The build/insert contract wants ``N % devices == 0``; sentinel rows
        route round-robin, land in trash buckets, and are invisible to
        every read — the same padding idiom as the exchange.  With
        ``bucket`` the batch is padded all the way to that fixed size, so
        every delta it builds shares one geometry (the AOT grid contract).
        """
        schema = self.table.schema
        keys = schema.pack_keys(keys)
        n = keys.shape[0]
        if values is None:
            values = np.arange(n, dtype=np.int32)
            if schema.value_cols > 1:
                values = np.stack(
                    [values] * schema.value_cols, axis=1
                )
        values = schema.pack_values(values)
        pad = (-n) % self.table.num_devices if bucket is None else bucket - n
        if pad:
            kshape = (pad,) + tuple(keys.shape[1:])
            vshape = (pad,) + tuple(values.shape[1:])
            keys = jnp.concatenate(
                [keys, jnp.full(kshape, EMPTY_KEY, jnp.uint32)]
            )
            values = jnp.concatenate(
                [values, jnp.full(vshape, -1, jnp.int32)]
            )
        return keys, values

    def submit_insert(self, keys, values=None) -> None:
        """Queue one insert batch (applied by the writer loop).

        With ``write_bucket`` set, the batch is chunked to the bucket size
        and each chunk EMPTY-padded up to it: every queued insert then
        builds a delta of identical geometry, which is what lets
        :meth:`warm` enumerate (and AOT-compile) every state structure the
        writer can reach.
        """
        schema = self.table.schema
        keys = schema.pack_keys(keys)
        n = keys.shape[0]
        if values is None:
            values = np.arange(n, dtype=np.int32)
            if schema.value_cols > 1:
                values = np.stack([values] * schema.value_cols, axis=1)
        values = schema.pack_values(values)
        wb = self.write_bucket
        if wb is None:
            ops = [self._pad_insert(keys, values)]
        else:
            ops = [
                self._pad_insert(keys[i : i + wb], values[i : i + wb], bucket=wb)
                for i in range(0, max(1, n), wb)
            ]
        with self._lock:
            for k, v in ops:
                self._writes.append(("insert", k, v, None))

    def submit_delete(self, keys) -> None:
        """Queue one delete batch (applied by the writer loop).

        Batches are chunked to at most half the tombstone capacity so the
        per-op policy check between chunks can escalate (freeing the
        buffer) before any chunk could overflow it — one oversized batch
        must not silently lose deletes.  Residual overflow under an
        unusually permissive policy still surfaces in
        ``stats().shadow.tombstone_dropped``.
        """
        keys = self.table.schema.pack_keys(keys)
        chunk = max(1, self.table.tombstone_capacity // 2)
        with self._lock:
            for i in range(0, max(1, keys.shape[0]), chunk):
                self._writes.append(("delete", keys[i : i + chunk], None, None))

    def submit_upsert(self, keys, values=None, *, ttl: Optional[int] = None) -> None:
        """Queue one insert-or-replace batch (KV semantics; see
        :meth:`DistributedHashTable.upsert`).

        The batch is keep-last deduplicated at admission (one winner per
        key) and chunked like inserts; each chunk applies as one
        delete-prior-versions + one bucket-padded delta build, so with
        ``write_bucket`` set every upsert delta shares the warmed insert
        geometry — AOT reads never retrace.  ``ttl`` schedules expiry of
        the new version at ``now + ttl`` on the server's logical clock
        (:meth:`advance`).
        """
        schema = self.table.schema
        keys = schema.pack_keys(keys)
        n = keys.shape[0]
        if values is None:
            values = np.arange(n, dtype=np.int32)
            if schema.value_cols > 1:
                values = np.stack([values] * schema.value_cols, axis=1)
        values = schema.pack_values(values)
        # Keep-last dedup at admission: KV semantics demand one winner per
        # key per batch, and deduping host-side keeps the applied chunks
        # disjoint (cross-chunk duplicates would re-tombstone fresh rows).
        kn = np.asarray(keys)
        vn = np.asarray(values)
        rows = kn if kn.ndim == 2 else kn[:, None]
        _, first = np.unique(rows[::-1], axis=0, return_index=True)
        keep = np.sort(rows.shape[0] - 1 - first)
        keep = keep[~np.all(rows[keep] == np.uint32(EMPTY_KEY), axis=1)]
        if keep.shape[0] == 0:
            return
        keys = jnp.asarray(kn[keep])
        values = jnp.asarray(vn[keep])
        chunk = self.write_bucket or max(1, keys.shape[0])
        chunk = min(chunk, max(1, self.table.tombstone_capacity // 2))
        with self._lock:
            for i in range(0, keys.shape[0], chunk):
                self._writes.append(
                    ("upsert", keys[i : i + chunk], values[i : i + chunk], ttl)
                )

    def advance(self, now) -> None:
        """Advance the serving logical clock to ``now``; publish.

        TTL expiry is resolved against this clock at read time, so
        advancing it is how upserted rows age out of every later read.
        The clock is a *data* field of the state (no structure change —
        AOT executors keep matching); monotone by contract.  Blocks
        briefly on the shadow-mutation mutex (a fold in flight finishes
        first).
        """
        with self._writer_mutex:
            self._shadow = self._shadow.advance(now)
            self.registry.publish(self._shadow)

    def pending(self) -> int:
        return len(self._writes)

    def step(self) -> int:
        """Apply up to ``window`` queued mutations to the shadow; publish.

        Returns the number of batches applied (0 while a background fold
        is in flight — writes stay queued, reads stay live).  Runs the
        compaction policy *before* every mutation, so neither the delta
        ring (inserts) nor the tombstone buffer (delete runs) can overflow
        mid-stream while the policy's triggers are enabled.
        """
        # Non-blocking acquire keeps the documented contract even when a
        # fold wins the race between the flag check and the mutex: the
        # writes stay queued and the caller gets 0 instead of parking for
        # the whole fold.
        if self.fold_in_flight or not self._writer_mutex.acquire(blocking=False):
            return 0
        try:
            applied = 0
            # Lazy per-window stats: the device-read signals (tombstone
            # fill/overflow, drop tallies) are collected once per window and
            # re-read only after the ops that can move them (deletes,
            # folds); the delta-depth trigger is tracked host-side.  An idle
            # step() never touches the device.
            stats = None
            while applied < self.window:
                with self._lock:
                    if not self._writes:
                        break
                    op = self._writes.popleft()
                try:
                    if stats is None:
                        stats = self._shadow.stats()
                    if self.policy.due(stats):
                        self._fold_shadow()
                        stats = self._shadow.stats()
                    kind, keys, values, ttl = op
                    if kind == "insert":
                        self._shadow = self.table.insert(self._shadow, keys, values)
                        stats = dataclasses.replace(
                            stats, delta_depth=len(self._shadow.deltas)
                        )
                    elif kind == "upsert":
                        self._apply_upsert(keys, values, ttl)
                        stats = None  # delta depth AND tombstones moved
                    else:
                        self._shadow = self.table.delete(self._shadow, keys)
                        stats = None  # tombstone signals moved: re-read
                except Exception as e:
                    # An acknowledged write must never vanish: requeue it at
                    # the front, surface the error in stats, and re-raise
                    # (the embedded loop stops loudly; an external driver
                    # sees the exception directly).
                    with self._lock:
                        self._writes.appendleft(op)
                    self._last_error = f"{type(e).__name__}: {e}"
                    if applied:
                        self.registry.publish(self._shadow)
                    raise
                self._c_writes_applied.inc()
                applied += 1
            if applied:
                self.registry.publish(self._shadow)
            return applied
        finally:
            self._writer_mutex.release()

    def _apply_upsert(self, keys, values, ttl) -> None:
        """Apply one (deduped, unpadded) upsert chunk to the shadow.

        The delete-then-insert of ``table.upsert``, with the insert padded
        to ``write_bucket`` when set — the upsert delta then shares the
        warmed insert geometry, so the state signature stays inside the
        AOT grid and reads never retrace.  Only *real* keys are
        tombstoned (padding sentinels would burn buffer slots).
        """
        shadow = self.table.delete(self._shadow, keys)  # epoch d
        k_pad, v_pad = self._pad_insert(keys, values, bucket=self.write_bucket)
        shadow = self.table.insert(shadow, k_pad, v_pad)  # epoch d + 1
        if ttl is not None:
            shadow = dataclasses.replace(
                shadow,
                tombstones=shadow.tombstones.push(
                    keys,
                    epoch=len(shadow.deltas),
                    expires=shadow.tombstones.now + jnp.int32(ttl),
                ),
            )
        self._shadow = shadow

    # -- maintenance (off the read path) --------------------------------------
    def maintain(self) -> bool:
        """Fold the shadow now if the policy says it is due; publish.

        Synchronous variant for deterministic drivers; the background
        variant is :meth:`fold_async`.  Returns True iff a fold ran.
        """
        if self.fold_in_flight or not self._writer_mutex.acquire(blocking=False):
            return False
        try:
            if not self.policy.due(self._shadow.stats()):
                return False
            ran = self._fold_counts()
            self._fold_shadow()
            if self._fold_counts() == ran:
                return False  # due but nothing actionable: no phantom publish
            self.registry.publish(self._shadow)
            return True
        finally:
            self._writer_mutex.release()

    def _fold_shadow(self) -> None:
        stats = self._shadow.stats()
        escalate = self.policy.escalates(stats)
        layer_live = None
        if self.policy.fold_k is None and not escalate and stats.delta_depth:
            # Stats-driven sizing: one counts round measures per-layer live
            # rows and the policy folds the longest cold prefix first.
            layer_live = maintenance.collect_layer_live(self._shadow)
        k = self.policy.fold_amount(stats, layer_live)
        if not escalate and not k:
            return
        # An incoherent shadow (skew-guard fallback) cannot fold locally —
        # fold_oldest would full-compact anyway; route it here so the pause
        # is attributed to full_compacts, not folds.
        if escalate or k >= stats.delta_depth or not self._shadow.coherent:
            # Escalation: the full rebuild frees every tombstone (valid even
            # at delta depth 0) and re-flattens the base arrays that
            # incremental folds let grow.
            self._apply_fold(self.table.compact, full=True)
        else:
            self._apply_fold(lambda s: maintenance.fold_oldest(s, k), full=False)

    def _fold_counts(self) -> tuple:
        return (self._c_folds.value, self._c_full_compacts.value)

    def _apply_fold(self, fold_fn, *, full: bool) -> None:
        """Run one timed fold of the shadow and attribute the counter."""
        t0 = time.perf_counter()
        rows_before = maintenance.allocated_rows(self._shadow)
        self._shadow = fold_fn(self._shadow)
        if full and self.write_bucket is not None:
            # compact() resets the tombstone buffer to zero capacity when
            # nothing was pending; shape-stable serving re-grows it
            # immediately (clock preserved) so the state structure — and
            # with it the AOT executor keys — stays fixed.  With pending
            # TTL entries compact() already returned the capacity-preserving
            # remap, which must NOT be overwritten (the entries guard rows
            # that survived into the new base).
            ts = self._shadow.tombstones
            if ts.capacity != self.table.tombstone_capacity:
                self._shadow = dataclasses.replace(
                    self._shadow,
                    tombstones=empty_tombstones(
                        self.table.tombstone_capacity,
                        self.table.schema.key_lanes,
                        now=ts.now,
                    ),
                )
        dt = time.perf_counter() - t0
        # One recording site per fold: pause time, counter by kind, and
        # reclaimed rows all land in the shared registry.
        maintenance.record_fold(
            self.metrics_registry,
            kind="full" if full else "fold",
            seconds=dt,
            rows_before=rows_before,
            rows_after=maintenance.allocated_rows(self._shadow),
        )
        self._g_last_fold.set(dt)

    def fold_async(self, k: Optional[int] = None) -> threading.Thread:
        """Start one background fold of the shadow; reads keep flowing.

        The fold runs on a worker thread holding the shadow-mutation mutex
        for its whole duration: a ``step()`` that was mid-application when
        the fold started finishes first (the fold then reads the post-step
        shadow — acknowledged writes are never discarded), later steps
        defer until the fold lands (writes queue), and the folded state is
        published atomically on completion.  Reads never touch the mutex.
        Returns the thread (join it or poll :attr:`fold_in_flight`).
        """
        if self.fold_in_flight:
            raise RuntimeError("a background fold is already in flight")

        def run():
            try:
                with self._writer_mutex:
                    ran_before = self._fold_counts()
                    if k is None:
                        # Policy-driven: same decision tree as inline
                        # maintenance (including the depth-0
                        # tombstone-pressure escalation).
                        self._fold_shadow()
                    else:
                        kk = min(k, len(self._shadow.deltas))
                        if kk <= 0:
                            return
                        if self._shadow.coherent and kk < len(self._shadow.deltas):
                            self._apply_fold(
                                lambda s: maintenance.fold_oldest(s, kk), full=False
                            )
                        else:  # fold-all or incoherent: full rebuild either way
                            self._apply_fold(self.table.compact, full=True)
                    if self._fold_counts() != ran_before:
                        self.registry.publish(self._shadow)
            except Exception as e:
                # A dead fold thread must never be silent: the failure is
                # surfaced on stats().last_error and re-raised by drain().
                # The published snapshot stays at the last good seqno and
                # the read path keeps serving it.
                self._fold_error = f"{type(e).__name__}: {e}"
                self._last_error = self._fold_error

        t = threading.Thread(target=run, name="serve-table-fold", daemon=True)
        self._fold_thread = t
        t.start()
        return t

    @property
    def fold_in_flight(self) -> bool:
        t = self._fold_thread
        return t is not None and t.is_alive()

    # -- read path (never blocks on writes/folds) ------------------------------
    def current(self) -> Snapshot:
        """The snapshot reads execute against right now."""
        return self.registry.current()

    def query_many(self, requests) -> tuple[list, int]:
        """Merged multiplicities per request against the current snapshot.

        Returns ``(results, seqno)`` — one int32 array per request plus
        the seqno of the snapshot that served them (every key of every
        request in the batch observes that one consistent version).
        """
        snap = self.registry.current()
        out = self.batcher.query_many(snap.state, requests)
        self._c_reads.inc(len(requests))
        self._c_read_batches.inc()
        return out, snap.seqno

    def retrieve_many(self, requests, *, per_layer_counts: bool = False):
        """Stored values per request key against the current snapshot.

        Returns ``(results, seqno)``; see
        :meth:`MicroBatcher.retrieve_many` for the result shape.
        """
        snap = self.registry.current()
        out = self.batcher.retrieve_many(
            snap.state, requests, per_layer_counts=per_layer_counts
        )
        self._c_reads.inc(len(requests))
        self._c_read_batches.inc()
        return out, snap.seqno

    def query(self, keys) -> np.ndarray:
        """Single-request convenience wrapper over :meth:`query_many`."""
        return self.query_many([keys])[0][0]

    # -- AOT warmup ---------------------------------------------------------------
    def warm(self, **kwargs):
        """AOT-compile the read-executor grid before admitting traffic.

        Thin wrapper over :func:`repro.serve_table.aot.warm_server` (see it
        for the knobs); requires ``write_bucket``.  After this, live reads
        whose (bucket, state structure) fall inside the warmed grid run
        pre-compiled XLA executables — zero tracing, zero compilation —
        and coverage is visible in ``stats().warmup``.
        """
        from repro.serve_table.aot import warm_server

        return warm_server(self, **kwargs)

    # -- embedded writer loop ---------------------------------------------------
    def start(self, poll_interval: float = 0.001) -> None:
        """Run the writer loop on a daemon thread until :meth:`stop`.

        A write that fails to apply stops the loop (the failed batch stays
        at the head of the queue) and surfaces as ``stats().last_error`` —
        never a silently dead thread.
        """
        if self._writer_thread is not None and self._writer_thread.is_alive():
            raise RuntimeError("writer loop already running")
        self._stop.clear()

        def loop():
            while not self._stop.is_set():
                try:
                    applied = self.step()
                except Exception:
                    self._stop.set()  # error is in stats().last_error
                    return
                if not applied:
                    time.sleep(poll_interval)

        self._writer_thread = threading.Thread(
            target=loop, name="serve-table-writer", daemon=True
        )
        self._writer_thread.start()

    def stop(self) -> None:
        """Stop the writer loop (queued writes stay queued)."""
        self._stop.set()
        if self._writer_thread is not None:
            self._writer_thread.join()
            self._writer_thread = None

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every queued write has been applied and published.

        Works with the embedded writer loop (waits) or without one (drives
        :meth:`step` inline); in-flight background folds are joined.

        Never exits silently with work still queued:

        * raises :class:`TimeoutError` (with the number of still-pending
          batches) if the queue has not emptied by ``timeout``;
        * raises :class:`RuntimeError` promptly — not at timeout — if the
          embedded writer it is waiting on stops (explicit :meth:`stop`,
          or a write failure killing the loop) or a background fold
          crashed, carrying ``last_error`` when one is recorded.
        """
        deadline = time.monotonic() + timeout
        embedded = (
            self._writer_thread is not None and self._writer_thread.is_alive()
        )
        while True:
            if self._fold_error is not None:
                raise RuntimeError(
                    f"background fold failed: {self._fold_error}"
                )
            pending = self.pending()
            if not pending and not self.fold_in_flight and self._settled():
                return
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"drain timed out with {pending} pending "
                    f"batch{'es' if pending != 1 else ''}"
                    + (" and a fold in flight" if self.fold_in_flight else "")
                )
            if self.fold_in_flight:
                t = self._fold_thread
                if t is not None:
                    t.join(
                        timeout=min(0.05, max(0.0, deadline - time.monotonic()))
                    )
                continue
            writer_alive = (
                self._writer_thread is not None and self._writer_thread.is_alive()
            )
            if embedded and (self._stop.is_set() or not writer_alive):
                # The writer this drain was parked on is gone: stop() was
                # called, or a failing write batch killed the loop.  Waiters
                # unblock immediately instead of spinning to the timeout.
                why = (
                    f"writer failed: {self._last_error}"
                    if self._last_error
                    else "server stopped"
                )
                raise RuntimeError(
                    f"drain unblocked ({why}) with {pending} pending "
                    f"batch{'es' if pending != 1 else ''}"
                )
            if writer_alive:
                time.sleep(0.0005)
            else:
                self.step()

    def _settled(self) -> bool:
        """True once applied work is *published*, not merely dequeued.

        ``pending()`` drops to 0 the moment the writer pops the last op —
        before the mutation lands and the snapshot swaps.  Briefly taking
        the shadow-mutation mutex proves no step/fold is mid-application
        (both publish before releasing it), closing the drain-returns-early
        race.
        """
        if not self._writer_mutex.acquire(timeout=0.01):
            return False
        try:
            return not self.pending() and not self.fold_in_flight
        finally:
            self._writer_mutex.release()

    # -- metrics ----------------------------------------------------------------
    def stats(self) -> ServerStats:
        """A coherent host-side sample of every serving counter.

        The view is a thin wrapper over ONE registry snapshot (a single
        lock acquisition observes every counter at the same instant — no
        field-by-field tearing between, say, ``reads`` and
        ``read_batches``); the shadow's :class:`TableStats` is the usual
        few-scalar device read on top.
        """
        snap = self.metrics_registry.snapshot()
        hist_fold = snap.histogram("maintenance_fold_seconds", {"kind": "fold"})
        hist_full = snap.histogram("maintenance_fold_seconds", {"kind": "full"})
        fold_seconds = (hist_fold.sum if hist_fold else 0.0) + (
            hist_full.sum if hist_full else 0.0
        )
        return ServerStats(
            seqno=self.registry.seqno,
            pending_writes=self.pending(),
            writes_applied=int(snap.value("serve_writes_applied_total")),
            reads=int(snap.value("serve_reads_total")),
            read_batches=int(snap.value("serve_read_batches_total")),
            folds=int(snap.value("maintenance_folds_total", {"kind": "fold"})),
            full_compacts=int(
                snap.value("maintenance_folds_total", {"kind": "full"})
            ),
            fold_seconds_total=fold_seconds,
            last_fold_seconds=float(snap.value("serve_last_fold_seconds", default=0.0)),
            fold_in_flight=self.fold_in_flight,
            skew_fallbacks=self.table.skew_fallbacks - self._skew_base,
            last_error=self._last_error,
            batcher=self.batcher.stats(snapshot=snap),
            shadow=self._shadow.stats(),
            warmup=(
                self.batcher.executors.stats()
                if self.batcher.executors is not None
                else None
            ),
        )

    def metrics(self, refresh: bool = True) -> RegistrySnapshot:
        """One atomic sample of the server's whole metrics registry.

        With ``refresh`` (default) the state-derived gauges — seqno, queue
        depths, drop tallies, delta depth, the jit dispatch-cache size —
        are re-read first (costs the shadow's few-scalar device sync);
        ``refresh=False`` samples the counters as-is.  Feed the result to
        :func:`repro.obs.render_prometheus` / :func:`repro.obs.render_jsonl`
        or assert on it directly (``benchmarks.common.assert_clean_run``).
        """
        if refresh:
            reg = self.metrics_registry
            sh = self._shadow.stats()
            reg.gauge("serve_seqno", help="Last published snapshot seqno.").set(
                self.registry.seqno
            )
            reg.gauge(
                "serve_pending_writes", help="Queued, not yet applied writes."
            ).set(self.pending())
            reg.gauge(
                "serve_fold_in_flight", help="1 while a background fold runs."
            ).set(int(self.fold_in_flight))
            reg.gauge(
                "serve_delta_depth", help="Live delta layers on the shadow."
            ).set(sh.delta_depth)
            reg.gauge(
                "serve_dropped_rows",
                help="Rows lost to capacity anywhere in the stack (want 0).",
            ).set(sh.num_dropped)
            reg.gauge(
                "serve_tombstone_dropped",
                help="Deletes lost to tombstone capacity (want 0).",
            ).set(sh.tombstone_dropped)
            reg.gauge(
                "serve_skew_fallbacks",
                help="Inserts routed incoherent by the skew guard.",
            ).set(self.table.skew_fallbacks - self._skew_base)
            reg.gauge(
                "jit_dispatch_cache_size",
                help="exec_query jit cache entries (flat once warmed).",
            ).set(plans.exec_query._cache_size())
        return self.metrics_registry.snapshot()
