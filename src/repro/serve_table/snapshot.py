"""Seqno-stamped, atomically-published table snapshots.

The serving design splits the table into two roles:

* a **published snapshot** — the immutable :class:`~repro.core.state.
  TableState` every reader queries.  States are functional pytrees, so a
  reader holding a snapshot can never observe a torn write: the arrays it
  references are never mutated, only *replaced* by publishing a new state.
* a **shadow state** — the writer's working copy.  Mutations (insert /
  delete / fold) build new states off the shadow and publish when a batch
  is complete.

:class:`SnapshotRegistry` is the hinge between them: ``publish`` stamps a
monotonically increasing ``seqno`` and swaps the current reference under a
lock; ``current`` is a plain reference read (atomic in CPython, lock-free)
— the read path never waits on a writer or a background compaction.  A
small history ring keeps recent seqnos inspectable for debugging and
consistency tests.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Optional

from repro.core.state import TableState


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One published version of the table: ``(seqno, state)``.

    ``seqno`` 0 is the initial build; every publish increments it.  The
    state is immutable — holding a snapshot pins a consistent view for as
    long as the reference lives, with no locking protocol on the reader.
    """

    seqno: int
    state: TableState


class SnapshotRegistry:
    """Atomic publish/read of table snapshots.

    Thread contract: any number of reader threads call :meth:`current`;
    writers serialize :meth:`publish` through the internal lock (the
    server's writer loop is single-threaded anyway, the lock makes misuse
    safe rather than fast).  Readers are wait-free — ``current`` is one
    attribute load of an immutable :class:`Snapshot`.
    """

    def __init__(self, state: TableState, *, history: int = 8):
        self._lock = threading.Lock()
        self._published = threading.Condition(self._lock)
        self._current = Snapshot(0, state)
        self._history: deque = deque([self._current], maxlen=max(1, history))

    def current(self) -> Snapshot:
        """The last published snapshot (wait-free reference read)."""
        return self._current

    @property
    def seqno(self) -> int:
        return self._current.seqno

    def publish(self, state: TableState) -> Snapshot:
        """Stamp ``state`` with the next seqno and swap it in atomically."""
        with self._lock:
            snap = Snapshot(self._current.seqno + 1, state)
            self._current = snap
            self._history.append(snap)
            self._published.notify_all()
            return snap

    def wait_for(self, seqno: int, timeout: Optional[float] = None) -> Snapshot:
        """Block until a snapshot with ``seqno`` or later is published.

        Read-your-writes for async callers: a writer learns the seqno its
        batch published at, hands it to a reader, and the reader parks here
        (Condition wait, no polling) until the read path is guaranteed to
        observe the write.  Returns the current snapshot (whose seqno may
        exceed the request); raises :class:`TimeoutError` on timeout.
        """
        with self._published:
            ok = self._published.wait_for(
                lambda: self._current.seqno >= seqno, timeout=timeout
            )
            if not ok:
                raise TimeoutError(
                    f"snapshot seqno {seqno} not published within {timeout}s "
                    f"(current {self._current.seqno})"
                )
            return self._current

    def recent(self, seqno: int) -> Optional[Snapshot]:
        """A recently published snapshot by seqno, if still in the ring."""
        for snap in self._history:
            if snap.seqno == seqno:
                return snap
        return None
