"""Query micro-batching — ragged request streams onto cached static shapes.

The HashGraph lineage gets its throughput from large *static-shaped*
batches: every executor in ``repro.core.plans`` is a jitted program keyed
on ``(table, capacities, query count, state structure)``.  A serving
workload is the opposite shape — a stream of small, ragged query/retrieve
requests, each of which would trace (and compile) its own executor if
executed naively.

:class:`MicroBatcher` is the admission layer between the two: it

1. **coalesces** a batch of variable-size requests into one flat query
   array,
2. **pads** it with EMPTY sentinels up to a **pow2-bucketed** static size
   (sentinel queries cost nothing: they are masked to zero counts by the
   routing layer, exactly like exchange padding), so the executor cache
   key space is logarithmic in the request-size range,
3. executes ONE fused plan over the whole batch, and
4. **scatters** the CSR results back per request.

Output capacities are bucketed the same way (next pow2 of the planning
round's exact need), and the counts-planning sync runs once per bucket —
steady traffic reuses compiled executors with zero per-request retraces
(``cache_hits`` / ``cache_misses`` make this observable; tests assert on
it).  Overflow (``num_dropped > 0`` from data drift within a bucket) is
handled by bounded capacity doubling, never silently.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashgraph import EMPTY_KEY
from repro.core.state import as_state
from repro.core.table import retrieval_to_lists
from repro.obs.registry import MetricsRegistry, RegistrySnapshot
from repro.utils import cdiv


@dataclasses.dataclass(frozen=True)
class BatcherStats:
    """Counters of one :class:`MicroBatcher` (monotone, host-side)."""

    requests: int  # individual requests served
    batches: int  # coalesced executions
    cache_hits: int  # executions reusing a cached (bucket, caps) plan
    cache_misses: int  # executions that had to build (and trace) a plan
    overflow_retries: int  # capacity-doubling re-executions
    keys_served: int  # real (unpadded) query keys
    keys_padded: int  # EMPTY sentinel keys shipped for shape bucketing

    @property
    def pad_fraction(self) -> float:
        total = self.keys_served + self.keys_padded
        return self.keys_padded / total if total else 0.0


@dataclasses.dataclass
class PendingBatch:
    """One dispatched (not yet gathered) fused query execution.

    ``counts`` is the device array jax has already enqueued; nothing has
    blocked on it yet.  :meth:`scatter` performs the host transfer (blocks
    until the device finishes) and slices results back per request — the
    front end runs it on a separate thread so the device crunches batch
    ``n+1`` while the host scatters batch ``n``.
    """

    counts: object  # enqueued device array
    bounds: list  # (start, stop) per request in the flat batch
    seqno: int  # snapshot the batch executed against
    aot: bool  # served by an AOT-warmed executable (no jit dispatch)

    @property
    def bucket(self) -> int:
        """The static batch size this execution was padded to."""
        return int(self.counts.shape[0])

    def wait(self) -> "PendingBatch":
        """Block until the device result is ready; no host transfer yet.

        Splitting the device wait from :meth:`scatter`'s host-side work is
        what lets a tracing front end attribute time to the *device* phase
        separately from the scatter phase.
        """
        jax.block_until_ready(self.counts)
        return self

    def scatter(self) -> list:
        c = np.asarray(self.counts)
        return [c[a:b] for a, b in self.bounds]


class MicroBatcher:
    """Coalesce ragged read requests into plan-cache-hitting static batches.

    ``min_bucket`` floors the padded batch size (also the compile-cache
    floor); buckets are the next power of two of the coalesced total,
    rounded up to a device multiple.  One batcher serves one table config.
    Concurrent readers are safe but serialize through an internal lock for
    the duration of a batch — the plan caches, working capacities, and
    counters are shared mutable state (two threads racing a fresh bucket
    would otherwise both run the blocking planning round and clobber each
    other's doubled capacities); jax execution itself is serialized by the
    dispatch lock anyway, so the batch lock costs no real parallelism.
    """

    # metric name -> BatcherStats field, in declaration order
    _METRICS = {
        "batch_requests_total": "requests",
        "batch_executions_total": "batches",
        "batch_cache_hits_total": "cache_hits",
        "batch_cache_misses_total": "cache_misses",
        "batch_overflow_retries_total": "overflow_retries",
        "batch_keys_served_total": "keys_served",
        "batch_keys_padded_total": "keys_padded",
    }

    def __init__(
        self,
        table,
        *,
        min_bucket: int = 64,
        max_retries: int = 4,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.table = table
        self.min_bucket = max(int(min_bucket), table.num_devices)
        self.max_retries = int(max_retries)
        # AOT executor grid (repro.serve_table.aot.ExecutorGrid), attached
        # by warm_server(): consulted before the jit plan caches so warmed
        # traffic never touches jax's dispatch machinery.
        self.executors = None
        self._batch_lock = threading.Lock()
        self._qplans = {}  # bucket -> QueryPlan
        self._rplans = {}  # (bucket, out_cap, seg_cap, per_layer) -> RetrievePlan
        self._caps = {}  # bucket -> (out_cap, seg_cap) current working caps
        # Counters live in a MetricsRegistry (private by default; a hosting
        # TableServer rebinds the batcher onto its own via bind_registry so
        # one registry exports the whole stack).
        self.metrics_registry = registry if registry is not None else MetricsRegistry()
        self._make_counters()

    def _make_counters(self) -> None:
        self._counters = {
            name: self.metrics_registry.counter(
                name, help=f"MicroBatcher {field.replace('_', ' ')}."
            )
            for name, field in self._METRICS.items()
        }

    def bind_registry(self, registry: MetricsRegistry) -> None:
        """Re-home the batcher's counters onto ``registry``.

        Counts accumulated so far carry over (incremented into the new
        registry's counters), so adopting a standalone batcher into a
        server loses nothing.
        """
        with self._batch_lock:
            old = self.metrics_registry.snapshot()
            self.metrics_registry = registry
            self._make_counters()
            for name in self._METRICS:
                carried = int(old.value(name))
                if carried:
                    self._counters[name].inc(carried)

    # -- shape bucketing -----------------------------------------------------
    def bucket_size(self, total: int) -> int:
        """Static batch size for ``total`` coalesced keys: pow2, device-aligned."""
        b = max(self.min_bucket, total)
        b = 1 << (b - 1).bit_length()
        d = self.table.num_devices
        return cdiv(b, d) * d

    def _coalesce(self, requests: Sequence):
        """Pack + concatenate + EMPTY-pad the request key arrays.

        Returns ``(padded_queries, bounds)`` where ``bounds[i]`` is the
        ``(start, stop)`` slice of request ``i`` in the flat batch.
        """
        packed = [self.table.schema.pack_keys(r) for r in requests]
        bounds = []
        off = 0
        for p in packed:
            bounds.append((off, off + p.shape[0]))
            off += p.shape[0]
        bucket = self.bucket_size(off)
        lanes = self.table.schema.key_lanes
        shape = (bucket,) if lanes == 1 else (bucket, lanes)
        flat = np.full(shape, EMPTY_KEY, np.uint32)
        cat = np.concatenate([np.asarray(p) for p in packed], axis=0)
        flat[:off] = cat
        self._counters["batch_keys_served_total"].inc(off)
        self._counters["batch_keys_padded_total"].inc(bucket - off)
        return jnp.asarray(flat), bounds

    # -- read paths ----------------------------------------------------------
    def dispatch_query(self, state, requests: Sequence, seqno: int = -1) -> PendingBatch:
        """Enqueue one fused query execution; return before results land.

        The returned :class:`PendingBatch` carries the enqueued device
        array — call :meth:`PendingBatch.scatter` (outside the batch lock,
        on any thread) to block on the device and slice results back per
        request.  Splitting dispatch from scatter is what lets the async
        front end overlap host-side scatter of batch ``n`` with the device
        execution of batch ``n+1``.

        An attached AOT :attr:`executors` grid is consulted first: a hit
        calls the pre-compiled XLA executable directly (jit's dispatch
        cache is never touched — AOT executables don't live there); a miss
        falls back to the cached jit plans and is counted on the grid.
        """
        with self._batch_lock:
            st = as_state(self.table, state)
            q, bounds = self._coalesce(requests)
            bucket = q.shape[0]
            grid = self.executors
            handle = grid.query_handle(st, bucket) if grid is not None else None
            if handle is not None:
                self._counters["batch_cache_hits_total"].inc()
                counts = handle(st, q)
            else:
                plan = self._qplans.get(bucket)
                if plan is None:
                    plan = self.table.plan_query(num_queries=bucket)
                    self._qplans[bucket] = plan
                    self._counters["batch_cache_misses_total"].inc()
                else:
                    self._counters["batch_cache_hits_total"].inc()
                counts = plan(st, q)
            self._counters["batch_requests_total"].inc(len(requests))
            self._counters["batch_executions_total"].inc()
            return PendingBatch(
                counts=counts, bounds=bounds, seqno=seqno, aot=handle is not None
            )

    def query_many(self, state, requests: Sequence) -> list:
        """Merged multiplicities for each request, one fused execution.

        Returns one ``np.int32`` array per request, aligned with its keys.
        (Synchronous wrapper: dispatch + scatter back to back; the host
        transfer happens outside the batch lock.)
        """
        if not requests:
            return []
        return self.dispatch_query(state, requests).scatter()

    def retrieve_many(
        self, state, requests: Sequence, *, per_layer_counts: bool = False
    ):
        """All stored values for each request's keys, one fused execution.

        Returns one list per request with one value array per key (the
        ``retrieval_to_lists`` host view, sliced back per request).  With
        ``per_layer_counts=True`` returns ``(values, layer_counts)`` pairs
        per request instead, where ``layer_counts`` is the request's
        ``(num_keys, L)`` provenance block.

        Capacity lifecycle: the first batch of a bucket runs the exact
        counts-planning round, then quantizes both capacities to powers of
        two — later batches in the bucket reuse the compiled executor.  A
        batch whose results outgrow the cached capacities (``num_dropped >
        0``) doubles them (bounded by ``max_retries``) and re-executes;
        the doubled caps become the bucket's new working set.
        """
        if not requests:
            return []
        with self._batch_lock:
            st = as_state(self.table, state)
            q, bounds = self._coalesce(requests)
            bucket = q.shape[0]
            caps = self._caps.get(bucket)
            if caps is None:
                seg_need, out_need = self.table.plan_caps(st, q)
                caps = (_pow2(out_need), _pow2(seg_need))
                self._caps[bucket] = caps
            res, hit = self._exec_retrieve(st, q, bucket, caps, per_layer_counts)
            for _ in range(self.max_retries):
                if int(res.num_dropped) == 0:
                    break
                caps = (caps[0] * 2, caps[1] * 2)
                self._caps[bucket] = caps
                self._counters["batch_overflow_retries_total"].inc()
                res, hit = self._exec_retrieve(st, q, bucket, caps, per_layer_counts)
            if int(res.num_dropped) != 0:
                # Never silent: the per-request scatter has no num_dropped
                # field, so a truncated batch must fail loudly rather than
                # hand back partially-missing value lists.
                raise RuntimeError(
                    f"retrieve batch still overflows after {self.max_retries} "
                    f"capacity doublings (bucket {bucket}, out/seg caps {caps}, "
                    f"num_dropped {int(res.num_dropped)}); raise max_retries or "
                    "pre-warm the bucket with representative traffic"
                )
            if hit:
                self._counters["batch_cache_hits_total"].inc()
            else:
                self._counters["batch_cache_misses_total"].inc()
            self._counters["batch_requests_total"].inc(len(requests))
            self._counters["batch_executions_total"].inc()
            per_key = retrieval_to_lists(res)
            out = [per_key[a:b] for a, b in bounds]
            if not per_layer_counts:
                return out
            lc = np.asarray(res.layer_counts)
            return [(vals, lc[a:b]) for vals, (a, b) in zip(out, bounds)]

    def _exec_retrieve(self, st, q, bucket, caps, per_layer):
        grid = self.executors
        if grid is not None:
            handle = grid.retrieve_handle(st, bucket, caps[0], caps[1], per_layer)
            if handle is not None:
                return handle(st, q), True
        key = (bucket, caps[0], caps[1], per_layer)
        plan = self._rplans.get(key)
        hit = plan is not None
        if plan is None:
            plan = self.table.plan_retrieve(
                num_queries=bucket,
                out_capacity=caps[0],
                seg_capacity=caps[1],
                per_layer_counts=per_layer,
            )
            self._rplans[key] = plan
        return plan(st, q), hit

    # -- metrics --------------------------------------------------------------
    def stats(self, snapshot: Optional[RegistrySnapshot] = None) -> BatcherStats:
        """A :class:`BatcherStats` view over the registry.

        One registry snapshot (single lock acquisition — no tearing across
        fields); pass a pre-taken ``snapshot`` to fold this view into a
        larger atomic sample (``TableServer.stats`` does).
        """
        snap = snapshot if snapshot is not None else self.metrics_registry.snapshot()
        return BatcherStats(
            **{field: int(snap.value(name)) for name, field in self._METRICS.items()}
        )


def _pow2(n) -> int:
    n = int(n)
    return 8 if n <= 8 else 1 << (n - 1).bit_length()
