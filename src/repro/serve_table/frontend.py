"""Async request front end — futures in, deadline-batched executions out.

The synchronous serving path (:meth:`TableServer.query_many`) makes the
*caller* responsible for coalescing: one thread shows up with a list of
requests and blocks for the whole execute+scatter round trip.  Open-loop
traffic doesn't arrive that way — requests trickle in from many callers at
ragged times, and a device kept waiting for a "full" batch is a device
idling.  :class:`AsyncFrontend` closes that gap with the classic serving
triad:

* :class:`DeadlineBatcher` — a bounded admission queue that groups
  requests into a batch when a pow2 bucket's worth of keys has
  accumulated **or** the oldest request's deadline (capped by the
  ``linger`` period) comes due, whichever is first.  Low load pays at
  most one linger of latency; high load always ships full buckets.
* a **dispatcher thread** that pops due batches, stamps them with the
  current snapshot, and *enqueues* the fused execution on the device
  without blocking on results (:meth:`MicroBatcher.dispatch_query`);
* a **scatter thread** that blocks on the device transfer and resolves
  each caller's :class:`~concurrent.futures.Future` — so the host-side
  scatter of batch ``n`` overlaps the device execution of batch ``n+1``
  (the dispatch/scatter handoff queue is bounded, which also bounds
  device work in flight).

Writes go through the owning :class:`TableServer`'s writer loop; the front
end adds a **bounded write backlog**: ``submit_insert``/``submit_delete``
block (backpressure) while the server's queue is at capacity instead of
letting an open-loop producer grow it without bound.

Every public entry point returns immediately with a ``Future`` (reads) or
after admission (writes); no live request ever traces or compiles when the
server was warmed (:meth:`TableServer.warm`) — the dispatcher rides the
AOT executor grid like every other read.

The batcher takes an injectable ``clock`` so the deadline logic is testable
under a fake clock (drive :meth:`DeadlineBatcher.poll` manually) as well as
the real timer (:meth:`DeadlineBatcher.next_batch` blocks on a Condition
with the exact next-due timeout — no polling loop).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional

import numpy as np

from repro.obs.registry import MetricsRegistry, RegistrySnapshot
from repro.obs.tracing import Tracer


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """What a read future resolves to: counts + the snapshot that served it."""

    counts: np.ndarray  # int32, aligned with the request's keys
    seqno: int  # snapshot seqno the batch executed against


@dataclasses.dataclass
class _Pending:
    """One admitted request parked in the deadline batcher."""

    keys: np.ndarray  # packed key array
    size: int  # number of keys
    deadline: float  # absolute clock() time the caller needs dispatch by
    enqueued: float  # absolute clock() admission time
    future: Future = dataclasses.field(default_factory=Future)
    trace: Optional[object] = None  # obs.tracing.Trace when tracing is on


class DeadlineBatcher:
    """Bounded request queue with fill-or-deadline flushing.

    Flush rule — a batch is due as soon as either holds:

    * **fill**: pending keys reach ``flush_keys`` (a pow2 bucket's worth —
      shipping it now costs no extra padding), or
    * **deadline**: the clock reaches ``min(oldest.enqueued + linger,
      oldest.deadline)`` — nobody waits longer than the linger period, and
      a request with an earlier explicit deadline pulls the flush forward.

    ``capacity`` bounds admitted-but-undispatched requests; ``submit``
    blocks (backpressure) while full.  All state lives under one
    Condition; :meth:`poll` is the non-blocking fake-clock entry point and
    :meth:`next_batch` the blocking real-timer one.
    """

    def __init__(
        self,
        *,
        flush_keys: int = 64,
        linger: float = 0.002,
        capacity: int = 4096,
        clock: Callable[[], float] = time.monotonic,
        registry: Optional[MetricsRegistry] = None,
    ):
        if flush_keys < 1:
            raise ValueError("flush_keys must be >= 1")
        if linger < 0:
            raise ValueError("linger must be >= 0")
        self.flush_keys = int(flush_keys)
        self.linger = float(linger)
        self.capacity = int(capacity)
        self.clock = clock
        self._cond = threading.Condition()
        self._queue: list[_Pending] = []
        self._queued_keys = 0
        self._closed = False
        # Counters live in a registry (private unless a front end shares
        # its server's); instruments are leaf-locked, safe under _cond.
        self.metrics_registry = registry if registry is not None else MetricsRegistry()
        self._c_submitted = self.metrics_registry.counter(
            "frontend_submitted_total", help="Read requests admitted."
        )
        self._c_flushed = self.metrics_registry.counter(
            "frontend_flushed_batches_total", help="Batches popped for dispatch."
        )
        self._c_fill = self.metrics_registry.counter(
            "frontend_flushed_fill_total",
            help="Batches shipped because the bucket filled.",
        )
        self._c_due = self.metrics_registry.counter(
            "frontend_flushed_due_total",
            help="Batches shipped on linger/deadline expiry.",
        )

    # -- admission -------------------------------------------------------------
    def submit(
        self,
        keys,
        *,
        deadline: Optional[float] = None,
        timeout: Optional[float] = None,
        trace=None,
    ) -> _Pending:
        """Admit one request; block while the queue is at capacity.

        ``deadline`` is an absolute ``clock()`` time (default: admission +
        linger).  Raises :class:`RuntimeError` once closed and
        :class:`TimeoutError` if backpressure outlasts ``timeout``.
        ``trace`` (an :class:`~repro.obs.tracing.Trace`) rides the request
        through the pipeline; its admission phase ends here, at enqueue —
        so backpressure waits are *admission* time, not linger.
        """
        keys = np.asarray(keys)
        size = int(keys.shape[0])
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._closed or len(self._queue) < self.capacity,
                timeout=timeout,
            )
            if self._closed:
                raise RuntimeError("batcher is closed")
            if not ok:
                raise TimeoutError(
                    f"admission queue full ({self.capacity}) for {timeout}s"
                )
            now = self.clock()
            req = _Pending(
                keys=keys,
                size=size,
                deadline=now + self.linger if deadline is None else deadline,
                enqueued=now,
                trace=trace,
            )
            if trace is not None:
                trace.mark("admission", now)
            self._queue.append(req)
            self._queued_keys += size
            self._c_submitted.inc()
            self._cond.notify_all()  # wake the dispatcher (and full-queue waiters)
            return req

    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- flush decision ----------------------------------------------------------
    def _due_at(self) -> Optional[float]:
        """Absolute time the next flush is owed (None = empty queue).

        The linger bound is tightest at the head (FIFO admission), but an
        explicit deadline can arrive on *any* queued request — a later
        submission with an urgent deadline pulls the whole flush forward,
        so the deadline term is the queue-wide minimum.
        """
        if not self._queue:
            return None
        return min(
            self._queue[0].enqueued + self.linger,
            min(r.deadline for r in self._queue),
        )

    def _pop_batch_locked(self) -> list[_Pending]:
        """Pop FIFO requests up to one bucket's worth (always >= 1)."""
        batch = []
        total = 0
        while self._queue:
            r = self._queue[0]
            if batch and total + r.size > self.flush_keys:
                break  # next request starts the following batch
            batch.append(self._queue.pop(0))
            total += r.size
            if total >= self.flush_keys:
                break
        self._queued_keys -= total
        self._c_flushed.inc()
        if total >= self.flush_keys:
            self._c_fill.inc()
        else:
            self._c_due.inc()
        self._cond.notify_all()  # free capacity: wake blocked submitters
        return batch

    def poll(self, now: Optional[float] = None) -> Optional[list[_Pending]]:
        """Non-blocking: the due batch at time ``now``, or None.

        The deterministic driver for fake-clock tests; the real-timer path
        (:meth:`next_batch`) applies the same rule.
        """
        with self._cond:
            if not self._queue:
                return None
            if now is None:
                now = self.clock()
            if self._queued_keys >= self.flush_keys or now >= self._due_at():
                return self._pop_batch_locked()
            return None

    def next_batch(self, timeout: Optional[float] = None) -> Optional[list[_Pending]]:
        """Block until a batch is due (or ``timeout``/close); None if neither.

        Sleeps on the Condition for exactly the time until the earliest
        flush obligation — a submit that fills the bucket (or arrives with
        an earlier deadline) wakes it immediately.
        """
        outer = None if timeout is None else self.clock() + timeout
        with self._cond:
            while True:
                now = self.clock()
                if self._queue and (
                    self._queued_keys >= self.flush_keys or now >= self._due_at()
                ):
                    return self._pop_batch_locked()
                if self._closed:
                    # Drain everything still queued on close (dispatched,
                    # never dropped), then report exhaustion.
                    return self._pop_batch_locked() if self._queue else None
                waits = [] if outer is None else [outer - now]
                if self._queue:
                    waits.append(self._due_at() - now)
                if outer is not None and now >= outer:
                    return None
                self._cond.wait(timeout=min(waits) if waits else None)

    def close(self) -> None:
        """Stop admissions; wake every waiter (queued requests stay poppable)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def counters(self) -> dict:
        snap = self.metrics_registry.snapshot()  # one consistent sample
        with self._cond:
            queued = len(self._queue)
        return {
            "submitted": int(snap.value("frontend_submitted_total")),
            "queued": queued,
            "flushed_batches": int(snap.value("frontend_flushed_batches_total")),
            "flushed_fill": int(snap.value("frontend_flushed_fill_total")),
            "flushed_due": int(snap.value("frontend_flushed_due_total")),
        }


@dataclasses.dataclass(frozen=True)
class FrontendStats:
    """One coherent sample of the async front end's counters."""

    submitted: int  # read requests admitted
    completed: int  # read futures resolved (results or errors)
    failed: int  # read futures resolved with an exception
    batches_dispatched: int  # fused executions enqueued on the device
    batches_fill: int  # ... flushed because the bucket filled
    batches_due: int  # ... flushed on linger/deadline expiry
    queue_depth: int  # admitted, not yet dispatched
    inflight: int  # dispatched, not yet scattered
    write_backpressure_waits: int  # writes that blocked on the backlog bound
    last_error: Optional[str]


class AsyncFrontend:
    """Futures-returning async facade over a (warmed) :class:`TableServer`.

    ``linger`` is the latency knob (max time a lone request waits for
    company), ``flush_keys`` the throughput knob (how many keys make a
    bucket worth shipping early; default: the server batcher's
    ``min_bucket``), ``default_deadline`` the per-request dispatch
    deadline when the caller doesn't pass one.  ``write_backlog`` bounds
    the server's write queue as seen through this front end —
    ``submit_insert``/``submit_delete`` block while it is full.

    Lifecycle: ``start()`` launches the dispatcher + scatter threads (and
    the server's embedded writer loop unless it is already running);
    ``stop()`` closes admission, drains in-flight batches, resolves every
    remaining future, and joins all threads.
    """

    # frontend counter names -> FrontendStats fields (per-instance views
    # subtract the at-construction base, the shared registry stays
    # cumulative across sequential front ends on one server)
    _METRICS = {
        "frontend_submitted_total": "submitted",
        "frontend_completed_total": "completed",
        "frontend_failed_total": "failed",
        "frontend_flushed_batches_total": "batches_dispatched",
        "frontend_flushed_fill_total": "batches_fill",
        "frontend_flushed_due_total": "batches_due",
        "frontend_backpressure_waits_total": "write_backpressure_waits",
    }

    def __init__(
        self,
        server,
        *,
        linger: float = 0.002,
        flush_keys: Optional[int] = None,
        capacity: int = 4096,
        default_deadline: float = 0.05,
        write_backlog: int = 64,
        inflight: int = 2,
        clock: Callable[[], float] = time.monotonic,
        tracing: bool = True,
        trace_ring: int = 256,
    ):
        self.server = server
        self.default_deadline = float(default_deadline)
        self.write_backlog = int(write_backlog)
        self.clock = clock
        # One registry for the whole stack: share the server's.
        self.metrics_registry = server.metrics_registry
        self.tracer = Tracer(
            self.metrics_registry, ring=trace_ring, enabled=tracing, clock=clock
        )
        self.batcher = DeadlineBatcher(
            flush_keys=(
                server.batcher.min_bucket if flush_keys is None else int(flush_keys)
            ),
            linger=linger,
            capacity=capacity,
            clock=clock,
            registry=self.metrics_registry,
        )
        # dispatcher -> scatter handoff; the bound is the overlap depth AND
        # the cap on un-scattered device work in flight.
        self._handoff: list = []
        self._handoff_cond = threading.Condition()
        self._handoff_bound = max(1, int(inflight))
        self._dispatcher: Optional[threading.Thread] = None
        self._scatterer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._started_writer = False
        self._c_completed = self.metrics_registry.counter(
            "frontend_completed_total",
            help="Read futures resolved (results or errors).",
        )
        self._c_failed = self.metrics_registry.counter(
            "frontend_failed_total",
            help="Read futures resolved with an exception.",
        )
        self._c_bp_waits = self.metrics_registry.counter(
            "frontend_backpressure_waits_total",
            help="Writes that blocked on the backlog bound.",
        )
        base = self.metrics_registry.snapshot()
        self._base = {name: int(base.value(name)) for name in self._METRICS}
        self._last_error: Optional[str] = None
        self._lock = threading.Lock()  # last_error only

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> "AsyncFrontend":
        if self._dispatcher is not None:
            raise RuntimeError("frontend already started")
        self._stop.clear()
        if not (
            self.server._writer_thread is not None
            and self.server._writer_thread.is_alive()
        ):
            self.server.start()
            self._started_writer = True
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="serve-frontend-dispatch", daemon=True
        )
        self._scatterer = threading.Thread(
            target=self._scatter_loop, name="serve-frontend-scatter", daemon=True
        )
        self._dispatcher.start()
        self._scatterer.start()
        return self

    def stop(self) -> None:
        """Graceful shutdown: stop admissions, flush the pipeline, join."""
        self.batcher.close()
        if self._dispatcher is not None:
            self._dispatcher.join()
        self._stop.set()
        with self._handoff_cond:
            self._handoff_cond.notify_all()
        if self._scatterer is not None:
            self._scatterer.join()
        self._dispatcher = None
        self._scatterer = None
        if self._started_writer:
            self.server.stop()
            self._started_writer = False

    def __enter__(self) -> "AsyncFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- read path ----------------------------------------------------------------
    def submit_query(
        self,
        keys,
        *,
        deadline: Optional[float] = None,
        timeout: Optional[float] = None,
    ) -> Future:
        """Admit one query; resolve later to a :class:`QueryResult`.

        ``deadline`` (absolute ``clock()`` time; default now +
        ``default_deadline``) bounds how long the request may linger
        undispatched.  Blocks only on admission backpressure (bounded
        queue), never on execution.
        """
        packed = np.asarray(self.server.table.schema.pack_keys(keys))
        if deadline is None:
            deadline = self.clock() + self.default_deadline
        trace = self.tracer.start(size=int(packed.shape[0]))
        try:
            req = self.batcher.submit(
                packed, deadline=deadline, timeout=timeout, trace=trace
            )
        except Exception:
            self.tracer.abandon(trace)  # rejected at admission: not a span
            raise
        return req.future

    # -- write path (bounded backlog -> server writer loop) -------------------------
    def _write_backpressure(self, timeout: Optional[float]) -> None:
        if self.server.pending() < self.write_backlog:
            return
        self._c_bp_waits.inc()
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.server.pending() >= self.write_backlog:
            if self._stop.is_set():
                raise RuntimeError("frontend stopped while write was blocked")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"write backlog stayed at/above {self.write_backlog} "
                    f"for {timeout}s"
                )
            time.sleep(0.0002)

    def submit_insert(self, keys, values=None, *, timeout: Optional[float] = None):
        """Queue one insert through the bounded backlog (blocks when full)."""
        self._write_backpressure(timeout)
        self.server.submit_insert(keys, values)

    def submit_delete(self, keys, *, timeout: Optional[float] = None):
        """Queue one delete through the bounded backlog (blocks when full)."""
        self._write_backpressure(timeout)
        self.server.submit_delete(keys)

    def submit_upsert(
        self,
        keys,
        values=None,
        *,
        ttl: Optional[int] = None,
        timeout: Optional[float] = None,
    ):
        """Queue one insert-or-replace through the bounded backlog.

        KV semantics (``TableServer.submit_upsert``): prior versions are
        hidden, later reads see exactly the new values, ``ttl`` schedules
        expiry on the server's logical clock.
        """
        self._write_backpressure(timeout)
        self.server.submit_upsert(keys, values, ttl=ttl)

    # -- worker loops ----------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            batch = self.batcher.next_batch(timeout=0.05)
            if batch is None:
                with self.batcher._cond:
                    if self.batcher._closed and not self.batcher._queue:
                        return
                continue
            now = self.clock()
            for r in batch:
                if r.trace is not None:
                    r.trace.mark("linger", now)
            try:
                snap = self.server.current()
                pending = self.server.batcher.dispatch_query(
                    snap.state, [r.keys for r in batch], seqno=snap.seqno
                )
            except Exception as e:  # dispatch failed: fail this batch, keep serving
                self._fail_batch(batch, e)
                continue
            done = self.clock()
            for r in batch:
                if r.trace is not None:
                    r.trace.mark("dispatch", done)
                    r.trace.seqno = snap.seqno
                    r.trace.bucket = pending.bucket
            with self._handoff_cond:
                self._handoff_cond.wait_for(
                    lambda: len(self._handoff) < self._handoff_bound
                    or self._stop.is_set()
                )
                if self._stop.is_set():
                    self._fail_batch(
                        batch, RuntimeError("frontend stopped before scatter")
                    )
                    return
                self._handoff.append((pending, batch))
                self._handoff_cond.notify_all()

    def _scatter_loop(self) -> None:
        while True:
            with self._handoff_cond:
                self._handoff_cond.wait_for(
                    lambda: self._handoff or self._stop.is_set()
                )
                if not self._handoff:
                    if self._stop.is_set():
                        return
                    continue
                pending, batch = self._handoff.pop(0)
                self._handoff_cond.notify_all()
            traced = [r for r in batch if r.trace is not None]
            try:
                if traced:
                    # Split the device wait from the host-side scatter so
                    # the two phases are separately attributable; untraced
                    # batches keep the single blocking transfer.
                    pending.wait()
                    now = self.clock()
                    for r in traced:
                        r.trace.mark("device", now)
                results = pending.scatter()
            except Exception as e:
                self._fail_batch(batch, e)
                continue
            # Futures resolve BEFORE trace bookkeeping: callers see results
            # at the earliest instant; the scatter mark lands just after.
            for req, counts in zip(batch, results):
                req.future.set_result(QueryResult(counts=counts, seqno=pending.seqno))
            self._c_completed.inc(len(batch))
            if traced:
                now = self.clock()
                for r in traced:
                    r.trace.mark("scatter", now)
                    self.tracer.finish(r.trace)

    def _fail_batch(self, batch, exc: Exception) -> None:
        self._c_failed.inc(len(batch))
        self._c_completed.inc(len(batch))
        with self._lock:
            self._last_error = f"{type(exc).__name__}: {exc}"
        for req in batch:
            self.tracer.abandon(req.trace)  # error paths don't pollute latency
            if not req.future.done():
                req.future.set_exception(exc)

    # -- metrics ------------------------------------------------------------------
    def stats(self, snapshot: Optional[RegistrySnapshot] = None) -> FrontendStats:
        """Per-instance counter view from ONE registry snapshot.

        A single lock acquisition samples every counter (no tearing);
        values are this front end's own (the shared registry's cumulative
        totals minus the at-construction base).
        """
        snap = snapshot if snapshot is not None else self.metrics_registry.snapshot()
        vals = {
            field: int(snap.value(name)) - self._base[name]
            for name, field in self._METRICS.items()
        }
        with self._lock:
            last_error = self._last_error
        return FrontendStats(
            queue_depth=self.batcher.pending(),
            inflight=len(self._handoff),
            last_error=last_error,
            **vals,
        )

    def metrics(self, refresh: bool = True) -> RegistrySnapshot:
        """One atomic sample of the shared registry (front-end view).

        With ``refresh`` (default) the instantaneous gauges — admission
        queue depth, dispatch/scatter handoff depth, live (unfinished)
        traces — are re-read first.  The sample includes everything the
        owning server recorded too (same registry).
        """
        if refresh:
            reg = self.metrics_registry
            reg.gauge(
                "frontend_queue_depth", help="Admitted, not yet dispatched."
            ).set(self.batcher.pending())
            reg.gauge(
                "frontend_inflight", help="Dispatched, not yet scattered."
            ).set(len(self._handoff))
            reg.gauge(
                "trace_live",
                help="Traces started but not finished (0 after drain).",
            ).set(self.tracer.live())
        return self.metrics_registry.snapshot()


__all__ = [
    "AsyncFrontend",
    "DeadlineBatcher",
    "FrontendStats",
    "QueryResult",
]
