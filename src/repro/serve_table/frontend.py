"""Async request front end — futures in, deadline-batched executions out.

The synchronous serving path (:meth:`TableServer.query_many`) makes the
*caller* responsible for coalescing: one thread shows up with a list of
requests and blocks for the whole execute+scatter round trip.  Open-loop
traffic doesn't arrive that way — requests trickle in from many callers at
ragged times, and a device kept waiting for a "full" batch is a device
idling.  :class:`AsyncFrontend` closes that gap with the classic serving
triad:

* :class:`DeadlineBatcher` — a bounded admission queue that groups
  requests into a batch when a pow2 bucket's worth of keys has
  accumulated **or** the oldest request's deadline (capped by the
  ``linger`` period) comes due, whichever is first.  Low load pays at
  most one linger of latency; high load always ships full buckets.
* a **dispatcher thread** that pops due batches, stamps them with the
  current snapshot, and *enqueues* the fused execution on the device
  without blocking on results (:meth:`MicroBatcher.dispatch_query`);
* a **scatter thread** that blocks on the device transfer and resolves
  each caller's :class:`~concurrent.futures.Future` — so the host-side
  scatter of batch ``n`` overlaps the device execution of batch ``n+1``
  (the dispatch/scatter handoff queue is bounded, which also bounds
  device work in flight).

Writes go through the owning :class:`TableServer`'s writer loop; the front
end adds a **bounded write backlog**: ``submit_insert``/``submit_delete``
block (backpressure) while the server's queue is at capacity instead of
letting an open-loop producer grow it without bound.

Every public entry point returns immediately with a ``Future`` (reads) or
after admission (writes); no live request ever traces or compiles when the
server was warmed (:meth:`TableServer.warm`) — the dispatcher rides the
AOT executor grid like every other read.

The batcher takes an injectable ``clock`` so the deadline logic is testable
under a fake clock (drive :meth:`DeadlineBatcher.poll` manually) as well as
the real timer (:meth:`DeadlineBatcher.next_batch` blocks on a Condition
with the exact next-due timeout — no polling loop).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """What a read future resolves to: counts + the snapshot that served it."""

    counts: np.ndarray  # int32, aligned with the request's keys
    seqno: int  # snapshot seqno the batch executed against


@dataclasses.dataclass
class _Pending:
    """One admitted request parked in the deadline batcher."""

    keys: np.ndarray  # packed key array
    size: int  # number of keys
    deadline: float  # absolute clock() time the caller needs dispatch by
    enqueued: float  # absolute clock() admission time
    future: Future = dataclasses.field(default_factory=Future)


class DeadlineBatcher:
    """Bounded request queue with fill-or-deadline flushing.

    Flush rule — a batch is due as soon as either holds:

    * **fill**: pending keys reach ``flush_keys`` (a pow2 bucket's worth —
      shipping it now costs no extra padding), or
    * **deadline**: the clock reaches ``min(oldest.enqueued + linger,
      oldest.deadline)`` — nobody waits longer than the linger period, and
      a request with an earlier explicit deadline pulls the flush forward.

    ``capacity`` bounds admitted-but-undispatched requests; ``submit``
    blocks (backpressure) while full.  All state lives under one
    Condition; :meth:`poll` is the non-blocking fake-clock entry point and
    :meth:`next_batch` the blocking real-timer one.
    """

    def __init__(
        self,
        *,
        flush_keys: int = 64,
        linger: float = 0.002,
        capacity: int = 4096,
        clock: Callable[[], float] = time.monotonic,
    ):
        if flush_keys < 1:
            raise ValueError("flush_keys must be >= 1")
        if linger < 0:
            raise ValueError("linger must be >= 0")
        self.flush_keys = int(flush_keys)
        self.linger = float(linger)
        self.capacity = int(capacity)
        self.clock = clock
        self._cond = threading.Condition()
        self._queue: list[_Pending] = []
        self._queued_keys = 0
        self._closed = False
        self._submitted = 0
        self._flushed_batches = 0
        self._flushed_fill = 0  # batches shipped because the bucket filled
        self._flushed_due = 0  # batches shipped on linger/deadline expiry

    # -- admission -------------------------------------------------------------
    def submit(
        self,
        keys,
        *,
        deadline: Optional[float] = None,
        timeout: Optional[float] = None,
    ) -> _Pending:
        """Admit one request; block while the queue is at capacity.

        ``deadline`` is an absolute ``clock()`` time (default: admission +
        linger).  Raises :class:`RuntimeError` once closed and
        :class:`TimeoutError` if backpressure outlasts ``timeout``.
        """
        keys = np.asarray(keys)
        size = int(keys.shape[0])
        with self._cond:
            ok = self._cond.wait_for(
                lambda: self._closed or len(self._queue) < self.capacity,
                timeout=timeout,
            )
            if self._closed:
                raise RuntimeError("batcher is closed")
            if not ok:
                raise TimeoutError(
                    f"admission queue full ({self.capacity}) for {timeout}s"
                )
            now = self.clock()
            req = _Pending(
                keys=keys,
                size=size,
                deadline=now + self.linger if deadline is None else deadline,
                enqueued=now,
            )
            self._queue.append(req)
            self._queued_keys += size
            self._submitted += 1
            self._cond.notify_all()  # wake the dispatcher (and full-queue waiters)
            return req

    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- flush decision ----------------------------------------------------------
    def _due_at(self) -> Optional[float]:
        """Absolute time the next flush is owed (None = empty queue).

        The linger bound is tightest at the head (FIFO admission), but an
        explicit deadline can arrive on *any* queued request — a later
        submission with an urgent deadline pulls the whole flush forward,
        so the deadline term is the queue-wide minimum.
        """
        if not self._queue:
            return None
        return min(
            self._queue[0].enqueued + self.linger,
            min(r.deadline for r in self._queue),
        )

    def _pop_batch_locked(self) -> list[_Pending]:
        """Pop FIFO requests up to one bucket's worth (always >= 1)."""
        batch = []
        total = 0
        while self._queue:
            r = self._queue[0]
            if batch and total + r.size > self.flush_keys:
                break  # next request starts the following batch
            batch.append(self._queue.pop(0))
            total += r.size
            if total >= self.flush_keys:
                break
        self._queued_keys -= total
        self._flushed_batches += 1
        if total >= self.flush_keys:
            self._flushed_fill += 1
        else:
            self._flushed_due += 1
        self._cond.notify_all()  # free capacity: wake blocked submitters
        return batch

    def poll(self, now: Optional[float] = None) -> Optional[list[_Pending]]:
        """Non-blocking: the due batch at time ``now``, or None.

        The deterministic driver for fake-clock tests; the real-timer path
        (:meth:`next_batch`) applies the same rule.
        """
        with self._cond:
            if not self._queue:
                return None
            if now is None:
                now = self.clock()
            if self._queued_keys >= self.flush_keys or now >= self._due_at():
                return self._pop_batch_locked()
            return None

    def next_batch(self, timeout: Optional[float] = None) -> Optional[list[_Pending]]:
        """Block until a batch is due (or ``timeout``/close); None if neither.

        Sleeps on the Condition for exactly the time until the earliest
        flush obligation — a submit that fills the bucket (or arrives with
        an earlier deadline) wakes it immediately.
        """
        outer = None if timeout is None else self.clock() + timeout
        with self._cond:
            while True:
                now = self.clock()
                if self._queue and (
                    self._queued_keys >= self.flush_keys or now >= self._due_at()
                ):
                    return self._pop_batch_locked()
                if self._closed:
                    # Drain everything still queued on close (dispatched,
                    # never dropped), then report exhaustion.
                    return self._pop_batch_locked() if self._queue else None
                waits = [] if outer is None else [outer - now]
                if self._queue:
                    waits.append(self._due_at() - now)
                if outer is not None and now >= outer:
                    return None
                self._cond.wait(timeout=min(waits) if waits else None)

    def close(self) -> None:
        """Stop admissions; wake every waiter (queued requests stay poppable)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def counters(self) -> dict:
        with self._cond:
            return {
                "submitted": self._submitted,
                "queued": len(self._queue),
                "flushed_batches": self._flushed_batches,
                "flushed_fill": self._flushed_fill,
                "flushed_due": self._flushed_due,
            }


@dataclasses.dataclass(frozen=True)
class FrontendStats:
    """One coherent sample of the async front end's counters."""

    submitted: int  # read requests admitted
    completed: int  # read futures resolved (results or errors)
    failed: int  # read futures resolved with an exception
    batches_dispatched: int  # fused executions enqueued on the device
    batches_fill: int  # ... flushed because the bucket filled
    batches_due: int  # ... flushed on linger/deadline expiry
    queue_depth: int  # admitted, not yet dispatched
    inflight: int  # dispatched, not yet scattered
    write_backpressure_waits: int  # writes that blocked on the backlog bound
    last_error: Optional[str]


class AsyncFrontend:
    """Futures-returning async facade over a (warmed) :class:`TableServer`.

    ``linger`` is the latency knob (max time a lone request waits for
    company), ``flush_keys`` the throughput knob (how many keys make a
    bucket worth shipping early; default: the server batcher's
    ``min_bucket``), ``default_deadline`` the per-request dispatch
    deadline when the caller doesn't pass one.  ``write_backlog`` bounds
    the server's write queue as seen through this front end —
    ``submit_insert``/``submit_delete`` block while it is full.

    Lifecycle: ``start()`` launches the dispatcher + scatter threads (and
    the server's embedded writer loop unless it is already running);
    ``stop()`` closes admission, drains in-flight batches, resolves every
    remaining future, and joins all threads.
    """

    def __init__(
        self,
        server,
        *,
        linger: float = 0.002,
        flush_keys: Optional[int] = None,
        capacity: int = 4096,
        default_deadline: float = 0.05,
        write_backlog: int = 64,
        inflight: int = 2,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.server = server
        self.default_deadline = float(default_deadline)
        self.write_backlog = int(write_backlog)
        self.clock = clock
        self.batcher = DeadlineBatcher(
            flush_keys=(
                server.batcher.min_bucket if flush_keys is None else int(flush_keys)
            ),
            linger=linger,
            capacity=capacity,
            clock=clock,
        )
        # dispatcher -> scatter handoff; the bound is the overlap depth AND
        # the cap on un-scattered device work in flight.
        self._handoff: list = []
        self._handoff_cond = threading.Condition()
        self._handoff_bound = max(1, int(inflight))
        self._dispatcher: Optional[threading.Thread] = None
        self._scatterer: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._started_writer = False
        self._completed = 0
        self._failed = 0
        self._bp_waits = 0
        self._last_error: Optional[str] = None
        self._lock = threading.Lock()  # counters

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> "AsyncFrontend":
        if self._dispatcher is not None:
            raise RuntimeError("frontend already started")
        self._stop.clear()
        if not (
            self.server._writer_thread is not None
            and self.server._writer_thread.is_alive()
        ):
            self.server.start()
            self._started_writer = True
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="serve-frontend-dispatch", daemon=True
        )
        self._scatterer = threading.Thread(
            target=self._scatter_loop, name="serve-frontend-scatter", daemon=True
        )
        self._dispatcher.start()
        self._scatterer.start()
        return self

    def stop(self) -> None:
        """Graceful shutdown: stop admissions, flush the pipeline, join."""
        self.batcher.close()
        if self._dispatcher is not None:
            self._dispatcher.join()
        self._stop.set()
        with self._handoff_cond:
            self._handoff_cond.notify_all()
        if self._scatterer is not None:
            self._scatterer.join()
        self._dispatcher = None
        self._scatterer = None
        if self._started_writer:
            self.server.stop()
            self._started_writer = False

    def __enter__(self) -> "AsyncFrontend":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- read path ----------------------------------------------------------------
    def submit_query(
        self,
        keys,
        *,
        deadline: Optional[float] = None,
        timeout: Optional[float] = None,
    ) -> Future:
        """Admit one query; resolve later to a :class:`QueryResult`.

        ``deadline`` (absolute ``clock()`` time; default now +
        ``default_deadline``) bounds how long the request may linger
        undispatched.  Blocks only on admission backpressure (bounded
        queue), never on execution.
        """
        packed = self.server.table.schema.pack_keys(keys)
        if deadline is None:
            deadline = self.clock() + self.default_deadline
        req = self.batcher.submit(
            np.asarray(packed), deadline=deadline, timeout=timeout
        )
        return req.future

    # -- write path (bounded backlog -> server writer loop) -------------------------
    def _write_backpressure(self, timeout: Optional[float]) -> None:
        if self.server.pending() < self.write_backlog:
            return
        with self._lock:
            self._bp_waits += 1
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.server.pending() >= self.write_backlog:
            if self._stop.is_set():
                raise RuntimeError("frontend stopped while write was blocked")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"write backlog stayed at/above {self.write_backlog} "
                    f"for {timeout}s"
                )
            time.sleep(0.0002)

    def submit_insert(self, keys, values=None, *, timeout: Optional[float] = None):
        """Queue one insert through the bounded backlog (blocks when full)."""
        self._write_backpressure(timeout)
        self.server.submit_insert(keys, values)

    def submit_delete(self, keys, *, timeout: Optional[float] = None):
        """Queue one delete through the bounded backlog (blocks when full)."""
        self._write_backpressure(timeout)
        self.server.submit_delete(keys)

    def submit_upsert(
        self,
        keys,
        values=None,
        *,
        ttl: Optional[int] = None,
        timeout: Optional[float] = None,
    ):
        """Queue one insert-or-replace through the bounded backlog.

        KV semantics (``TableServer.submit_upsert``): prior versions are
        hidden, later reads see exactly the new values, ``ttl`` schedules
        expiry on the server's logical clock.
        """
        self._write_backpressure(timeout)
        self.server.submit_upsert(keys, values, ttl=ttl)

    # -- worker loops ----------------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            batch = self.batcher.next_batch(timeout=0.05)
            if batch is None:
                with self.batcher._cond:
                    if self.batcher._closed and not self.batcher._queue:
                        return
                continue
            try:
                snap = self.server.current()
                pending = self.server.batcher.dispatch_query(
                    snap.state, [r.keys for r in batch], seqno=snap.seqno
                )
            except Exception as e:  # dispatch failed: fail this batch, keep serving
                self._fail_batch(batch, e)
                continue
            with self._handoff_cond:
                self._handoff_cond.wait_for(
                    lambda: len(self._handoff) < self._handoff_bound
                    or self._stop.is_set()
                )
                if self._stop.is_set():
                    self._fail_batch(
                        batch, RuntimeError("frontend stopped before scatter")
                    )
                    return
                self._handoff.append((pending, batch))
                self._handoff_cond.notify_all()

    def _scatter_loop(self) -> None:
        while True:
            with self._handoff_cond:
                self._handoff_cond.wait_for(
                    lambda: self._handoff or self._stop.is_set()
                )
                if not self._handoff:
                    if self._stop.is_set():
                        return
                    continue
                pending, batch = self._handoff.pop(0)
                self._handoff_cond.notify_all()
            try:
                results = pending.scatter()
            except Exception as e:
                self._fail_batch(batch, e)
                continue
            for req, counts in zip(batch, results):
                req.future.set_result(QueryResult(counts=counts, seqno=pending.seqno))
            with self._lock:
                self._completed += len(batch)

    def _fail_batch(self, batch, exc: Exception) -> None:
        with self._lock:
            self._failed += len(batch)
            self._completed += len(batch)
            self._last_error = f"{type(exc).__name__}: {exc}"
        for req in batch:
            if not req.future.done():
                req.future.set_exception(exc)

    # -- metrics ------------------------------------------------------------------
    def stats(self) -> FrontendStats:
        c = self.batcher.counters()
        with self._lock:
            return FrontendStats(
                submitted=c["submitted"],
                completed=self._completed,
                failed=self._failed,
                batches_dispatched=c["flushed_batches"],
                batches_fill=c["flushed_fill"],
                batches_due=c["flushed_due"],
                queue_depth=c["queued"],
                inflight=len(self._handoff),
                write_backpressure_waits=self._bp_waits,
                last_error=self._last_error,
            )


__all__ = [
    "AsyncFrontend",
    "DeadlineBatcher",
    "FrontendStats",
    "QueryResult",
]
