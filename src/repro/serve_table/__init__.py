"""Table serving engine — snapshot-swapped reads, micro-batched requests,
incremental background compaction.

Quickstart::

    from repro.serve_table import TableServer

    server = TableServer(table, keys, values)       # seqno-0 snapshot
    server.submit_insert(new_keys, new_values)      # queued
    server.step()                                   # applied + published
    counts, seqno = server.query_many([q1, q2, q3]) # one fused execution
    server.fold_async()                             # compaction off the read path

See :mod:`repro.serve_table.server` for the serving design,
:mod:`repro.serve_table.batcher` for the static-shape admission layer, and
:mod:`repro.core.maintenance` for the fold/policy primitives underneath.
"""
from repro.core.maintenance import CompactionPolicy, TableStats, fold_oldest
from repro.serve_table.batcher import BatcherStats, MicroBatcher
from repro.serve_table.server import ServerStats, TableServer
from repro.serve_table.snapshot import Snapshot, SnapshotRegistry

__all__ = [
    "BatcherStats",
    "CompactionPolicy",
    "MicroBatcher",
    "ServerStats",
    "Snapshot",
    "SnapshotRegistry",
    "TableServer",
    "TableStats",
    "fold_oldest",
]
