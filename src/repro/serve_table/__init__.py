"""Table serving engine — snapshot-swapped reads, micro-batched requests,
incremental background compaction, and an async AOT-warmed front end.

Quickstart (synchronous)::

    from repro.serve_table import TableServer

    server = TableServer(table, keys, values)       # seqno-0 snapshot
    server.submit_insert(new_keys, new_values)      # queued
    server.step()                                   # applied + published
    counts, seqno = server.query_many([q1, q2, q3]) # one fused execution
    server.fold_async()                             # compaction off the read path

Quickstart (async, zero live compiles)::

    from repro.serve_table import AsyncFrontend, TableServer

    server = TableServer(table, keys, values, write_bucket=256)
    server.warm(buckets=(64, 128, 256))             # AOT: compile the grid
    with AsyncFrontend(server, linger=0.002) as fe:
        fut = fe.submit_query(q)                    # -> Future[QueryResult]
        fe.submit_insert(new_keys)                  # bounded backlog
        print(fut.result().counts)

See :mod:`repro.serve_table.server` for the serving design,
:mod:`repro.serve_table.batcher` for the static-shape admission layer,
:mod:`repro.serve_table.frontend` for deadline batching + futures,
:mod:`repro.serve_table.aot` for the executor-grid warmup, and
:mod:`repro.core.maintenance` for the fold/policy primitives underneath.
"""
from repro.core.maintenance import CompactionPolicy, TableStats, fold_oldest
from repro.serve_table.aot import ExecutorGrid, WarmupStats, warm_server
from repro.serve_table.batcher import BatcherStats, MicroBatcher, PendingBatch
from repro.serve_table.frontend import (
    AsyncFrontend,
    DeadlineBatcher,
    FrontendStats,
    QueryResult,
)
from repro.serve_table.server import ServerStats, TableServer
from repro.serve_table.snapshot import Snapshot, SnapshotRegistry

__all__ = [
    "AsyncFrontend",
    "BatcherStats",
    "CompactionPolicy",
    "DeadlineBatcher",
    "ExecutorGrid",
    "FrontendStats",
    "MicroBatcher",
    "PendingBatch",
    "QueryResult",
    "ServerStats",
    "Snapshot",
    "SnapshotRegistry",
    "TableServer",
    "TableStats",
    "WarmupStats",
    "warm_server",
]
