"""AOT warmup — compile the serving executor grid before the first request.

The serving front end admits reads on pow2-bucketed static shapes
(:class:`~repro.serve_table.batcher.MicroBatcher`) and writes padded to a
fixed ``write_bucket`` (:class:`~repro.serve_table.server.TableServer`), so
the set of programs live traffic can demand is *enumerable up front*: one
read executor per ``(bucket, state structure)`` pair, where the structure
is determined by the delta depth, the (uniform) delta geometry, the
tombstone buffer, and how many incremental folds have grown the base.

:func:`warm_server` walks exactly that grid at server start, building each
program through the ``jax.jit(...).lower(...).compile()`` idiom (the
offline-inference warmup pattern: per-padded-shape executables compiled
ahead of time, keyed by shape) and parks the executables in an
:class:`ExecutorGrid`.  The grid hooks into the micro-batcher: a read whose
``(bucket, state signature)`` matches a warmed entry runs the XLA
executable directly — ``jax.jit``'s dispatch cache is never consulted, so a
fully-warmed server does **zero live tracing or compilation** (asserted by
the no-retrace regression tests and the CI open-loop smoke).  Reads that
miss the grid (unwarmed depth, post-full-compact geometry, oversized write
batches) fall back to the normal plan path and are *counted*, never wrong:
``WarmupStats.coverage`` makes warmup adequacy observable.

State structures are warmed without real data: a **sentinel delta** (one
insert of ``write_bucket`` EMPTY keys) has byte-for-byte the geometry of
any real write at that bucket, so depth-``d`` prototypes are the base plus
``d`` references to it, and fold-``f`` prototypes fold the sentinel stack
``f`` times.  Prototype construction also warms the write-path executor
(``_build_delta_jit``) and the incremental fold as a side effect.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Sequence

import jax.numpy as jnp

from repro.core import maintenance
from repro.core.hashgraph import EMPTY_KEY
from repro.core.plans import CompiledPlan, state_signature
from repro.core.state import TableState


@dataclasses.dataclass(frozen=True)
class WarmupStats:
    """Coverage of the AOT-warmed executor grid (one coherent sample).

    ``entries`` is the number of compiled executables held; ``aot_hits`` /
    ``aot_misses`` count live read executions served by a warmed executable
    vs falling back to the jit plan path (a nonzero miss count after warmup
    means live traffic reached a structure outside the warmed grid — wider
    ``depths``/``fold_horizon``/``buckets`` close it).
    """

    write_bucket: int
    buckets: tuple  # read bucket sizes warmed
    depths: tuple  # delta depths warmed (at fold step 0)
    fold_horizon: int  # incremental folds whose post-fold bases are warmed
    entries: int  # compiled executables held
    compile_seconds: float  # wall-clock cost of the warmup pass
    aot_hits: int  # live executions served by a warmed executable
    aot_misses: int  # live executions that fell back to the jit path
    profiles: tuple = ()  # ExecutorCost rows from the warmup profiling pass

    @property
    def coverage(self) -> float:
        total = self.aot_hits + self.aot_misses
        return self.aot_hits / total if total else 1.0


class ExecutorGrid:
    """Registry of AOT-compiled read executors, keyed by shape + structure.

    Lookup key: ``(kind, bucket, extra-statics, state_signature(state))`` —
    a hit means the compiled executable was lowered against a structurally
    identical state and runs with zero tracing.  Hit/miss counters are
    plain ints guarded by a lock (lookups come from the micro-batcher's
    locked sections and the front end's single dispatcher thread).
    """

    def __init__(self):
        self._handles = {}
        self._retrieve_caps = {}  # bucket -> (out_cap, seg_cap) warmed caps
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        # Mirror counters in a shared MetricsRegistry (None until bound).
        # The plain ints stay authoritative for THIS grid's stats; the
        # registry counters accumulate across re-warms (a replaced grid
        # binds to the same registry), matching Prometheus counter
        # semantics.
        self._hit_counter = None
        self._miss_counter = None
        self.profiles: tuple = ()  # ExecutorCost rows (warmup profiling pass)
        self._meta = {
            "write_bucket": 0,
            "buckets": (),
            "depths": (),
            "fold_horizon": 0,
            "compile_seconds": 0.0,
        }

    def bind_registry(self, registry) -> None:
        """Mirror hit/miss counts into ``registry`` (carrying current counts)."""
        with self._lock:
            self._hit_counter = registry.counter(
                "aot_hits_total", help="Reads served by an AOT-warmed executable."
            )
            self._miss_counter = registry.counter(
                "aot_misses_total",
                help="Reads that fell back to the jit plan path.",
            )
            if self._hits:
                self._hit_counter.inc(self._hits)
            if self._misses:
                self._miss_counter.inc(self._misses)

    def __len__(self) -> int:
        return len(self._handles)

    def add(self, bucket: int, handle: CompiledPlan, extra: tuple = ()) -> None:
        key = (handle.kind, bucket, extra, handle.signature)
        with self._lock:
            self._handles[key] = handle

    def query_handle(self, state, bucket: int) -> Optional[CompiledPlan]:
        """The warmed query executable for this exact structure, or None.

        Counts the hit/miss either way — the pair is the live coverage
        signal in :class:`WarmupStats`.
        """
        return self._lookup(("query", bucket, (), state_signature(state)))

    def retrieve_handle(
        self, state, bucket: int, out_cap: int, seg_cap: int, per_layer: bool
    ) -> Optional[CompiledPlan]:
        return self._lookup(
            ("retrieve", bucket, (out_cap, seg_cap, per_layer), state_signature(state))
        )

    def _lookup(self, key) -> Optional[CompiledPlan]:
        with self._lock:
            h = self._handles.get(key)
            if h is None:
                self._misses += 1
                if self._miss_counter is not None:
                    self._miss_counter.inc()
            else:
                self._hits += 1
                if self._hit_counter is not None:
                    self._hit_counter.inc()
            return h

    def _peek(self, key) -> Optional[CompiledPlan]:
        """Uncounted lookup (warmup-internal; never a coverage signal)."""
        with self._lock:
            return self._handles.get(key)

    def cost_profile(self) -> tuple:
        """The warmup profiling pass's :class:`ExecutorCost` rows."""
        return self.profiles

    def retrieve_caps(self, bucket: int) -> Optional[tuple]:
        """The (out, seg) capacities retrieve was warmed with for a bucket
        (the batcher seeds its working caps from these so warmed traffic
        lands on the compiled executables instead of re-planning)."""
        return self._retrieve_caps.get(bucket)

    def stats(self) -> WarmupStats:
        with self._lock:
            return WarmupStats(
                write_bucket=self._meta["write_bucket"],
                buckets=tuple(self._meta["buckets"]),
                depths=tuple(self._meta["depths"]),
                fold_horizon=self._meta["fold_horizon"],
                entries=len(self._handles),
                compile_seconds=self._meta["compile_seconds"],
                aot_hits=self._hits,
                aot_misses=self._misses,
                profiles=self.profiles,
            )


def _sentinel_batch(table, n: int):
    """An all-EMPTY insert batch: real geometry, no visible rows."""
    schema = table.schema
    lanes = schema.key_lanes
    kshape = (n,) if lanes == 1 else (n, lanes)
    vshape = (n,) if schema.value_cols == 1 else (n, schema.value_cols)
    keys = jnp.full(kshape, EMPTY_KEY, jnp.uint32)
    values = jnp.full(vshape, -1, jnp.int32)
    return keys, values


def warm_server(
    server,
    *,
    buckets: Optional[Sequence[int]] = None,
    depths: Optional[Sequence[int]] = None,
    fold_horizon: int = 1,
    retrieve_caps=None,
    workers: Optional[int] = None,
    profile: bool = True,
) -> WarmupStats:
    """AOT-compile the server's whole reachable read-executor grid.

    * ``buckets`` — read batch sizes to warm (pow2, device-aligned;
      default: the batcher's ``min_bucket`` and the next two doublings).
    * ``depths`` — delta depths to warm at fold step 0 (default: every
      depth the compaction policy lets the writer reach, ``0..trigger``).
    * ``fold_horizon`` — how many incremental folds ahead to warm: each
      fold grows the base by the folded deltas' rows, a new structure.
      Post-fold steps warm depths ``trigger-fold_k..trigger`` (the band a
      folding writer actually revisits).  Ignored (treated as 0) when the
      policy never folds incrementally.
    * ``retrieve_caps`` — ``(out, seg)`` pair or ``{bucket: (out, seg)}``
      to additionally warm retrieve executors; queries only by default.
    * ``workers`` — thread pool width for the XLA compile stage (tracing
      is sequential; compilation releases the GIL).  0 = fully sequential.
    * ``profile`` — run the jaxpr collective accountant over the warmed
      grid: one :class:`~repro.obs.profiling.ExecutorCost` per distinct
      (kind, depth) program structure at the smallest bucket, combining
      collective counts/bytes with the compiled executable's XLA cost
      analysis.  Surfaced on ``grid.cost_profile()`` / ``stats().warmup.
      profiles`` and as labelled registry gauges.

    Attaches the resulting :class:`ExecutorGrid` to the server's batcher
    and records coverage in ``server.stats().warmup``.  Idempotent-ish:
    re-warming replaces the grid (the server registry's AOT counters keep
    accumulating across re-warms).
    """
    table = server.table
    if server.write_bucket is None:
        raise ValueError(
            "AOT warmup needs a shape-stable write path: construct the "
            "TableServer with write_bucket=<pow2> so every insert delta "
            "shares one geometry"
        )
    t0 = time.perf_counter()
    state0 = server.current().state
    policy = server.policy
    trigger = policy.max_delta_depth
    if trigger is None or trigger > table.max_deltas:
        trigger = table.max_deltas
    # Stats-driven policies (fold_k=None) size each fold at runtime; warm
    # the single-step geometry (their cold-prefix walk returns >= 1) and
    # let fold_horizon cover repetition.
    pfk = 1 if policy.fold_k is None else policy.fold_k
    fold_k = min(max(1, pfk), max(1, trigger - 1))
    folds_incremental = trigger is not None and pfk < trigger
    if not folds_incremental:
        fold_horizon = 0  # escalations full-compact: geometry is data-sized

    if buckets is None:
        b0 = server.batcher.min_bucket
        buckets = (b0, b0 * 2, b0 * 4)
    buckets = tuple(sorted({server.batcher.bucket_size(int(b)) for b in buckets}))
    if depths is None:
        depths = range(0, trigger + 1)
    depths = tuple(sorted({int(d) for d in depths if 0 <= d <= table.max_deltas}))
    if isinstance(retrieve_caps, tuple):
        retrieve_caps = {b: retrieve_caps for b in buckets}
    retrieve_caps = retrieve_caps or {}

    # -- prototype states: sentinel delta, fold-grown bases -------------------
    keys, values = _sentinel_batch(table, server.write_bucket)
    delta = table.insert(state0, keys, values).deltas[-1]

    def proto(base, depth) -> TableState:
        return dataclasses.replace(
            state0, base=base, deltas=(delta,) * depth, coherent=True
        )

    protos = []  # (fold_step, depth, state)
    base = state0.base
    for f in range(fold_horizon + 1):
        dd = depths if f == 0 else tuple(
            d for d in range(max(0, trigger - fold_k), trigger + 1)
        )
        for d in dd:
            protos.append((f, d, proto(base, d)))
        if f < fold_horizon:
            # The next fold step's base: fold fold_k sentinel deltas in.
            # (Also warms the incremental fold executor as a side effect.)
            base = maintenance.fold_oldest(proto(base, fold_k), fold_k).base

    # -- lower sequentially (tracing), compile on a pool (XLA, GIL-free) ------
    grid = ExecutorGrid()
    jobs = []  # (bucket, extra, kind-lowered)
    for _, _, st in protos:
        for b in buckets:
            qp = table.plan_query(num_queries=b)
            jobs.append((b, (), "query", qp.lower(st), state_signature(st)))
            caps = retrieve_caps.get(b)
            if caps is not None:
                out_cap, seg_cap = int(caps[0]), int(caps[1])
                rp = table.plan_retrieve(
                    num_queries=b, out_capacity=out_cap, seg_capacity=seg_cap
                )
                jobs.append(
                    (b, (out_cap, seg_cap, False), "retrieve",
                     rp.lower(st), state_signature(st))
                )

    def compile_one(job):
        b, extra, kind, lowered, sig = job
        handle = CompiledPlan(
            compiled=lowered.compile(), kind=kind, num_queries=b, signature=sig
        )
        grid.add(b, handle, extra=extra)

    if workers is None:
        workers = min(8, len(jobs))
    if workers and len(jobs) > 1:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            list(pool.map(compile_one, jobs))
    else:
        for job in jobs:
            compile_one(job)

    for b, caps in retrieve_caps.items():
        grid._retrieve_caps[int(b)] = (int(caps[0]), int(caps[1]))

    # -- device-cost profiling: jaxpr accountant over the warmed grid ---------
    if profile:
        grid.profiles = _profile_grid(
            table, grid, protos, buckets, retrieve_caps
        )

    grid._meta.update(
        write_bucket=server.write_bucket,
        buckets=buckets,
        depths=depths,
        fold_horizon=fold_horizon,
        compile_seconds=time.perf_counter() - t0,
    )
    registry = getattr(server, "metrics_registry", None)
    if registry is not None:
        grid.bind_registry(registry)
        registry.gauge(
            "aot_entries", help="Compiled executables held by the AOT grid."
        ).set(len(grid))
        registry.gauge(
            "aot_compile_seconds", help="Wall-clock cost of the last warmup."
        ).set(time.perf_counter() - t0)
        for cost in grid.profiles:
            labels = {
                "kind": cost.kind,
                "bucket": cost.bucket,
                "depth": cost.depth,
            }
            registry.gauge(
                "executor_all_to_alls",
                labels=labels,
                help="all_to_all primitives per executor (jaxpr accountant).",
            ).set(cost.all_to_alls)
            registry.gauge(
                "executor_collective_bytes",
                labels=labels,
                help="Per-device bytes moved through collectives per call.",
            ).set(cost.total_collective_bytes)
    server.batcher.executors = grid
    # Seed the batcher's retrieve working caps so warmed buckets skip the
    # planning round and land on the compiled executables.
    for b, caps in grid._retrieve_caps.items():
        server.batcher._caps.setdefault(b, caps)
    return grid.stats()


def _profile_grid(table, grid, protos, buckets, retrieve_caps) -> tuple:
    """One :class:`ExecutorCost` per (kind, depth) structure, smallest bucket.

    The jaxpr walk is per program *structure* — collective count and bytes
    do not depend on which fold step grew the base — so fold step 0 at the
    smallest warmed bucket bounds the tracing cost while still covering
    every delta depth (the acceptance criterion: the accountant must
    re-confirm the fused 2-all-to-all budget at each depth).
    """
    from repro.core.plans import _proto_queries, state_signature
    from repro.obs.profiling import profile_executor

    b0 = buckets[0]
    q = _proto_queries(table, b0)
    costs = []
    seen = set()
    for f, d, st in protos:
        if f != 0 or d in seen:
            continue
        seen.add(d)
        sig = state_signature(st)
        handle = grid._peek(("query", b0, (), sig))
        costs.append(
            profile_executor(
                table,
                st,
                q,
                kind="query",
                compiled=None if handle is None else handle.compiled,
            )
        )
        caps = retrieve_caps.get(b0)
        if caps is not None:
            out_cap, seg_cap = int(caps[0]), int(caps[1])
            rhandle = grid._peek(
                ("retrieve", b0, (out_cap, seg_cap, False), sig)
            )
            costs.append(
                profile_executor(
                    table,
                    st,
                    q,
                    kind="retrieve",
                    compiled=None if rhandle is None else rhandle.compiled,
                    exec_kwargs={
                        "out_capacity": out_cap,
                        "seg_capacity": seg_cap,
                    },
                )
            )
    return tuple(costs)


__all__ = ["ExecutorGrid", "WarmupStats", "warm_server"]
