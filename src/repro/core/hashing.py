"""Murmur hashing in pure JAX ``uint32`` arithmetic.

The paper (§4.2) uses MurmurHash [Appleby 2008] on 32-bit keys, as do
single-GPU HashGraph and WarpDrive.  We reproduce MurmurHash3's 32-bit
path bit-exactly with wrapping ``uint32`` ops (JAX integer arithmetic wraps,
matching C semantics).

Two entry points:

* :func:`murmur3_u32` — hash of a single 32-bit word per lane (the paper's
  key hash).  Vectorized elementwise; this is what the Pallas kernel in
  ``repro.kernels.murmur`` fuses with the bin/modulo step.
* :func:`murmur3_stream` — hash of a trailing axis of 32-bit words
  (sequence fingerprints for the data-pipeline dedup).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# MurmurHash3 x86_32 constants.
_C1 = jnp.uint32(0xCC9E2D51)
_C2 = jnp.uint32(0x1B873593)
_MIX1 = jnp.uint32(0x85EBCA6B)
_MIX2 = jnp.uint32(0xC2B2AE35)
_FIVE = jnp.uint32(5)
_N = jnp.uint32(0xE6546B64)

DEFAULT_SEED = 0x9747B28C  # seed used by the reference murmur CLI examples

# Seed for the probe fingerprint lane.  Deliberately distinct from
# DEFAULT_SEED: the fingerprint must be mixed *independently* of the
# bucket hash, otherwise rows that collide into one bucket would be
# biased toward colliding on the fingerprint too (the fingerprint's job
# is exactly to separate keys the bucket hash could not).
FINGERPRINT_SEED = 0x5BD1E995  # the MurmurHash2 multiplier, reused as a seed


def _rotl32(x: jax.Array, r: int) -> jax.Array:
    r = r % 32
    return (x << jnp.uint32(r)) | (x >> jnp.uint32(32 - r))


def fmix32(h: jax.Array) -> jax.Array:
    """MurmurHash3 finalizer — a strong standalone integer avalanche."""
    h = h.astype(jnp.uint32)
    h = h ^ (h >> jnp.uint32(16))
    h = h * _MIX1
    h = h ^ (h >> jnp.uint32(13))
    h = h * _MIX2
    h = h ^ (h >> jnp.uint32(16))
    return h


def _mix_k(k: jax.Array) -> jax.Array:
    k = k * _C1
    k = _rotl32(k, 15)
    k = k * _C2
    return k


def _mix_h(h: jax.Array, k: jax.Array) -> jax.Array:
    h = h ^ k
    h = _rotl32(h, 13)
    h = h * _FIVE + _N
    return h


def murmur3_u32(keys: jax.Array, seed: int = DEFAULT_SEED) -> jax.Array:
    """MurmurHash3_x86_32 of each 32-bit element of ``keys``.

    Matches the C reference for a 4-byte little-endian input.
    """
    k = keys.astype(jnp.uint32)
    h = jnp.uint32(seed)
    h = _mix_h(h, _mix_k(k))
    h = h ^ jnp.uint32(4)  # total length in bytes
    return fmix32(h)


def murmur3_stream(words: jax.Array, seed: int = DEFAULT_SEED, axis: int = -1) -> jax.Array:
    """MurmurHash3_x86_32 over a whole axis of 32-bit words.

    ``words[..., i]`` is treated as the i-th 4-byte block of the message.
    Returns a ``uint32`` array with ``axis`` reduced.  Used to fingerprint
    token sequences for the HashGraph-based dedup pipeline.
    """
    w = jnp.moveaxis(words.astype(jnp.uint32), axis, 0)
    nwords = w.shape[0]

    def body(h, k):
        return _mix_h(h, _mix_k(k)), None

    h0 = jnp.full(w.shape[1:], jnp.uint32(seed))
    h, _ = jax.lax.scan(body, h0, w)
    h = h ^ jnp.uint32(4 * nwords)
    return fmix32(h)


def murmur3_packed(keys: jax.Array, seed: int = DEFAULT_SEED) -> jax.Array:
    """MurmurHash3_x86_32 of 1-lane or multi-lane packed keys.

    * ``(N,)`` — the single-word path (:func:`murmur3_u32`), unchanged.
    * ``(N, L)`` — each row is an ``4*L``-byte little-endian message whose
      i-th 4-byte block is lane ``i``; for the 2-lane uint64 packing
      (``schema.pack_u64``: lane 0 = low word) this is bit-exact
      MurmurHash3_x86_32 of the 8-byte little-endian key.

    Returns a ``(N,)`` uint32 hash either way.
    """
    if keys.ndim == 1:
        return murmur3_u32(keys, seed=seed)
    return murmur3_stream(keys, seed=seed, axis=-1)


def fingerprint32(keys: jax.Array, seed: int = FINGERPRINT_SEED) -> jax.Array:
    """32-bit probe fingerprint of 1-lane ``(N,)`` or packed ``(N, L)`` keys.

    Same MurmurHash3 stream as :func:`murmur3_packed` but under
    ``FINGERPRINT_SEED``, so the fingerprint is statistically independent
    of the bucket assignment (``hash_to_buckets`` under ``DEFAULT_SEED``).
    The sorted probe path (:func:`repro.core.hashgraph.query_locate`)
    bisects this single uint32 lane first and touches the full key lanes
    only inside the run of rows whose fingerprint already matched.
    """
    return murmur3_packed(keys, seed=seed)


def hash_to_buckets(keys: jax.Array, table_size: int, seed: int = DEFAULT_SEED) -> jax.Array:
    """``hash(e) mod V`` (Alg. 1 line 2 / Alg. 2 line 4), returned as int32.

    ``keys`` may be ``(N,)`` uint32 or ``(N, L)`` packed multi-lane keys
    (:func:`murmur3_packed`).  ``table_size`` must be ``<= 2**31 - 1`` so
    bucket ids fit int32 (the paper similarly caps table size at 2^31 when
    the key count exceeds 2^32).
    """
    if table_size <= 0 or table_size > 2**31 - 1:
        raise ValueError(f"table_size must be in [1, 2^31-1], got {table_size}")
    h = murmur3_packed(keys, seed=seed)
    return (h % jnp.uint32(table_size)).astype(jnp.int32)
