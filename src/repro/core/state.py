"""Versioned functional table state — base graph + delta ring + tombstones.

The paper's CSR HashGraph is build-once; this module turns it into a
mutable-by-value table in the LSM style:

* ``base`` — the big :class:`~repro.core.multi_hashgraph.DistributedHashGraph`
  from the last full build/compaction (epoch 0).
* ``deltas`` — a bounded ring of small DistributedHashGraphs, one per
  ``insert`` batch; the ``i``-th delta (0-based) has epoch ``i + 1``.
* ``tombstones`` — a fixed-capacity buffer of deleted keys, each stamped
  with the number of deltas that existed when the delete was issued.  A
  tombstone with epoch ``e`` hides matching rows in every layer with epoch
  ``<= e`` (everything that existed at delete time) and leaves later
  inserts visible — so delete-then-reinsert behaves like a real table.

``TableState`` is a pytree: ``insert``/``delete`` return a *new* state (the
old one stays valid), and every operation is traceable under an outer
``jax.jit`` — the delta count and tombstone capacity are static structure.
``compact()`` folds deltas + tombstones into a fresh base via a rebuild and
resets the ring.

The mesh-level mutation ops live on
:class:`~repro.core.table.DistributedHashTable` (which owns the mesh and the
jitted shard_maps); the methods here are convenience forwarders through the
``table`` reference carried in the pytree's static metadata.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core.hashgraph import EMPTY_KEY, match_epochs, sort_tombstones
from repro.core.multi_hashgraph import DistributedHashGraph

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.table import DistributedHashTable


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("keys", "epochs", "count", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class Tombstones:
    """Fixed-capacity delete buffer, replicated on every device.

    Unused slots hold the EMPTY sentinel with epoch ``-1`` (matched by
    nothing).  ``num_dropped`` counts deletes that overflowed the buffer —
    reported, never silent, same contract as every other static capacity in
    the stack.
    """

    keys: jax.Array  # (T,) uint32 or (T, L) packed lanes
    epochs: jax.Array  # (T,) int32, -1 in unused slots
    count: jax.Array  # () int32 — used slots
    num_dropped: jax.Array  # () int32 — deletes lost to capacity

    @property
    def capacity(self) -> int:
        return int(self.keys.shape[0])

    def epoch_of(self, keys: jax.Array) -> jax.Array:
        """Newest tombstone epoch matching each key (-1 where none)."""
        return match_epochs(keys, self.keys, self.epochs)

    def push(self, keys: jax.Array, epoch: int) -> "Tombstones":
        """Append ``keys`` stamped with ``epoch``; overflow is counted."""
        n = keys.shape[0]
        idx = self.count + jnp.arange(n, dtype=jnp.int32)
        overflow = jnp.maximum(self.count + n - self.capacity, 0)
        return Tombstones(
            keys=self.keys.at[idx].set(keys, mode="drop"),
            epochs=self.epochs.at[idx].set(jnp.int32(epoch), mode="drop"),
            count=jnp.minimum(self.count + n, self.capacity).astype(jnp.int32),
            num_dropped=(self.num_dropped + overflow).astype(jnp.int32),
        )

    def as_mask_args(self) -> tuple[jax.Array, jax.Array]:
        """The raw ``(ts_keys, ts_epochs)`` pair (push/insertion order)."""
        return self.keys, self.epochs

    def index(self) -> tuple[jax.Array, jax.Array]:
        """Sorted tombstone index: ``(keys, epochs)`` ordered by key.

        The pair every sharded query/retrieve/plan path takes: lookups
        against it are per-key binary searches
        (:func:`repro.core.hashgraph.match_epochs_sorted`, ``O(log T)``)
        instead of the O(T) broadcast compare per routed batch.  Pure and
        traceable — the sort costs ``O(T log T)`` once per operation, with
        ``T`` the small, bounded tombstone capacity.
        """
        return sort_tombstones(self.keys, self.epochs)


def empty_tombstones(capacity: int, key_lanes: int = 1) -> Tombstones:
    """An all-empty tombstone buffer for the given schema width."""
    shape = (capacity,) if key_lanes == 1 else (capacity, key_lanes)
    return Tombstones(
        keys=jnp.full(shape, EMPTY_KEY, jnp.uint32),
        epochs=jnp.full((capacity,), -1, jnp.int32),
        count=jnp.int32(0),
        num_dropped=jnp.int32(0),
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("base", "deltas", "tombstones"),
    meta_fields=("table", "coherent"),
)
@dataclasses.dataclass(frozen=True)
class TableState:
    """Immutable snapshot of a mutable distributed table.

    ``insert``/``delete``/``compact`` return new snapshots; plans built by
    :meth:`DistributedHashTable.plan_query` / ``plan_retrieve`` /
    ``plan_join`` execute against any snapshot with compatible shapes.  The
    ``table`` reference is static pytree metadata (the config that owns the
    mesh and jit caches), so ``state.insert(...)`` composes under an outer
    ``jax.jit`` exactly like ``table.insert(state, ...)``.

    ``coherent`` stamps the partition-coherence invariant: every delta was
    built on the *base's* frozen ``hash_splits`` (same hash range, same
    seed), so one routing round serves the whole layer stack and the
    executors take the fused single-route path.  States whose deltas own
    independent splits (``coherent_deltas=False`` inserts, hand-assembled
    stacks) carry ``coherent=False`` and fall back to per-layer routing.
    Static pytree metadata — the flag keys the jit cache alongside the
    delta count.
    """

    base: DistributedHashGraph
    deltas: tuple  # tuple[DistributedHashGraph, ...] — delta ring, epoch i+1
    tombstones: Tombstones
    table: "DistributedHashTable"  # static metadata
    coherent: bool = True  # static: deltas share the base's hash_splits

    @property
    def epoch(self) -> int:
        """Current insert epoch == number of live deltas (static)."""
        return len(self.deltas)

    @property
    def layers(self) -> tuple:
        """``(base, *deltas)`` — layer ``i`` has epoch ``i``."""
        return (self.base,) + tuple(self.deltas)

    @property
    def num_dropped(self) -> jax.Array:
        """Total overflow across base build, delta builds, and tombstones."""
        total = self.base.num_dropped + self.tombstones.num_dropped
        for d in self.deltas:
            total = total + d.num_dropped
        return total

    def stats(self):
        """Cheap maintenance snapshot: a ``maintenance.TableStats``.

        Delta depth, allocated base/delta rows, tombstone fill, and drop
        tallies — the signals :class:`~repro.core.maintenance.
        CompactionPolicy` and the ``serve_table`` server metrics read.
        Three scalar device reads; call eagerly, never inside ``jax.jit``.
        """
        from repro.core.maintenance import collect_stats

        return collect_stats(self)

    def should_compact(
        self, *, tombstone_load: float = 0.5, ring_full: bool = True
    ) -> bool:
        """Host-level compaction trigger: is this state due for a fold?

        True when any of:

        * the delta ring is full (``ring_full=True``) — the next ``insert``
          would raise;
        * the tombstone buffer's fill fraction reaches ``tombstone_load``;
        * tombstones have already overflowed (``num_dropped > 0``) — deletes
          were lost to capacity and only a compaction restores exactness.

        Reads a few scalars from device, so call it eagerly (e.g. between
        update batches), never inside a jitted program.

        .. deprecated:: thin shim over :class:`~repro.core.maintenance.
           CompactionPolicy` (the thresholds' dataclass form, shared with
           the ``serve_table`` server); this signature is kept for older
           call sites.
        """
        from repro.core.maintenance import CompactionPolicy

        policy = CompactionPolicy(
            max_delta_depth=self.table.max_deltas if ring_full else None,
            tombstone_load=tombstone_load,
        )
        return policy.due(self.stats())

    # -- functional mutation (forwarders to the owning table) ---------------
    def insert(self, keys, values=None, *, auto_compact: bool = False) -> "TableState":
        """New state with one more delta holding ``keys``/``values``.

        ``auto_compact=True`` folds the state first when
        :meth:`should_compact` fires (ring full, tombstone load, or
        tombstone overflow), so a steady insert/delete stream never hits
        the delta-ring capacity error.  Host-syncing — eager use only.
        """
        return self.table.insert(self, keys, values, auto_compact=auto_compact)

    def delete(self, keys) -> "TableState":
        """New state with ``keys`` tombstoned at the current epoch."""
        return self.table.delete(self, keys)

    def compact(self, capacity: Optional[int] = None) -> "TableState":
        """Fold deltas + tombstones into a fresh base; reset the ring."""
        return self.table.compact(self, capacity=capacity)


def as_state(table: "DistributedHashTable", state) -> TableState:
    """Lift a bare :class:`DistributedHashGraph` (the pre-plan API's state)
    into a delta-free :class:`TableState`; pass ``TableState`` through."""
    if isinstance(state, TableState):
        return state
    if isinstance(state, DistributedHashGraph):
        # Zero-capacity tombstone buffer: legacy eager call sites pay no
        # masking cost (match_epochs early-outs on an empty buffer); the
        # buffer grows to table.tombstone_capacity on first delete().
        return TableState(
            base=state,
            deltas=(),
            tombstones=empty_tombstones(0, table.schema.key_lanes),
            table=table,
        )
    raise TypeError(
        f"expected TableState or DistributedHashGraph, got {type(state).__name__}"
    )
