"""Versioned functional table state — base graph + delta ring + tombstones.

The paper's CSR HashGraph is build-once; this module turns it into a
mutable-by-value table in the LSM style:

* ``base`` — the big :class:`~repro.core.multi_hashgraph.DistributedHashGraph`
  from the last full build/compaction (epoch 0).
* ``deltas`` — a bounded ring of small DistributedHashGraphs, one per
  ``insert`` batch; the ``i``-th delta (0-based) has epoch ``i + 1``.
* ``tombstones`` — a fixed-capacity buffer of deleted keys, each stamped
  with the number of deltas that existed when the delete was issued.  A
  tombstone with epoch ``e`` hides matching rows in every layer with epoch
  ``<= e`` (everything that existed at delete time) and leaves later
  inserts visible — so delete-then-reinsert behaves like a real table.

  Each entry additionally carries an ``expires`` stamp against the state's
  logical clock ``now`` (KV-cache TTL semantics): a plain delete expires at
  0 (always in the past — it masks immediately), while an entry pushed with
  ``expires = now + ttl`` is *pending* — invisible to reads until the clock
  reaches it, at which point it behaves exactly like a delete issued at its
  epoch.  Expiry is resolved inside :meth:`Tombstones.index` (entries not
  yet expired sort with epoch ``-1``), so every masking path — query,
  retrieve, fold, compact, live-count sizing — honours TTLs with zero
  changes to its collective structure.

``TableState`` is a pytree: ``insert``/``delete`` return a *new* state (the
old one stays valid), and every operation is traceable under an outer
``jax.jit`` — the delta count and tombstone capacity are static structure.
``compact()`` folds deltas + tombstones into a fresh base via a rebuild and
resets the ring.

The mesh-level mutation ops live on
:class:`~repro.core.table.DistributedHashTable` (which owns the mesh and the
jitted shard_maps); the methods here are convenience forwarders through the
``table`` reference carried in the pytree's static metadata.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import TYPE_CHECKING, Optional

import jax
import jax.numpy as jnp

from repro.core.hashgraph import EMPTY_KEY, match_epochs, sort_tombstones
from repro.core.multi_hashgraph import DistributedHashGraph

if TYPE_CHECKING:  # pragma: no cover - import cycle guard, typing only
    from repro.core.table import DistributedHashTable


# Expiry stamp meaning "never": larger than any reachable logical clock.
NEVER_EXPIRES = 0x7FFFFFFF


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("keys", "epochs", "expires", "count", "num_dropped", "now"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class Tombstones:
    """Fixed-capacity delete/TTL buffer, replicated on every device.

    Unused slots hold the EMPTY sentinel with epoch ``-1`` (matched by
    nothing).  ``expires`` stamps each entry against the logical clock
    ``now``: a plain delete expires at 0 (effective immediately), a TTL
    entry at ``now + ttl`` (pending until the clock reaches it).
    ``num_dropped`` counts deletes that overflowed the buffer — reported,
    never silent, same contract as every other static capacity in the
    stack.
    """

    keys: jax.Array  # (T,) uint32 or (T, L) packed lanes
    epochs: jax.Array  # (T,) int32, -1 in unused slots
    expires: jax.Array  # (T,) int32 — logical time the entry takes effect
    count: jax.Array  # () int32 — used slots
    num_dropped: jax.Array  # () int32 — deletes lost to capacity
    now: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.int32(0)
    )  # () int32 — the state's logical clock

    @property
    def capacity(self) -> int:
        return int(self.keys.shape[0])

    def effective_epochs(self) -> jax.Array:
        """Per-entry masking epoch at the current clock.

        An entry masks nothing until it expires: pending entries (``now <
        expires``) report epoch ``-1`` (matched by no layer), expired ones
        their stamped epoch.  This is the *only* place expiry is resolved —
        everything downstream consumes effective epochs and needs no TTL
        awareness.
        """
        return jnp.where(self.now >= self.expires, self.epochs, jnp.int32(-1))

    def epoch_of(self, keys: jax.Array) -> jax.Array:
        """Newest effective tombstone epoch matching each key (-1: none)."""
        return match_epochs(keys, self.keys, self.effective_epochs())

    def push(
        self, keys: jax.Array, epoch: int, expires: Optional[jax.Array] = None
    ) -> "Tombstones":
        """Append ``keys`` stamped with ``epoch``; overflow is counted.

        ``expires`` defaults to 0 — an immediately-effective delete (the
        clock never goes negative).  Pass ``now + ttl`` for a pending TTL
        entry, or :data:`NEVER_EXPIRES` to park an inert entry.
        """
        n = keys.shape[0]
        idx = self.count + jnp.arange(n, dtype=jnp.int32)
        overflow = jnp.maximum(self.count + n - self.capacity, 0)
        if expires is None:
            expires = jnp.int32(0)
        exp = jnp.broadcast_to(jnp.asarray(expires, jnp.int32), (n,))
        return Tombstones(
            keys=self.keys.at[idx].set(keys, mode="drop"),
            epochs=self.epochs.at[idx].set(jnp.int32(epoch), mode="drop"),
            expires=self.expires.at[idx].set(exp, mode="drop"),
            count=jnp.minimum(self.count + n, self.capacity).astype(jnp.int32),
            num_dropped=(self.num_dropped + overflow).astype(jnp.int32),
            now=self.now,
        )

    def at_time(self, now) -> "Tombstones":
        """The same buffer with the logical clock advanced to ``now``."""
        return dataclasses.replace(self, now=jnp.asarray(now, jnp.int32))

    def as_mask_args(self) -> tuple[jax.Array, jax.Array]:
        """The raw ``(ts_keys, effective_epochs)`` pair (push order)."""
        return self.keys, self.effective_epochs()

    def index(self) -> tuple[jax.Array, jax.Array]:
        """Sorted tombstone index: ``(keys, epochs)`` ordered by key.

        The pair every sharded query/retrieve/plan path takes: lookups
        against it are per-key binary searches
        (:func:`repro.core.hashgraph.match_epochs_sorted`, ``O(log T)``)
        instead of the O(T) broadcast compare per routed batch.  Epochs are
        the *effective* ones — pending TTL entries sort with ``-1`` (the
        front of their key's run), so the run's last entry still carries
        the newest epoch that actually masks.  Pure and traceable — the
        sort costs ``O(T log T)`` once per operation, with ``T`` the small,
        bounded tombstone capacity.
        """
        return sort_tombstones(self.keys, self.effective_epochs())


def empty_tombstones(capacity: int, key_lanes: int = 1, now=0) -> Tombstones:
    """An all-empty tombstone buffer for the given schema width."""
    shape = (capacity,) if key_lanes == 1 else (capacity, key_lanes)
    return Tombstones(
        keys=jnp.full(shape, EMPTY_KEY, jnp.uint32),
        epochs=jnp.full((capacity,), -1, jnp.int32),
        expires=jnp.full((capacity,), NEVER_EXPIRES, jnp.int32),
        count=jnp.int32(0),
        num_dropped=jnp.int32(0),
        now=jnp.asarray(now, jnp.int32),
    )


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("base", "deltas", "tombstones"),
    meta_fields=("table", "coherent"),
)
@dataclasses.dataclass(frozen=True)
class TableState:
    """Immutable snapshot of a mutable distributed table.

    ``insert``/``delete``/``compact`` return new snapshots; plans built by
    :meth:`DistributedHashTable.plan_query` / ``plan_retrieve`` /
    ``plan_join`` execute against any snapshot with compatible shapes.  The
    ``table`` reference is static pytree metadata (the config that owns the
    mesh and jit caches), so ``state.insert(...)`` composes under an outer
    ``jax.jit`` exactly like ``table.insert(state, ...)``.

    ``coherent`` stamps the partition-coherence invariant: every delta was
    built on the *base's* frozen ``hash_splits`` (same hash range, same
    seed), so one routing round serves the whole layer stack and the
    executors take the fused single-route path.  States whose deltas own
    independent splits (``coherent_deltas=False`` inserts, hand-assembled
    stacks) carry ``coherent=False`` and fall back to per-layer routing.
    Static pytree metadata — the flag keys the jit cache alongside the
    delta count.
    """

    base: DistributedHashGraph
    deltas: tuple  # tuple[DistributedHashGraph, ...] — delta ring, epoch i+1
    tombstones: Tombstones
    table: "DistributedHashTable"  # static metadata
    coherent: bool = True  # static: deltas share the base's hash_splits

    @property
    def epoch(self) -> int:
        """Current insert epoch == number of live deltas (static)."""
        return len(self.deltas)

    @property
    def layers(self) -> tuple:
        """``(base, *deltas)`` — layer ``i`` has epoch ``i``."""
        return (self.base,) + tuple(self.deltas)

    @property
    def num_dropped(self) -> jax.Array:
        """Total overflow across base build, delta builds, and tombstones."""
        total = self.base.num_dropped + self.tombstones.num_dropped
        for d in self.deltas:
            total = total + d.num_dropped
        return total

    def stats(self):
        """Cheap maintenance snapshot: a ``maintenance.TableStats``.

        Delta depth, allocated base/delta rows, tombstone fill, and drop
        tallies — the signals :class:`~repro.core.maintenance.
        CompactionPolicy` and the ``serve_table`` server metrics read.
        Three scalar device reads; call eagerly, never inside ``jax.jit``.
        """
        from repro.core.maintenance import collect_stats

        return collect_stats(self)

    def should_compact(
        self, *, tombstone_load: float = 0.5, ring_full: bool = True
    ) -> bool:
        """Host-level compaction trigger: is this state due for a fold?

        True when any of:

        * the delta ring is full (``ring_full=True``) — the next ``insert``
          would raise;
        * the tombstone buffer's fill fraction reaches ``tombstone_load``;
        * tombstones have already overflowed (``num_dropped > 0``) — deletes
          were lost to capacity and only a compaction restores exactness.

        Reads a few scalars from device, so call it eagerly (e.g. between
        update batches), never inside a jitted program.

        .. deprecated:: thin shim over :class:`~repro.core.maintenance.
           CompactionPolicy` (the thresholds' dataclass form, shared with
           the ``serve_table`` server); this signature is kept for older
           call sites.
        """
        from repro.core.maintenance import CompactionPolicy

        policy = CompactionPolicy(
            max_delta_depth=self.table.max_deltas if ring_full else None,
            tombstone_load=tombstone_load,
        )
        return policy.due(self.stats())

    # -- functional mutation (forwarders to the owning table) ---------------
    def insert(self, keys, values=None, *, auto_compact: bool = False) -> "TableState":
        """New state with one more delta holding ``keys``/``values``.

        ``auto_compact=True`` folds the state first when
        :meth:`should_compact` fires (ring full, tombstone load, or
        tombstone overflow), so a steady insert/delete stream never hits
        the delta-ring capacity error.  Host-syncing — eager use only.
        """
        return self.table.insert(self, keys, values, auto_compact=auto_compact)

    def delete(self, keys) -> "TableState":
        """New state with ``keys`` tombstoned at the current epoch."""
        return self.table.delete(self, keys)

    def upsert(self, keys, values=None, *, ttl: Optional[int] = None) -> "TableState":
        """New state where ``keys`` map to exactly ``values`` (KV semantics).

        Insert-or-replace: prior versions are tombstoned at the current
        epoch and the new rows land in a fresh delta.  ``ttl`` additionally
        schedules expiry at ``now + ttl`` on the logical clock.
        """
        return self.table.upsert(self, keys, values, ttl=ttl)

    @property
    def now(self) -> jax.Array:
        """The state's logical clock (drives TTL expiry)."""
        return self.tombstones.now

    def advance(self, now) -> "TableState":
        """New state with the logical clock at ``now`` (monotone by contract).

        Purely functional and O(1): expiry is resolved at read time from
        the clock, so advancing it is how TTL'd rows age out of view.
        """
        return dataclasses.replace(self, tombstones=self.tombstones.at_time(now))

    def compact(self, capacity: Optional[int] = None) -> "TableState":
        """Fold deltas + tombstones into a fresh base; reset the ring."""
        return self.table.compact(self, capacity=capacity)


def as_state(table: "DistributedHashTable", state) -> TableState:
    """Lift a bare :class:`DistributedHashGraph` (the pre-plan API's state)
    into a delta-free :class:`TableState`; pass ``TableState`` through."""
    if isinstance(state, TableState):
        return state
    if isinstance(state, DistributedHashGraph):
        # Zero-capacity tombstone buffer: legacy eager call sites pay no
        # masking cost (match_epochs early-outs on an empty buffer); the
        # buffer grows to table.tombstone_capacity on first delete().
        return TableState(
            base=state,
            deltas=(),
            tombstones=empty_tombstones(0, table.schema.key_lanes),
            table=table,
        )
    raise TypeError(
        f"expected TableState or DistributedHashGraph, got {type(state).__name__}"
    )
