"""Table schema — key width and payload shape for the HashGraph stack.

The paper targets 32-bit keys with a single int32 payload; its headline
applications (database joins, DNA k-mers) need 64-bit keys and wider
payloads.  A :class:`TableSchema` names the key dtype (``uint32`` or
``uint64``) and the number of int32 payload columns; the whole
build/query/retrieve/join data path is polymorphic over it.

Representation
--------------
JAX on TPU has no native 64-bit integer lanes (and ``jax_enable_x64`` is
off by default), so a 64-bit key is stored **packed as two uint32 lanes**:

* 1-lane keys: a ``(N,)`` uint32 array — the paper's layout, unchanged.
* 2-lane keys: a ``(N, 2)`` uint32 array with ``[:, 0]`` the low word and
  ``[:, 1]`` the high word (little-endian word order, matching the
  4-byte-block order MurmurHash3_x86_32 consumes — see
  ``hashing.murmur3_packed``).

Payloads are ``(N,)`` int32 for a single column or ``(N, C)`` int32 for
``C`` columns.  Every core routine accepts either layout; the 1-D forms
are the exact PR-1 API and stay bit-identical.

Host-side packing helpers (``pack_u64`` / ``unpack_u64``) convert numpy
uint64 arrays to and from the two-lane layout without ever materializing
64-bit integers on device.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

_KEY_DTYPES = ("uint32", "uint64")


def pack_u64(keys) -> jax.Array:
    """Host-side: numpy uint64 (or python ints) ``(N,)`` → ``(N, 2)`` uint32.

    Lane 0 is the low 32 bits, lane 1 the high 32 bits.
    """
    a = np.asarray(keys, dtype=np.uint64)
    lo = (a & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (a >> np.uint64(32)).astype(np.uint32)
    return jnp.asarray(np.stack([lo, hi], axis=-1))


def unpack_u64(packed) -> np.ndarray:
    """Host-side inverse of :func:`pack_u64`: ``(N, 2)`` uint32 → np.uint64."""
    a = np.asarray(packed)
    lo = a[..., 0].astype(np.uint64)
    hi = a[..., 1].astype(np.uint64)
    return (hi << np.uint64(32)) | lo


@dataclasses.dataclass(frozen=True)
class TableSchema:
    """Key width + payload shape of one hash table.

    ``key_dtype`` — ``"uint32"`` (1 lane) or ``"uint64"`` (2 packed lanes).
    ``value_cols`` — number of int32 payload columns (1 keeps the PR-1
    1-D layout; >1 stores ``(N, C)``).
    """

    key_dtype: str = "uint32"
    value_cols: int = 1

    def __post_init__(self):
        if self.key_dtype not in _KEY_DTYPES:
            raise ValueError(
                f"key_dtype must be one of {_KEY_DTYPES}, got {self.key_dtype!r}"
            )
        if not 1 <= int(self.value_cols):
            raise ValueError(f"value_cols must be >= 1, got {self.value_cols}")

    @property
    def key_lanes(self) -> int:
        return 2 if self.key_dtype == "uint64" else 1

    # -- device-array canonicalization --------------------------------------
    def pack_keys(self, keys) -> jax.Array:
        """Canonical device layout: ``(N,)`` uint32 or ``(N, 2)`` uint32.

        Accepts host numpy arrays (uint64 arrays are split into lanes) or
        already-packed device arrays; validates the lane count.
        """
        if isinstance(keys, np.ndarray) and keys.dtype in (np.uint64, np.int64):
            if self.key_lanes == 2:
                if keys.dtype == np.int64:
                    if (keys < 0).any():
                        raise ValueError("uint64 schema got negative int64 keys")
                    keys = keys.astype(np.uint64)
                keys = pack_u64(keys)
            else:
                # 1-lane schema: reject wide values instead of wrapping mod 2^32.
                if (keys < 0).any() or (keys > 0xFFFFFFFF).any():
                    raise ValueError(
                        "uint32 schema got 64-bit key values out of range; "
                        "use TableSchema('uint64')"
                    )
                keys = keys.astype(np.uint32)
        keys = jnp.asarray(keys)
        keys = keys.astype(jnp.uint32)
        if self.key_lanes == 1:
            if keys.ndim != 1:
                raise ValueError(
                    f"uint32 schema expects (N,) keys, got shape {keys.shape}"
                )
        else:
            if keys.ndim != 2 or keys.shape[-1] != 2:
                raise ValueError(
                    f"uint64 schema expects (N, 2) packed uint32 keys "
                    f"(see schema.pack_u64), got shape {keys.shape}"
                )
        return keys

    def pack_values(self, values) -> jax.Array:
        """Canonical payload layout: ``(N,)`` or ``(N, C)`` int32."""
        values = jnp.asarray(values).astype(jnp.int32)
        if self.value_cols == 1:
            if values.ndim == 2 and values.shape[-1] == 1:
                values = values[:, 0]
            if values.ndim != 1:
                raise ValueError(
                    f"1-column schema expects (N,) values, got {values.shape}"
                )
        else:
            if values.ndim != 2 or values.shape[-1] != self.value_cols:
                raise ValueError(
                    f"schema expects (N, {self.value_cols}) values, "
                    f"got shape {values.shape}"
                )
        return values
