"""Multi-device HashGraph — Alg. 2 of the paper, on a TPU mesh.

Every function here runs *inside* ``shard_map`` over the device axes named
in ``axis_names`` (the hash table treats the whole mesh — e.g. ``("pod",
"data", "model")`` — as a flat 1-D device space; the exchange itself is
hierarchical per axis, see ``repro.core.exchange``).

Build (:func:`build_sharded`) follows the paper's four phases:

1. **Partitioning** — local coarse-bin histogram, ``psum``, balanced splits
   (``repro.core.partition``).
2. **Reorganization** — counting-sort keys by destination device.
3. **Movement** — capacity-padded hierarchical all-to-all.
4. **Creation** — single-device HashGraph per shard over its hash range.

Query (:func:`query_sharded`) is the paper's query: route query keys with
the *same* splits, intersect against the local table, route counts back.

Static-shape note: a device's hash-range width ``splits[d+1]-splits[d]`` is
data-dependent, but XLA needs a static local table size.  We allocate
``local_range_cap = ceil(HR/D) * range_slack`` buckets and clamp rebased
hash values into the last bucket.  Both build and query clamp through the
same deterministic map, so matching is exact even when clamping fires
(clamped buckets just get longer lists — HashGraph's collision handling
absorbs this, the paper's headline robustness property).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import exchange, hashing, hashgraph, partition
from repro.core.hashgraph import EMPTY_KEY, HashGraph
from repro.utils import cdiv


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("local", "hash_splits", "num_dropped"),
    meta_fields=("hash_range", "seed", "local_range_cap", "axis_names", "bucket_stride"),
)
@dataclasses.dataclass(frozen=True)
class DistributedHashGraph:
    """Per-device shard of the distributed table (inside shard_map).

    ``bucket_stride`` coarsens the rebased-hash → local-bucket map:
    ``bucket = clip((h - lo) // stride, 0, local_range_cap - 1)``.  The base
    graph uses stride 1 (one bucket per hash value slot); delta graphs built
    on the base's *frozen* splits shrink their offsets arrays by striding
    instead of narrowing the hash range, which keeps routing identical
    across the layer stack (the partition-coherence invariant behind
    single-route layered execution).  Striding only lengthens bucket lists;
    the sorted-bucket binary search absorbs it exactly like split clamping.
    """

    local: HashGraph  # this device's CSR over its hash range
    hash_splits: jax.Array  # (D+1,) int32 — identical on all devices
    num_dropped: jax.Array  # () int32 — capacity overflow during build
    hash_range: int
    seed: int
    local_range_cap: int
    axis_names: tuple
    bucket_stride: int = 1


def default_capacity(n_local: int, num_devices: int, slack: float) -> int:
    """Per-destination slot size: balanced share × slack, lane-aligned."""
    base = cdiv(n_local, num_devices)
    cap = int(base * slack) + 8
    return cdiv(cap, 8) * 8


def _rebase_buckets(
    h: jax.Array,
    is_pad: jax.Array,
    lo: jax.Array,
    local_cap: int,
    stride: int,
) -> jax.Array:
    """Rebased hash → local bucket id, sentinel keys → trash bucket.

    Split off from the hashing so the fused layered paths hash a routed
    batch once and rebase per layer (layers share ``hash_range``/``seed``
    but may differ in ``local_cap``/``stride``).
    """
    rebased = h - lo
    if stride != 1:
        rebased = rebased // jnp.int32(stride)
    rebased = jnp.clip(rebased, 0, local_cap - 1)
    return jnp.where(is_pad, jnp.int32(local_cap), rebased)


def _local_buckets(
    keys: jax.Array,
    lo: jax.Array,
    hash_range: int,
    local_cap: int,
    seed: int,
    stride: int = 1,
) -> jax.Array:
    """Rebased hash → local bucket id, sentinel keys → trash bucket."""
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    is_pad = hashgraph.is_empty_key(keys)
    return _rebase_buckets(h, is_pad, lo, local_cap, stride)


def build_sharded(
    keys: jax.Array,
    *,
    hash_range: int,
    axis_names: Sequence[str],
    values: Optional[jax.Array] = None,
    num_bins: Optional[int] = None,
    capacity_slack: float = 1.25,
    range_slack: float = 1.5,
    seed: int = hashing.DEFAULT_SEED,
    capacity: Optional[int] = None,
    hash_splits: Optional[jax.Array] = None,
    local_range_cap: Optional[int] = None,
    bucket_stride: int = 1,
    fingerprint: Optional[bool] = None,
    dest_offsets: Optional[jax.Array] = None,
) -> DistributedHashGraph:
    """Build the distributed HashGraph from this device's local ``keys``.

    ``values`` (payload, e.g. original global row ids for joins) ride along
    through the exchange.  ``keys`` may contain EMPTY sentinels (compaction
    rebuilds ship tombstoned rows masked to EMPTY): sentinels are excluded
    from the balanced-split histogram and the overflow count, spread
    round-robin over destinations, and land in the owner's trash bucket.
    ``capacity`` overrides the per-destination slot size (compaction passes
    an allowance for the sentinel rows).

    ``hash_splits`` *freezes* the partitioning: phase 1 (histogram → psum →
    balanced splits) is skipped entirely and the given split points route
    the exchange.  This is how delta graphs stay partition-coherent with
    their base — same hash range, same seed, same owners — so one query
    dispatch serves the whole layer stack.  ``local_range_cap`` /
    ``bucket_stride`` size the local bucket space (deltas stride the base's
    bucket map down to O(batch) offsets instead of paying the base's
    O(hash_range / D) arrays).  ``fingerprint`` selects the probe
    fingerprint lane for the local CSR (None = auto by key width, see
    :func:`repro.core.hashgraph.build_from_buckets`); the fingerprints are
    derived owner-side from the routed keys, so the exchange itself is
    unchanged.  Call inside ``shard_map``.

    ``dest_offsets`` (hot-key replication) shifts each row's destination by
    a per-row device offset — ``(hash owner + offset) % D`` — so a single
    hot key's rows spread across ``R`` owners instead of funnelling into
    one device's dispatch slot.  Off-owner rows land in the receiving
    device's *clamped* bucket (``_rebase_buckets`` clips out-of-range
    buckets), where the exact key compare of every probe path still finds
    them; readers recover the full count by summing query rounds routed
    with each ``dest_offset`` (see ``query_sharded``).
    """
    axis_names = tuple(axis_names)
    keys = keys.astype(jnp.uint32)
    n_local = keys.shape[0]
    num_devices = exchange.device_count(axis_names)
    if values is None:
        # Globalize the default payload: original row id within this shard,
        # offset by the shard's rank so values are unique across devices.
        values = exchange.my_rank(axis_names) * n_local + jnp.arange(
            n_local, dtype=jnp.int32
        )
    is_pad = hashgraph.is_empty_key(keys)

    # ---- Phase 1: partitioning --------------------------------------------
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    if hash_splits is None:
        bins_g = num_bins or partition.choose_num_bins(hash_range, num_devices)
        hist = partition.local_bin_histogram(h, bins_g, hash_range, valid=~is_pad)
        ghist = jax.lax.psum(hist, axis_names)
        splits = partition.balanced_hash_splits(ghist, num_devices, hash_range)
    else:
        splits = hash_splits.astype(jnp.int32)  # frozen: no collective round

    # ---- Phase 2: reorganization ------------------------------------------
    dest = partition.destination_of(h, splits)
    if dest_offsets is not None:
        dest = (dest + dest_offsets.astype(jnp.int32)) % num_devices
    # Sentinels route round-robin (all EMPTY rows hash identically — sending
    # them by hash would funnel every one to a single owner's slot).
    dest = jnp.where(
        is_pad, jnp.arange(n_local, dtype=jnp.int32) % num_devices, dest
    )

    # ---- Phase 3: movement -------------------------------------------------
    if capacity is None:
        capacity = default_capacity(n_local, num_devices, capacity_slack)
    (rkeys, rvalues), route = exchange.dispatch(
        (keys, values),
        dest,
        axis_names,
        capacity,
        fills=(jnp.uint32(EMPTY_KEY), jnp.int32(-1)),
        count_mask=~is_pad,
    )

    # ---- Phase 4: local HashGraph creation ---------------------------------
    if local_range_cap is None:
        local_cap = int(cdiv(hash_range, num_devices) * range_slack)
    else:
        local_cap = int(local_range_cap)
    rank = exchange.my_rank(axis_names)
    lo = splits[rank]
    buckets = _local_buckets(rkeys, lo, hash_range, local_cap, seed, bucket_stride)
    local = hashgraph.build_from_buckets(
        rkeys,
        buckets,
        local_cap,
        rvalues,
        seed=seed,
        sort_within_bucket=True,
        fingerprint=fingerprint,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=splits,
        num_dropped=jax.lax.psum(route.num_dropped, axis_names),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=axis_names,
        bucket_stride=bucket_stride,
    )


def _route_queries_once(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    capacity_slack: float,
    dest_offset: int = 0,
) -> tuple[jax.Array, exchange.Route, jax.Array, jax.Array, jax.Array, int]:
    """The one exchange round of the query hot path (paper §3.3 phase 1).

    Hash local queries and dispatch them to their owning shards by the
    *build* splits of ``dhg``.  On a partition-coherent layer stack this
    single round serves every layer: the owner-side hash of the received
    keys is layer-independent (same hash range and seed), and each layer
    rebases it into its own bucket space via :func:`_rebase_buckets`.

    ``dest_offset`` (static) routes every query ``r`` devices past its hash
    owner — the read side of hot-key replication (``build_sharded``'s
    ``dest_offsets``): replica ``r`` of a hot key lives on device
    ``(owner + r) % D``, and a non-replicated key simply counts 0 there
    (the exact key compare finds nothing), so summing rounds over
    ``r = 0..R-1`` merges replica counts exactly.  The default 0 is guarded
    to keep the hot path's jaxpr byte-identical.

    Returns ``(rq, route, rh, is_pad, lo, capacity)`` — received queries
    (EMPTY-padded), the reverse route, their owner-side hash values, the
    padding mask, this owner's split base, and the per-(src, dst) slot
    capacity.
    """
    axis_names = dhg.axis_names
    queries = queries.astype(jnp.uint32)
    num_devices = exchange.device_count(axis_names)

    h = hashing.hash_to_buckets(queries, dhg.hash_range, seed=dhg.seed)
    dest = partition.destination_of(h, dhg.hash_splits)
    if dest_offset:
        dest = (dest + jnp.int32(dest_offset)) % num_devices
    capacity = default_capacity(queries.shape[0], num_devices, capacity_slack)
    (rq,), route = exchange.dispatch(
        (queries,), dest, axis_names, capacity, fills=(jnp.uint32(EMPTY_KEY),)
    )
    lo = dhg.hash_splits[exchange.my_rank(axis_names)]
    rh = hashing.hash_to_buckets(rq, dhg.hash_range, seed=dhg.seed)
    is_pad = hashgraph.is_empty_key(rq)
    return rq, route, rh, is_pad, lo, capacity


def _route_queries(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    capacity_slack: float,
    dest_offset: int = 0,
) -> tuple[jax.Array, exchange.Route, jax.Array, int]:
    """Single-graph routing preamble: :func:`_route_queries_once` plus this
    graph's own bucket rebase.

    Every per-layer query path (count, retrieve, planning, query-side
    HashGraph) routes through this one function: the planning round's
    correctness depends on using the exact same capacity and slot layout as
    retrieval.  Returns ``(rq, route, rbuckets, capacity)``.
    """
    rq, route, rh, is_pad, lo, capacity = _route_queries_once(
        dhg, queries, capacity_slack, dest_offset
    )
    rbuckets = _rebase_buckets(
        rh, is_pad, lo, dhg.local_range_cap, dhg.bucket_stride
    )
    return rq, route, rbuckets, capacity


def _routed_fingerprints(
    layers: Sequence[DistributedHashGraph], rq: jax.Array
) -> Optional[jax.Array]:
    """Probe fingerprints of a routed query batch, or None if no layer
    carries a fingerprint lane.

    Hashed once per exchange round and shared by every layer's locate —
    the fused stack pays one ``fingerprint32`` per routed batch, not per
    layer.  Layers without the lane simply ignore the precomputed values
    (``query_locate`` drops ``qfp`` for plain tables), so mixed stacks
    stay correct.
    """
    if any(layer.local.fingerprints is not None for layer in layers):
        return hashing.fingerprint32(rq)
    return None


def _tombstone_epochs(
    rq: jax.Array, tombstones: Optional[tuple[jax.Array, jax.Array]]
) -> Optional[jax.Array]:
    """Newest tombstone epoch per routed key, or None without tombstones.

    ``tombstones`` is the *sorted* ``(keys, epochs)`` index of the versioned
    table (``Tombstones.index()``): the lookup is one binary search per key
    — O(R log T) per routed batch instead of the old O(R·T) broadcast
    compare.  Computed once per routing round and shared by every layer's
    mask (a tombstone with epoch ``e`` hides layers ``0..e``).
    """
    if tombstones is None:
        return None
    ts_keys, ts_epochs = tombstones
    return hashgraph.match_epochs_sorted(rq, ts_keys, ts_epochs)


def _mask_counts(
    counts: jax.Array,
    rq: jax.Array,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
    match_e: Optional[jax.Array] = None,
) -> jax.Array:
    """Zero counts of padding slots and of rows hidden by tombstones.

    ``tombstones`` is the sorted ``(keys, epochs)`` index
    (``Tombstones.index()``); a row is hidden from the layer with epoch
    ``layer_epoch`` iff a matching tombstone with epoch >= ``layer_epoch``
    exists (deleted at or after this layer's creation).  ``match_e``
    short-circuits the lookup with a precomputed per-key epoch (the fused
    layered paths resolve it once per routed batch).
    """
    counts = jnp.where(hashgraph.is_empty_key(rq), 0, counts)
    if match_e is None:
        match_e = _tombstone_epochs(rq, tombstones)
    if match_e is not None:
        counts = jnp.where(match_e >= layer_epoch, 0, counts)
    return counts


def query_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    paper_faithful_probe: bool = False,
    max_probe: int = 64,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
    dest_offset: int = 0,
) -> jax.Array:
    """Multiplicity of each local query key in the distributed table.

    Phases (paper §3.3 "Querying Multi-GPU HashGraph"): route queries by the
    *build* splits, count against the local shard, route counts back.
    ``tombstones`` (the sorted ``Tombstones.index()`` pair) / ``layer_epoch``
    mask rows deleted from this layer of a versioned table (see
    :func:`_mask_counts`).  ``dest_offset`` counts replica ``r`` of
    hot-key-replicated rows (see :func:`_route_queries_once`).  Returns an
    int32 array aligned with ``queries``.
    """
    axis_names = dhg.axis_names
    rq, route, rbuckets, _ = _route_queries(
        dhg, queries, capacity_slack, dest_offset
    )
    if paper_faithful_probe:
        counts = hashgraph.query_count_probe(
            dhg.local, rq, max_probe=max_probe, buckets=rbuckets
        )
    else:
        counts = hashgraph.query_count_sorted(dhg.local, rq, buckets=rbuckets)
    # Padding slots probe the trash bucket; force their count to zero anyway.
    counts = _mask_counts(counts, rq, tombstones, layer_epoch)
    return exchange.combine(counts, route, axis_names, fill=jnp.int32(0))


def query_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    fused: Optional[bool] = None,
    capacity_slack: float = 1.25,
    paper_faithful_probe: bool = False,
    max_probe: int = 64,
    dest_offset: int = 0,
) -> jax.Array:
    """Merged multiplicity over a versioned stack of layers.

    ``layers`` is ``(base, delta_1, ..., delta_L)`` — layer ``i`` has epoch
    ``i``, so a tombstone stamped with epoch ``e`` hides layers ``0..e`` and
    leaves later inserts visible (delete-then-reinsert works).

    ``fused`` selects single-route execution: one dispatch all-to-all and
    one count return serve the whole stack (valid only when every layer
    shares the base's splits — the ``TableState.coherent`` invariant; the
    caller asserts it).  ``fused=False`` is the per-layer legacy path for
    mixed-split stacks (L dispatches, L returns).  ``None`` auto-selects
    fused only for the trivially coherent single-layer stack.
    """
    layers = tuple(layers)
    if fused is None:
        fused = len(layers) == 1
    if not fused:
        total = jnp.zeros(queries.shape[0], jnp.int32)
        for epoch, layer in enumerate(layers):
            total = total + query_sharded(
                layer,
                queries,
                tombstones=tombstones,
                layer_epoch=epoch,
                capacity_slack=capacity_slack,
                paper_faithful_probe=paper_faithful_probe,
                max_probe=max_probe,
                dest_offset=dest_offset,
            )
        return total

    base = layers[0]
    rq, route, rh, is_pad, lo, _ = _route_queries_once(
        base, queries, capacity_slack, dest_offset
    )
    match_e = _tombstone_epochs(rq, tombstones)
    rfp = _routed_fingerprints(layers, rq)
    total = jnp.zeros(rq.shape[0], jnp.int32)
    for epoch, layer in enumerate(layers):
        rb = _rebase_buckets(rh, is_pad, lo, layer.local_range_cap, layer.bucket_stride)
        if paper_faithful_probe:
            c = hashgraph.query_count_probe(
                layer.local, rq, max_probe=max_probe, buckets=rb
            )
        else:
            c = hashgraph.query_count_sorted(layer.local, rq, buckets=rb, qfp=rfp)
        total = total + _mask_counts(c, rq, tombstones, epoch, match_e)
    # One merged return trip carries the whole stack's counts.
    return exchange.combine(total, route, base.axis_names, fill=jnp.int32(0))


def contains_sharded(
    dhg: DistributedHashGraph, queries: jax.Array, **kw
) -> jax.Array:
    """Membership test for each local query key."""
    return query_sharded(dhg, queries, **kw) > 0


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("offsets", "values", "counts", "num_dropped", "layer_counts"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardRetrieval:
    """Per-device CSR of retrieved values (inside shard_map).

    Local query ``i``'s values are ``values[offsets[i]:offsets[i+1]]``.
    ``num_dropped`` is a *global* (psum'd) overflow indicator: zero iff no
    static capacity anywhere in the pipeline truncated results.  When
    positive it is an unnormalized tally (stage drops can double-count the
    same missing result), not an exact loss count — treat any nonzero value
    as "rerun with larger ``seg_capacity``/``out_capacity``".  Never
    silently truncated.

    ``layer_counts`` is the optional per-layer provenance breakdown
    (``retrieve(..., per_layer_counts=True)``): an ``(n_local_queries, L)``
    int32 array with ``layer_counts[i].sum() == counts[i]`` — query ``i``'s
    result count split by layer epoch (base first).  ``None`` unless
    requested; on the fused path it rides home inside the same single
    all-to-all as the values (the bitcast packing trick of
    ``exchange.combine_ragged``), so requesting it adds no collective round.
    """

    offsets: jax.Array  # (n_local_queries + 1,) int32
    values: jax.Array  # (out_capacity,) int32
    counts: jax.Array  # (n_local_queries,) int32
    num_dropped: jax.Array  # () int32, global
    layer_counts: Optional[jax.Array] = None  # (n_local_queries, L) int32


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("query_idx", "values", "num_results", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardJoin:
    """Per-device materialized join pairs (inside shard_map).

    ``(query_idx[j], values[j])`` for ``j < num_results[0]`` are the match
    pairs produced by this device's queries; ``query_idx`` is the *global*
    query row id (rank * n_local + local index).  Same ``num_dropped``
    contract as :class:`ShardRetrieval`.
    """

    query_idx: jax.Array  # (out_capacity,) int32, -1 beyond num_results
    values: jax.Array  # (out_capacity,) int32
    num_results: jax.Array  # (1,) int32 — this device's valid pair count
    num_dropped: jax.Array  # () int32, global


def _use_kernel_default(use_kernel: Optional[bool]) -> bool:
    """Resolve the kernel-path flag: auto-on on TPU, jnp fallback elsewhere."""
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return bool(use_kernel)


def _csr_gather_any(starts, counts, table, capacity: int, use_kernel: bool):
    """CSR gather via the Pallas kernel (TPU hot path) or the jnp idiom.

    Same ``(offsets, row_idx, gathered, num_dropped)`` contract either way;
    the kernel path is the ROADMAP "kernel-path retrieval" item.
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.csr_gather(starts, counts, table, capacity=capacity)
    return hashgraph.csr_gather(starts, counts, table, capacity)


def _retrieve_runs(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    capacity_slack: float,
    use_kernel: bool,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
):
    """One layer's owner-side gather + return trip.

    Pass 1 (count): route queries to owning shards by the build splits and
    locate each routed query's contiguous match run in the local CSR.
    Pass 2 (gather): each owner prefix-sums the run lengths *per source
    block* and gathers the matched values into one static segment per source
    (the HashGraph build idiom applied to results) — a single fused Pallas
    launch over all sources on the kernel path — then a reverse all-to-all
    returns segments and run lengths to the querying shard.

    Returns ``(counts, starts, seg_flat, dropped)`` in the querier's local
    row order: row ``i``'s values are
    ``seg_flat[starts[i] : starts[i] + counts[i]]``.
    """
    axis_names = dhg.axis_names
    num_devices = exchange.device_count(axis_names)

    rq, route, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    run_starts, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = _mask_counts(run_counts, rq, tombstones, layer_epoch)

    # Owner side: one packed segment of matched values per source device.
    starts_b = run_starts.reshape(num_devices, capacity)
    counts_b = run_counts.reshape(num_devices, capacity)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        # Fused launch: one grid over (sources, capacity tiles) instead of
        # one pallas_call per source block.
        _, _, seg_values, owner_dropped = kernel_ops.csr_gather_batched(
            starts_b, counts_b, dhg.local.values, capacity=seg_capacity
        )
    else:
        _, _, seg_values, seg_dropped = jax.vmap(
            lambda s, c: hashgraph.csr_gather(s, c, dhg.local.values, seg_capacity)
        )(starts_b, counts_b)
        owner_dropped = jnp.sum(seg_dropped)

    # Querier side: segments + run lengths come home.
    counts, starts, seg_flat = exchange.combine_ragged(
        seg_values, run_counts, route, axis_names
    )
    return counts, starts, seg_flat, owner_dropped + route.num_dropped


def _layer_run_descriptors(
    layers: Sequence[DistributedHashGraph],
    rq: jax.Array,
    rh: jax.Array,
    is_pad: jax.Array,
    lo: jax.Array,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
) -> tuple[jax.Array, jax.Array, tuple]:
    """Owner-side batched locate across a partition-coherent layer stack.

    One binary-search locate per layer against the *same* routed batch
    (compute only — no communication), with each layer's run starts offset
    into the concatenated value-table address space.  Tombstone epochs are
    resolved once for the batch and reused by every layer's mask.

    Returns ``(starts, counts, tables)``: ``(L, R)`` stacked descriptors
    (``R`` = routed slots) addressing ``jnp.concatenate(tables)``.
    """
    match_e = _tombstone_epochs(rq, tombstones)
    rfp = _routed_fingerprints(layers, rq)
    starts_l, counts_l, tables = [], [], []
    off = 0
    for epoch, layer in enumerate(layers):
        rb = _rebase_buckets(rh, is_pad, lo, layer.local_range_cap, layer.bucket_stride)
        s, c = hashgraph.query_locate(layer.local, rq, buckets=rb, qfp=rfp)
        c = _mask_counts(c, rq, tombstones, epoch, match_e)
        starts_l.append(s + off)
        counts_l.append(c)
        tables.append(layer.local.values)
        off += layer.local.values.shape[0]
    return jnp.stack(starts_l), jnp.stack(counts_l), tuple(tables)


def _csr_gather_layers_ref(starts, counts, tables, capacity: int):
    """jnp reference of ``kernels.ops.csr_gather_layers``: a vmapped
    ``hashgraph.csr_gather`` over the *same* interleaved descriptors (the
    packing order has exactly one definition —
    ``kernels.ops.interleave_layer_runs``)."""
    from repro.kernels.ops import interleave_layer_runs

    starts_i, counts_i, table_cat = interleave_layer_runs(starts, counts, tables)
    _, _, seg_values, seg_dropped = jax.vmap(
        lambda a, b: hashgraph.csr_gather(a, b, table_cat, capacity)
    )(starts_i, counts_i)
    return seg_values, jnp.sum(seg_dropped)


def _retrieve_parts_fused(
    layers: tuple,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float,
    use_kernel: bool,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    per_layer: bool = False,
):
    """Single-route merged retrieval over a partition-coherent layer stack.

    One dispatch all-to-all routes the queries for *every* layer at once
    (all layers share the base's splits); owner-side, the per-layer locates
    run back-to-back on the routed batch and one fused gather packs each
    routed query's runs — layer-minor, epoch order — into a single segment
    per source device; one ragged return ships segments + per-slot totals
    home.  Collective rounds per retrieve: 2, independent of delta depth
    (previously ``~3·L``).

    ``per_layer=True`` additionally returns the per-layer count breakdown
    (``(n_local, L)``): the owner's per-layer run-length planes are bitcast
    into the same fused return buffer (``exchange.combine_ragged``'s
    ``layer_counts``), so provenance costs zero extra collective rounds.
    """
    base = layers[0]
    nlayers = len(layers)
    axis_names = base.axis_names
    num_devices = exchange.device_count(axis_names)
    n_local = queries.shape[0]
    rank = exchange.my_rank(axis_names)

    rq, route, rh, is_pad, lo, capacity = _route_queries_once(
        base, queries, capacity_slack
    )
    starts_lr, counts_lr, tables = _layer_run_descriptors(
        layers, rq, rh, is_pad, lo, tombstones
    )
    # (L, D*cap) -> (L, D, cap): the gather's source axis is the dispatching
    # device, its row axis the slot-major/layer-minor interleaved runs.
    starts_lsn = starts_lr.reshape(nlayers, num_devices, capacity)
    counts_lsn = counts_lr.reshape(nlayers, num_devices, capacity)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        seg_values, owner_dropped = kernel_ops.csr_gather_layers(
            starts_lsn, counts_lsn, tables, capacity=seg_capacity
        )
    else:
        seg_values, owner_dropped = _csr_gather_layers_ref(
            starts_lsn, counts_lsn, tables, seg_capacity
        )

    # One ragged return: per-slot totals over the stack reconstruct, on the
    # querier, exactly the interleaved offsets the owner packed with.
    slot_totals = jnp.sum(counts_lr, axis=0)
    layer_breakdown = None
    if per_layer:
        counts, starts, seg_flat, layer_breakdown = exchange.combine_ragged(
            seg_values, slot_totals, route, axis_names, layer_counts=counts_lr
        )
    else:
        counts, starts, seg_flat = exchange.combine_ragged(
            seg_values, slot_totals, route, axis_names
        )
    offsets, slot_rows, values, out_dropped = _csr_gather_any(
        starts, counts, seg_flat, out_capacity, use_kernel
    )
    num_dropped = jax.lax.psum(
        owner_dropped + route.num_dropped + out_dropped, axis_names
    )
    return offsets, slot_rows, values, counts, num_dropped, rank, n_local, layer_breakdown


def _retrieve_parts(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    fused: Optional[bool] = None,
    per_layer: bool = False,
):
    """Merged two-pass retrieval over a layer stack; returns the local CSR.

    ``fused=True`` (valid only for partition-coherent stacks — the
    ``TableState.coherent`` invariant) takes
    :func:`_retrieve_parts_fused`: one exchange round for the whole stack.
    ``fused=False`` is the legacy per-layer path for mixed-split stacks:
    :func:`_retrieve_runs` per layer (base epoch 0, delta ``i`` epoch
    ``i``), then one querier-side gather compacts all layers' returned runs
    into the output CSR — the per-layer ``(start, count)`` run descriptors
    are interleaved query-major, so the standard ``csr_gather`` produces
    the merged values array directly and every L-th offset is the per-query
    merged offset.  ``None`` auto-selects fused only for the trivially
    coherent single-layer stack.

    ``use_kernel`` selects the Pallas ``csr_gather`` kernel for both gather
    stages (None = auto: on for TPU, jnp elsewhere).  Both paths produce
    identical outputs (same per-query epoch-order value runs), including the
    ``per_layer`` count breakdown (fused: shipped in the same all-to-all;
    legacy: stacked from the per-layer return trips).
    """
    layers = tuple(layers)
    nlayers = len(layers)
    use_kernel = _use_kernel_default(use_kernel)
    if fused is None:
        fused = nlayers == 1
    if fused:
        return _retrieve_parts_fused(
            layers,
            queries,
            seg_capacity=seg_capacity,
            out_capacity=out_capacity,
            capacity_slack=capacity_slack,
            use_kernel=use_kernel,
            tombstones=tombstones,
            per_layer=per_layer,
        )

    axis_names = layers[0].axis_names
    n_local = queries.shape[0]
    rank = exchange.my_rank(axis_names)

    counts_l, starts_l, segs_l = [], [], []
    dropped = jnp.int32(0)
    for epoch, layer in enumerate(layers):
        counts, starts, seg_flat, drop = _retrieve_runs(
            layer,
            queries,
            seg_capacity=seg_capacity,
            capacity_slack=capacity_slack,
            use_kernel=use_kernel,
            tombstones=tombstones,
            layer_epoch=epoch,
        )
        counts_l.append(counts)
        starts_l.append(starts + epoch * seg_flat.shape[0])
        segs_l.append(seg_flat)
        dropped = dropped + drop

    seg_all = segs_l[0] if nlayers == 1 else jnp.concatenate(segs_l, axis=0)
    counts_il = jnp.stack(counts_l, axis=1).reshape(n_local * nlayers)
    starts_il = jnp.stack(starts_l, axis=1).reshape(n_local * nlayers)
    offsets_il, slot_rows, values, out_dropped = _csr_gather_any(
        starts_il, counts_il, seg_all, out_capacity, use_kernel
    )
    offsets = offsets_il[::nlayers]  # every L-th interleaved offset
    counts = counts_il.reshape(n_local, nlayers).sum(axis=1).astype(jnp.int32)
    query_idx = jnp.where(slot_rows >= 0, slot_rows // nlayers, jnp.int32(-1))
    # Overflow indicator, not an exact loss count: the stages can
    # double-count one missing result (owner segment + querier output), and
    # route drops count lost query *rows* whose result count is unknown.
    # Zero iff nothing anywhere was truncated.
    num_dropped = jax.lax.psum(dropped + out_dropped, axis_names)
    layer_breakdown = (
        jnp.stack(counts_l, axis=1).astype(jnp.int32) if per_layer else None
    )
    return offsets, query_idx, values, counts, num_dropped, rank, n_local, layer_breakdown


def retrieve_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardRetrieval:
    """All stored values for every occurrence of every local query key.

    Returns this device's :class:`ShardRetrieval` CSR over its ``queries``.
    Call inside ``shard_map``.
    """
    return retrieve_layers_sharded(
        (dhg,),
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )


def retrieve_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    fused: Optional[bool] = None,
    per_layer_counts: bool = False,
) -> ShardRetrieval:
    """Merged retrieval over a versioned layer stack (base + deltas).

    Per-query values concatenate layer runs in epoch order; tombstoned rows
    are masked before the gather, so they consume no output capacity.
    ``fused`` selects single-route execution over a partition-coherent
    stack (see :func:`_retrieve_parts`).  ``per_layer_counts`` fills the
    result's ``layer_counts`` provenance field (``(n_local, L)``); on the
    fused path the planes ride the same single all-to-all as the values.
    Call inside ``shard_map``.
    """
    offsets, _, values, counts, num_dropped, _, _, layer_counts = _retrieve_parts(
        layers,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
        tombstones=tombstones,
        fused=fused,
        per_layer=per_layer_counts,
    )
    return ShardRetrieval(
        offsets=offsets,
        values=values,
        counts=counts,
        num_dropped=num_dropped,
        layer_counts=layer_counts,
    )


def inner_join_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardJoin:
    """Materialized inner join ``build ⋈ queries`` as global-row match pairs.

    Call inside ``shard_map``.
    """
    return inner_join_layers_sharded(
        (dhg,),
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )


def inner_join_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    fused: Optional[bool] = None,
) -> ShardJoin:
    """Materialized inner join against a versioned layer stack.

    Call inside ``shard_map``.
    """
    _, query_idx, values, counts, num_dropped, rank, n_local, _ = _retrieve_parts(
        layers,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
        tombstones=tombstones,
        fused=fused,
    )
    globl = rank.astype(jnp.int32) * n_local + query_idx
    query_idx = jnp.where(query_idx >= 0, globl, jnp.int32(-1))
    num_results = jnp.minimum(jnp.sum(counts), out_capacity).astype(jnp.int32)
    return ShardJoin(
        query_idx=query_idx,
        values=values,
        num_results=num_results[None],
        num_dropped=num_dropped,
    )


def _plan_block_totals(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
) -> jax.Array:
    """Owner-side result totals per source device for one layer: (D,) int32.

    Entry ``s`` is the number of values this owner will return to source
    ``s`` — exactly the quantity both capacity plans are built from.  Routes
    queries exactly like :func:`_retrieve_runs` pass 1 (same splits, same
    slack, so the same slot layout).
    """
    num_devices = exchange.device_count(dhg.axis_names)
    rq, _, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    _, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = _mask_counts(run_counts, rq, tombstones, layer_epoch)
    return jnp.sum(run_counts.reshape(num_devices, capacity), axis=1)


def plan_seg_capacity_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
) -> jax.Array:
    """Count-only planning round: the exact ``seg_capacity`` retrieval needs.

    ``pmax`` of the owner-side per-source totals across the mesh: the
    smallest segment width for which no owner→querier return segment
    overflows.  This is the ROADMAP "ragged all-to-all" counts round — a
    cheap reduction instead of shipping ``seg_capacity``-padded value
    segments sized by worst-case guesses.  Returns a replicated () int32.

    Call inside ``shard_map``.
    """
    block_totals = _plan_block_totals(
        dhg,
        queries,
        capacity_slack=capacity_slack,
        tombstones=tombstones,
        layer_epoch=layer_epoch,
    )
    return jax.lax.pmax(jnp.max(block_totals).astype(jnp.int32), dhg.axis_names)


def plan_out_capacity_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
) -> jax.Array:
    """Count-first output sizing: the exact ``out_capacity`` retrieval needs.

    ``psum`` of the owner-side per-source totals gives, per querying device,
    the total number of values it will receive; the max over devices is the
    smallest output CSR that fits every shard.  Same counts round as
    :func:`plan_seg_capacity_sharded` — ``retrieve`` never needs a
    worst-case output guess.  Returns a replicated () int32.

    Call inside ``shard_map``.
    """
    block_totals = _plan_block_totals(
        dhg,
        queries,
        capacity_slack=capacity_slack,
        tombstones=tombstones,
        layer_epoch=layer_epoch,
    )
    per_device = jax.lax.psum(block_totals, dhg.axis_names)  # (D,) replicated
    return jnp.max(per_device).astype(jnp.int32)


def plan_caps_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    fused: Optional[bool] = None,
) -> tuple[jax.Array, jax.Array]:
    """One counts round sizing both retrieval capacities over a layer stack.

    Returns replicated ``(seg_capacity, out_capacity)`` () int32 — the exact
    per-segment and per-device output widths a merged
    :func:`retrieve_layers_sharded` needs to drop nothing.  ``fused`` must
    match the execution path being planned for: fused retrieval packs *all*
    layers' runs into one segment per source (seg sized by the per-source
    totals summed over layers, one routing round), the legacy path one
    segment per (layer, source) pair (per-layer max, L rounds).  Call
    inside ``shard_map``.
    """
    layers = tuple(layers)
    axis_names = tuple(layers[0].axis_names)
    if fused is None:
        fused = len(layers) == 1
    if fused:
        base = layers[0]
        num_devices = exchange.device_count(axis_names)
        rq, _, rh, is_pad, lo, capacity = _route_queries_once(
            base, queries, capacity_slack
        )
        _, counts_lr, _ = _layer_run_descriptors(
            layers, rq, rh, is_pad, lo, tombstones
        )
        block_totals = jnp.sum(
            counts_lr.reshape(len(layers), num_devices, capacity), axis=(0, 2)
        )
        seg = jax.lax.pmax(jnp.max(block_totals).astype(jnp.int32), axis_names)
        out = jnp.max(jax.lax.psum(block_totals, axis_names)).astype(jnp.int32)
        return seg, out

    seg_need = jnp.int32(0)
    out_vec = jnp.int32(0)
    for epoch, layer in enumerate(layers):
        block_totals = _plan_block_totals(
            layer,
            queries,
            capacity_slack=capacity_slack,
            tombstones=tombstones,
            layer_epoch=epoch,
        )
        seg_need = jnp.maximum(seg_need, jnp.max(block_totals))
        out_vec = out_vec + block_totals
    seg = jax.lax.pmax(seg_need.astype(jnp.int32), axis_names)
    out = jnp.max(jax.lax.psum(out_vec, axis_names)).astype(jnp.int32)
    return seg, out


def build_query_hashgraph_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
) -> HashGraph:
    """Paper-literal query phase 1: a *second* HashGraph from the query set,
    sharing the build table's splits (used by the list-intersection path and
    the build-vs-query benchmark)."""
    rq, _, rbuckets, _ = _route_queries(dhg, queries, capacity_slack)
    return hashgraph.build_from_buckets(
        rq,
        rbuckets,
        dhg.local_range_cap,
        seed=dhg.seed,
        sort_within_bucket=True,
        fingerprint=dhg.local.fingerprints is not None,
    )


def join_size_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    **kw,
) -> jax.Array:
    """Global inner-join cardinality |build ⋈ query| (paper's intersection).

    Sum of per-query multiplicities, ``psum``-reduced across the mesh.
    """
    counts = query_sharded(dhg, queries, **kw)
    return jax.lax.psum(jnp.sum(counts), dhg.axis_names)


def join_size_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    **kw,
) -> jax.Array:
    """Global inner-join cardinality against a versioned layer stack."""
    counts = query_layers_sharded(layers, queries, **kw)
    return jax.lax.psum(jnp.sum(counts), tuple(layers[0].axis_names))


def fold_layers_local(
    layers: Sequence[DistributedHashGraph],
    *,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
) -> DistributedHashGraph:
    """Merge a partition-coherent layer prefix into one graph — NO exchange.

    The incremental-compaction primitive: ``layers`` is the oldest prefix
    ``(base, delta_1, ..., delta_k)`` of a coherent stack.  Because every
    delta was built on the base's frozen ``hash_splits``, each device
    already owns exactly the rows of its hash range *in every layer* — so
    the fold is a purely local rebuild: mask tombstoned rows to the EMPTY
    sentinel (per layer epoch, same rule as ``compact``), concatenate the
    local rows, re-bucket through the base's deterministic map, and
    counting-sort one fresh local CSR.  Zero collective rounds (the full
    ``compact`` pays a round-robin pre-balance all-to-all plus the build
    exchange) — which is what lets a serving loop run folds in the
    background without ever touching the read path's collective budget.

    ``tombstones`` is the *sorted* index pair (``Tombstones.index()``); a
    tombstone with epoch ``e`` hides layer ``i`` (0-based position in
    ``layers``) iff ``e >= i``.  The caller is responsible for remapping
    the surviving tombstones of the wider stack (epochs ``> k`` shift down
    by ``k`` — see ``repro.core.maintenance``).

    Invalid for mixed-split stacks: rows of an incoherent delta live on
    devices chosen by the *delta's* splits, so a local fold would break the
    routing invariant.  Call inside ``shard_map``.
    """
    layers = tuple(layers)
    base = layers[0]
    keys_parts, vals_parts = [], []
    dropped = base.num_dropped
    for epoch, layer in enumerate(layers):
        k = layer.local.keys
        dead = hashgraph.is_empty_key(k)
        if tombstones is not None and tombstones[0].shape[0]:
            hidden = (
                hashgraph.match_epochs_sorted(k, tombstones[0], tombstones[1])
                >= epoch
            )
            dead = dead | hidden
        dead_b = dead[:, None] if k.ndim == 2 else dead
        keys_parts.append(jnp.where(dead_b, jnp.uint32(EMPTY_KEY), k))
        vals_parts.append(layer.local.values)
        if epoch:
            dropped = dropped + layer.num_dropped
    keys_cat = jnp.concatenate(keys_parts, axis=0)
    vals_cat = jnp.concatenate(vals_parts, axis=0)
    rank = exchange.my_rank(base.axis_names)
    buckets = _local_buckets(
        keys_cat,
        base.hash_splits[rank],
        base.hash_range,
        base.local_range_cap,
        base.seed,
        base.bucket_stride,
    )
    local = hashgraph.build_from_buckets(
        keys_cat,
        buckets,
        base.local_range_cap,
        vals_cat,
        seed=base.seed,
        sort_within_bucket=True,
        fingerprint=base.local.fingerprints is not None,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=base.hash_splits,
        num_dropped=dropped,
        hash_range=base.hash_range,
        seed=base.seed,
        local_range_cap=base.local_range_cap,
        axis_names=base.axis_names,
        bucket_stride=base.bucket_stride,
    )
