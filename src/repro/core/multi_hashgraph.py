"""Multi-device HashGraph — Alg. 2 of the paper, on a TPU mesh.

Every function here runs *inside* ``shard_map`` over the device axes named
in ``axis_names`` (the hash table treats the whole mesh — e.g. ``("pod",
"data", "model")`` — as a flat 1-D device space; the exchange itself is
hierarchical per axis, see ``repro.core.exchange``).

Build (:func:`build_sharded`) follows the paper's four phases:

1. **Partitioning** — local coarse-bin histogram, ``psum``, balanced splits
   (``repro.core.partition``).
2. **Reorganization** — counting-sort keys by destination device.
3. **Movement** — capacity-padded hierarchical all-to-all.
4. **Creation** — single-device HashGraph per shard over its hash range.

Query (:func:`query_sharded`) is the paper's query: route query keys with
the *same* splits, intersect against the local table, route counts back.

Static-shape note: a device's hash-range width ``splits[d+1]-splits[d]`` is
data-dependent, but XLA needs a static local table size.  We allocate
``local_range_cap = ceil(HR/D) * range_slack`` buckets and clamp rebased
hash values into the last bucket.  Both build and query clamp through the
same deterministic map, so matching is exact even when clamping fires
(clamped buckets just get longer lists — HashGraph's collision handling
absorbs this, the paper's headline robustness property).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import exchange, hashing, hashgraph, partition
from repro.core.hashgraph import EMPTY_KEY, HashGraph
from repro.utils import cdiv


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("local", "hash_splits", "num_dropped"),
    meta_fields=("hash_range", "seed", "local_range_cap", "axis_names"),
)
@dataclasses.dataclass(frozen=True)
class DistributedHashGraph:
    """Per-device shard of the distributed table (inside shard_map)."""

    local: HashGraph  # this device's CSR over its hash range
    hash_splits: jax.Array  # (D+1,) int32 — identical on all devices
    num_dropped: jax.Array  # () int32 — capacity overflow during build
    hash_range: int
    seed: int
    local_range_cap: int
    axis_names: tuple


def default_capacity(n_local: int, num_devices: int, slack: float) -> int:
    """Per-destination slot size: balanced share × slack, lane-aligned."""
    base = cdiv(n_local, num_devices)
    cap = int(base * slack) + 8
    return cdiv(cap, 8) * 8


def _local_buckets(
    keys: jax.Array,
    lo: jax.Array,
    hash_range: int,
    local_cap: int,
    seed: int,
) -> jax.Array:
    """Rebasedhash → local bucket id, sentinel keys → trash bucket."""
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    rebased = jnp.clip(h - lo, 0, local_cap - 1)
    is_pad = hashgraph.is_empty_key(keys)
    return jnp.where(is_pad, jnp.int32(local_cap), rebased)


def build_sharded(
    keys: jax.Array,
    *,
    hash_range: int,
    axis_names: Sequence[str],
    values: Optional[jax.Array] = None,
    num_bins: Optional[int] = None,
    capacity_slack: float = 1.25,
    range_slack: float = 1.5,
    seed: int = hashing.DEFAULT_SEED,
) -> DistributedHashGraph:
    """Build the distributed HashGraph from this device's local ``keys``.

    ``values`` (payload, e.g. original global row ids for joins) ride along
    through the exchange.  Call inside ``shard_map``.
    """
    axis_names = tuple(axis_names)
    keys = keys.astype(jnp.uint32)
    n_local = keys.shape[0]
    num_devices = exchange.device_count(axis_names)
    if values is None:
        # Globalize the default payload: original row id within this shard,
        # offset by the shard's rank so values are unique across devices.
        values = exchange.my_rank(axis_names) * n_local + jnp.arange(
            n_local, dtype=jnp.int32
        )

    # ---- Phase 1: partitioning --------------------------------------------
    bins_g = num_bins or partition.choose_num_bins(hash_range, num_devices)
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    hist = partition.local_bin_histogram(h, bins_g, hash_range)
    ghist = jax.lax.psum(hist, axis_names)
    splits = partition.balanced_hash_splits(ghist, num_devices, hash_range)

    # ---- Phase 2: reorganization ------------------------------------------
    dest = partition.destination_of(h, splits)

    # ---- Phase 3: movement -------------------------------------------------
    capacity = default_capacity(n_local, num_devices, capacity_slack)
    (rkeys, rvalues), route = exchange.dispatch(
        (keys, values),
        dest,
        axis_names,
        capacity,
        fills=(jnp.uint32(EMPTY_KEY), jnp.int32(-1)),
    )

    # ---- Phase 4: local HashGraph creation ---------------------------------
    local_cap = int(cdiv(hash_range, num_devices) * range_slack)
    rank = exchange.my_rank(axis_names)
    lo = splits[rank]
    buckets = _local_buckets(rkeys, lo, hash_range, local_cap, seed)
    local = hashgraph.build_from_buckets(
        rkeys, buckets, local_cap, rvalues, seed=seed, sort_within_bucket=True
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=splits,
        num_dropped=jax.lax.psum(route.num_dropped, axis_names),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=axis_names,
    )


def _route_queries(
    dhg: DistributedHashGraph, queries: jax.Array, capacity_slack: float
) -> tuple[jax.Array, exchange.Route, jax.Array, int]:
    """Shared query-routing preamble (paper §3.3 phase 1).

    Hash local queries, dispatch them to their owning shards by the *build*
    splits, and rebase the received keys into local bucket ids.  Every query
    path (count, retrieve, planning, query-side HashGraph) must route
    through this one function: the planning round's correctness depends on
    using the exact same capacity and slot layout as retrieval.

    Returns ``(rq, route, rbuckets, capacity)`` — received queries (padded
    with the EMPTY sentinel), the reverse route, their local bucket ids, and
    the per-(src, dst) slot capacity.
    """
    axis_names = dhg.axis_names
    queries = queries.astype(jnp.uint32)
    num_devices = exchange.device_count(axis_names)

    h = hashing.hash_to_buckets(queries, dhg.hash_range, seed=dhg.seed)
    dest = partition.destination_of(h, dhg.hash_splits)
    capacity = default_capacity(queries.shape[0], num_devices, capacity_slack)
    (rq,), route = exchange.dispatch(
        (queries,), dest, axis_names, capacity, fills=(jnp.uint32(EMPTY_KEY),)
    )
    lo = dhg.hash_splits[exchange.my_rank(axis_names)]
    rbuckets = _local_buckets(rq, lo, dhg.hash_range, dhg.local_range_cap, dhg.seed)
    return rq, route, rbuckets, capacity


def query_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    paper_faithful_probe: bool = False,
    max_probe: int = 64,
) -> jax.Array:
    """Multiplicity of each local query key in the distributed table.

    Phases (paper §3.3 "Querying Multi-GPU HashGraph"): route queries by the
    *build* splits, count against the local shard, route counts back.
    Returns an int32 array aligned with ``queries``.
    """
    axis_names = dhg.axis_names
    rq, route, rbuckets, _ = _route_queries(dhg, queries, capacity_slack)
    if paper_faithful_probe:
        counts = hashgraph.query_count_probe(
            dhg.local, rq, max_probe=max_probe, buckets=rbuckets
        )
    else:
        counts = hashgraph.query_count_sorted(dhg.local, rq, buckets=rbuckets)
    # Padding slots probe the trash bucket; force their count to zero anyway.
    counts = jnp.where(hashgraph.is_empty_key(rq), 0, counts)
    return exchange.combine(counts, route, axis_names, fill=jnp.int32(0))


def contains_sharded(
    dhg: DistributedHashGraph, queries: jax.Array, **kw
) -> jax.Array:
    """Membership test for each local query key."""
    return query_sharded(dhg, queries, **kw) > 0


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("offsets", "values", "counts", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardRetrieval:
    """Per-device CSR of retrieved values (inside shard_map).

    Local query ``i``'s values are ``values[offsets[i]:offsets[i+1]]``.
    ``num_dropped`` is a *global* (psum'd) overflow indicator: zero iff no
    static capacity anywhere in the pipeline truncated results.  When
    positive it is an unnormalized tally (stage drops can double-count the
    same missing result), not an exact loss count — treat any nonzero value
    as "rerun with larger ``seg_capacity``/``out_capacity``".  Never
    silently truncated.
    """

    offsets: jax.Array  # (n_local_queries + 1,) int32
    values: jax.Array  # (out_capacity,) int32
    counts: jax.Array  # (n_local_queries,) int32
    num_dropped: jax.Array  # () int32, global


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("query_idx", "values", "num_results", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardJoin:
    """Per-device materialized join pairs (inside shard_map).

    ``(query_idx[j], values[j])`` for ``j < num_results[0]`` are the match
    pairs produced by this device's queries; ``query_idx`` is the *global*
    query row id (rank * n_local + local index).  Same ``num_dropped``
    contract as :class:`ShardRetrieval`.
    """

    query_idx: jax.Array  # (out_capacity,) int32, -1 beyond num_results
    values: jax.Array  # (out_capacity,) int32
    num_results: jax.Array  # (1,) int32 — this device's valid pair count
    num_dropped: jax.Array  # () int32, global


def _use_kernel_default(use_kernel: Optional[bool]) -> bool:
    """Resolve the kernel-path flag: auto-on on TPU, jnp fallback elsewhere."""
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return bool(use_kernel)


def _csr_gather_any(starts, counts, table, capacity: int, use_kernel: bool):
    """CSR gather via the Pallas kernel (TPU hot path) or the jnp idiom.

    Same ``(offsets, row_idx, gathered, num_dropped)`` contract either way;
    the kernel path is the ROADMAP "kernel-path retrieval" item.
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.csr_gather(starts, counts, table, capacity=capacity)
    return hashgraph.csr_gather(starts, counts, table, capacity)


def _retrieve_parts(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
):
    """Shared two-pass distributed retrieval; returns the final local CSR.

    Pass 1 (count): route queries to owning shards by the build splits and
    locate each routed query's contiguous match run in the local CSR.
    Pass 2 (gather): each owner prefix-sums the run lengths *per source
    block* and gathers the matched values into one static segment per source
    (the HashGraph build idiom applied to results), then a reverse
    all-to-all returns segments and run lengths to the querying shard, which
    compacts them into its local output CSR.

    ``use_kernel`` selects the Pallas ``csr_gather`` kernel for both gather
    stages (None = auto: on for TPU, jnp elsewhere).
    """
    axis_names = dhg.axis_names
    n_local = queries.shape[0]
    num_devices = exchange.device_count(axis_names)
    use_kernel = _use_kernel_default(use_kernel)
    rank = exchange.my_rank(axis_names)

    rq, route, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    run_starts, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = jnp.where(hashgraph.is_empty_key(rq), 0, run_counts)

    # Owner side: one packed segment of matched values per source device.
    starts_b = run_starts.reshape(num_devices, capacity)
    counts_b = run_counts.reshape(num_devices, capacity)
    if use_kernel:
        # Static per-source loop: the kernel is invoked once per source
        # block (grid-parallel internally) instead of vmapping pallas_call.
        segs, seg_drops = [], []
        for s in range(num_devices):
            _, _, g, dr = _csr_gather_any(
                starts_b[s], counts_b[s], dhg.local.values, seg_capacity, True
            )
            segs.append(g)
            seg_drops.append(dr)
        seg_values = jnp.stack(segs)
        owner_dropped = jnp.sum(jnp.stack(seg_drops))
    else:
        _, _, seg_values, seg_dropped = jax.vmap(
            lambda s, c: hashgraph.csr_gather(s, c, dhg.local.values, seg_capacity)
        )(starts_b, counts_b)
        owner_dropped = jnp.sum(seg_dropped)

    # Querier side: segments + run lengths come home; compact to local CSR.
    counts, starts, seg_flat = exchange.combine_ragged(
        seg_values, run_counts, route, axis_names
    )
    offsets, query_idx, values, out_dropped = _csr_gather_any(
        starts, counts, seg_flat, out_capacity, use_kernel
    )
    # Overflow indicator, not an exact loss count: the three stages can
    # double-count one missing result (owner segment + querier output), and
    # route.num_dropped counts lost query *rows* whose result count is
    # unknown.  Zero iff nothing anywhere was truncated.
    num_dropped = jax.lax.psum(
        owner_dropped + out_dropped + route.num_dropped, axis_names
    )
    return offsets, query_idx, values, counts, num_dropped, rank, n_local


def retrieve_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardRetrieval:
    """All stored values for every occurrence of every local query key.

    Returns this device's :class:`ShardRetrieval` CSR over its ``queries``.
    Call inside ``shard_map``.
    """
    offsets, _, values, counts, num_dropped, _, _ = _retrieve_parts(
        dhg,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )
    return ShardRetrieval(
        offsets=offsets, values=values, counts=counts, num_dropped=num_dropped
    )


def inner_join_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardJoin:
    """Materialized inner join ``build ⋈ queries`` as global-row match pairs.

    Call inside ``shard_map``.
    """
    _, query_idx, values, counts, num_dropped, rank, n_local = _retrieve_parts(
        dhg,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )
    globl = rank.astype(jnp.int32) * n_local + query_idx
    query_idx = jnp.where(query_idx >= 0, globl, jnp.int32(-1))
    num_results = jnp.minimum(jnp.sum(counts), out_capacity).astype(jnp.int32)
    return ShardJoin(
        query_idx=query_idx,
        values=values,
        num_results=num_results[None],
        num_dropped=num_dropped,
    )


def plan_seg_capacity_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
) -> jax.Array:
    """Count-only planning round: the exact ``seg_capacity`` retrieval needs.

    Routes queries exactly like :func:`_retrieve_parts` pass 1 (same splits,
    same slack, so the same slot layout), sums each source block's match-run
    lengths on the owner, and ``pmax``-reduces across the mesh: the result is
    the smallest segment width for which no owner→querier return segment
    overflows.  This is the ROADMAP "ragged all-to-all" counts round — a
    cheap reduction instead of shipping ``seg_capacity``-padded value
    segments sized by worst-case guesses.  Returns a replicated () int32.

    Call inside ``shard_map``.
    """
    axis_names = dhg.axis_names
    num_devices = exchange.device_count(axis_names)
    rq, _, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    _, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = jnp.where(hashgraph.is_empty_key(rq), 0, run_counts)
    block_totals = jnp.sum(run_counts.reshape(num_devices, capacity), axis=1)
    return jax.lax.pmax(jnp.max(block_totals).astype(jnp.int32), axis_names)


def build_query_hashgraph_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
) -> HashGraph:
    """Paper-literal query phase 1: a *second* HashGraph from the query set,
    sharing the build table's splits (used by the list-intersection path and
    the build-vs-query benchmark)."""
    rq, _, rbuckets, _ = _route_queries(dhg, queries, capacity_slack)
    return hashgraph.build_from_buckets(
        rq, rbuckets, dhg.local_range_cap, seed=dhg.seed, sort_within_bucket=True
    )


def join_size_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    **kw,
) -> jax.Array:
    """Global inner-join cardinality |build ⋈ query| (paper's intersection).

    Sum of per-query multiplicities, ``psum``-reduced across the mesh.
    """
    counts = query_sharded(dhg, queries, **kw)
    return jax.lax.psum(jnp.sum(counts), dhg.axis_names)
