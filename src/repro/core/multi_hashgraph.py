"""Multi-device HashGraph — Alg. 2 of the paper, on a TPU mesh.

Every function here runs *inside* ``shard_map`` over the device axes named
in ``axis_names`` (the hash table treats the whole mesh — e.g. ``("pod",
"data", "model")`` — as a flat 1-D device space; the exchange itself is
hierarchical per axis, see ``repro.core.exchange``).

Build (:func:`build_sharded`) follows the paper's four phases:

1. **Partitioning** — local coarse-bin histogram, ``psum``, balanced splits
   (``repro.core.partition``).
2. **Reorganization** — counting-sort keys by destination device.
3. **Movement** — capacity-padded hierarchical all-to-all.
4. **Creation** — single-device HashGraph per shard over its hash range.

Query (:func:`query_sharded`) is the paper's query: route query keys with
the *same* splits, intersect against the local table, route counts back.

Static-shape note: a device's hash-range width ``splits[d+1]-splits[d]`` is
data-dependent, but XLA needs a static local table size.  We allocate
``local_range_cap = ceil(HR/D) * range_slack`` buckets and clamp rebased
hash values into the last bucket.  Both build and query clamp through the
same deterministic map, so matching is exact even when clamping fires
(clamped buckets just get longer lists — HashGraph's collision handling
absorbs this, the paper's headline robustness property).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import exchange, hashing, hashgraph, partition
from repro.core.hashgraph import EMPTY_KEY, HashGraph
from repro.utils import cdiv


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("local", "hash_splits", "num_dropped"),
    meta_fields=("hash_range", "seed", "local_range_cap", "axis_names"),
)
@dataclasses.dataclass(frozen=True)
class DistributedHashGraph:
    """Per-device shard of the distributed table (inside shard_map)."""

    local: HashGraph  # this device's CSR over its hash range
    hash_splits: jax.Array  # (D+1,) int32 — identical on all devices
    num_dropped: jax.Array  # () int32 — capacity overflow during build
    hash_range: int
    seed: int
    local_range_cap: int
    axis_names: tuple


def default_capacity(n_local: int, num_devices: int, slack: float) -> int:
    """Per-destination slot size: balanced share × slack, lane-aligned."""
    base = cdiv(n_local, num_devices)
    cap = int(base * slack) + 8
    return cdiv(cap, 8) * 8


def _local_buckets(
    keys: jax.Array,
    lo: jax.Array,
    hash_range: int,
    local_cap: int,
    seed: int,
) -> jax.Array:
    """Rebasedhash → local bucket id, sentinel keys → trash bucket."""
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    rebased = jnp.clip(h - lo, 0, local_cap - 1)
    is_pad = hashgraph.is_empty_key(keys)
    return jnp.where(is_pad, jnp.int32(local_cap), rebased)


def build_sharded(
    keys: jax.Array,
    *,
    hash_range: int,
    axis_names: Sequence[str],
    values: Optional[jax.Array] = None,
    num_bins: Optional[int] = None,
    capacity_slack: float = 1.25,
    range_slack: float = 1.5,
    seed: int = hashing.DEFAULT_SEED,
    capacity: Optional[int] = None,
) -> DistributedHashGraph:
    """Build the distributed HashGraph from this device's local ``keys``.

    ``values`` (payload, e.g. original global row ids for joins) ride along
    through the exchange.  ``keys`` may contain EMPTY sentinels (compaction
    rebuilds ship tombstoned rows masked to EMPTY): sentinels are excluded
    from the balanced-split histogram and the overflow count, spread
    round-robin over destinations, and land in the owner's trash bucket.
    ``capacity`` overrides the per-destination slot size (compaction passes
    an allowance for the sentinel rows).  Call inside ``shard_map``.
    """
    axis_names = tuple(axis_names)
    keys = keys.astype(jnp.uint32)
    n_local = keys.shape[0]
    num_devices = exchange.device_count(axis_names)
    if values is None:
        # Globalize the default payload: original row id within this shard,
        # offset by the shard's rank so values are unique across devices.
        values = exchange.my_rank(axis_names) * n_local + jnp.arange(
            n_local, dtype=jnp.int32
        )
    is_pad = hashgraph.is_empty_key(keys)

    # ---- Phase 1: partitioning --------------------------------------------
    bins_g = num_bins or partition.choose_num_bins(hash_range, num_devices)
    h = hashing.hash_to_buckets(keys, hash_range, seed=seed)
    hist = partition.local_bin_histogram(h, bins_g, hash_range, valid=~is_pad)
    ghist = jax.lax.psum(hist, axis_names)
    splits = partition.balanced_hash_splits(ghist, num_devices, hash_range)

    # ---- Phase 2: reorganization ------------------------------------------
    dest = partition.destination_of(h, splits)
    # Sentinels route round-robin (all EMPTY rows hash identically — sending
    # them by hash would funnel every one to a single owner's slot).
    dest = jnp.where(
        is_pad, jnp.arange(n_local, dtype=jnp.int32) % num_devices, dest
    )

    # ---- Phase 3: movement -------------------------------------------------
    if capacity is None:
        capacity = default_capacity(n_local, num_devices, capacity_slack)
    (rkeys, rvalues), route = exchange.dispatch(
        (keys, values),
        dest,
        axis_names,
        capacity,
        fills=(jnp.uint32(EMPTY_KEY), jnp.int32(-1)),
        count_mask=~is_pad,
    )

    # ---- Phase 4: local HashGraph creation ---------------------------------
    local_cap = int(cdiv(hash_range, num_devices) * range_slack)
    rank = exchange.my_rank(axis_names)
    lo = splits[rank]
    buckets = _local_buckets(rkeys, lo, hash_range, local_cap, seed)
    local = hashgraph.build_from_buckets(
        rkeys, buckets, local_cap, rvalues, seed=seed, sort_within_bucket=True
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=splits,
        num_dropped=jax.lax.psum(route.num_dropped, axis_names),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=axis_names,
    )


def _route_queries(
    dhg: DistributedHashGraph, queries: jax.Array, capacity_slack: float
) -> tuple[jax.Array, exchange.Route, jax.Array, int]:
    """Shared query-routing preamble (paper §3.3 phase 1).

    Hash local queries, dispatch them to their owning shards by the *build*
    splits, and rebase the received keys into local bucket ids.  Every query
    path (count, retrieve, planning, query-side HashGraph) must route
    through this one function: the planning round's correctness depends on
    using the exact same capacity and slot layout as retrieval.

    Returns ``(rq, route, rbuckets, capacity)`` — received queries (padded
    with the EMPTY sentinel), the reverse route, their local bucket ids, and
    the per-(src, dst) slot capacity.
    """
    axis_names = dhg.axis_names
    queries = queries.astype(jnp.uint32)
    num_devices = exchange.device_count(axis_names)

    h = hashing.hash_to_buckets(queries, dhg.hash_range, seed=dhg.seed)
    dest = partition.destination_of(h, dhg.hash_splits)
    capacity = default_capacity(queries.shape[0], num_devices, capacity_slack)
    (rq,), route = exchange.dispatch(
        (queries,), dest, axis_names, capacity, fills=(jnp.uint32(EMPTY_KEY),)
    )
    lo = dhg.hash_splits[exchange.my_rank(axis_names)]
    rbuckets = _local_buckets(rq, lo, dhg.hash_range, dhg.local_range_cap, dhg.seed)
    return rq, route, rbuckets, capacity


def _mask_counts(
    counts: jax.Array,
    rq: jax.Array,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
) -> jax.Array:
    """Zero counts of padding slots and of rows hidden by tombstones.

    ``tombstones`` is the ``(ts_keys, ts_epochs)`` pair of the versioned
    table (see ``repro.core.state``); a row is hidden from the layer with
    epoch ``layer_epoch`` iff a matching tombstone with epoch >=
    ``layer_epoch`` exists (deleted at or after this layer's creation).
    """
    counts = jnp.where(hashgraph.is_empty_key(rq), 0, counts)
    if tombstones is not None:
        ts_keys, ts_epochs = tombstones
        hidden = hashgraph.match_epochs(rq, ts_keys, ts_epochs) >= layer_epoch
        counts = jnp.where(hidden, 0, counts)
    return counts


def query_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    paper_faithful_probe: bool = False,
    max_probe: int = 64,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
) -> jax.Array:
    """Multiplicity of each local query key in the distributed table.

    Phases (paper §3.3 "Querying Multi-GPU HashGraph"): route queries by the
    *build* splits, count against the local shard, route counts back.
    ``tombstones``/``layer_epoch`` mask rows deleted from this layer of a
    versioned table (see :func:`_mask_counts`).  Returns an int32 array
    aligned with ``queries``.
    """
    axis_names = dhg.axis_names
    rq, route, rbuckets, _ = _route_queries(dhg, queries, capacity_slack)
    if paper_faithful_probe:
        counts = hashgraph.query_count_probe(
            dhg.local, rq, max_probe=max_probe, buckets=rbuckets
        )
    else:
        counts = hashgraph.query_count_sorted(dhg.local, rq, buckets=rbuckets)
    # Padding slots probe the trash bucket; force their count to zero anyway.
    counts = _mask_counts(counts, rq, tombstones, layer_epoch)
    return exchange.combine(counts, route, axis_names, fill=jnp.int32(0))


def query_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    **kw,
) -> jax.Array:
    """Merged multiplicity over a versioned stack of layers.

    ``layers`` is ``(base, delta_1, ..., delta_L)`` — layer ``i`` has epoch
    ``i``, so a tombstone stamped with epoch ``e`` hides layers ``0..e`` and
    leaves later inserts visible (delete-then-reinsert works).
    """
    total = jnp.zeros(queries.shape[0], jnp.int32)
    for epoch, layer in enumerate(layers):
        total = total + query_sharded(
            layer, queries, tombstones=tombstones, layer_epoch=epoch, **kw
        )
    return total


def contains_sharded(
    dhg: DistributedHashGraph, queries: jax.Array, **kw
) -> jax.Array:
    """Membership test for each local query key."""
    return query_sharded(dhg, queries, **kw) > 0


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("offsets", "values", "counts", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardRetrieval:
    """Per-device CSR of retrieved values (inside shard_map).

    Local query ``i``'s values are ``values[offsets[i]:offsets[i+1]]``.
    ``num_dropped`` is a *global* (psum'd) overflow indicator: zero iff no
    static capacity anywhere in the pipeline truncated results.  When
    positive it is an unnormalized tally (stage drops can double-count the
    same missing result), not an exact loss count — treat any nonzero value
    as "rerun with larger ``seg_capacity``/``out_capacity``".  Never
    silently truncated.
    """

    offsets: jax.Array  # (n_local_queries + 1,) int32
    values: jax.Array  # (out_capacity,) int32
    counts: jax.Array  # (n_local_queries,) int32
    num_dropped: jax.Array  # () int32, global


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("query_idx", "values", "num_results", "num_dropped"),
    meta_fields=(),
)
@dataclasses.dataclass(frozen=True)
class ShardJoin:
    """Per-device materialized join pairs (inside shard_map).

    ``(query_idx[j], values[j])`` for ``j < num_results[0]`` are the match
    pairs produced by this device's queries; ``query_idx`` is the *global*
    query row id (rank * n_local + local index).  Same ``num_dropped``
    contract as :class:`ShardRetrieval`.
    """

    query_idx: jax.Array  # (out_capacity,) int32, -1 beyond num_results
    values: jax.Array  # (out_capacity,) int32
    num_results: jax.Array  # (1,) int32 — this device's valid pair count
    num_dropped: jax.Array  # () int32, global


def _use_kernel_default(use_kernel: Optional[bool]) -> bool:
    """Resolve the kernel-path flag: auto-on on TPU, jnp fallback elsewhere."""
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return bool(use_kernel)


def _csr_gather_any(starts, counts, table, capacity: int, use_kernel: bool):
    """CSR gather via the Pallas kernel (TPU hot path) or the jnp idiom.

    Same ``(offsets, row_idx, gathered, num_dropped)`` contract either way;
    the kernel path is the ROADMAP "kernel-path retrieval" item.
    """
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        return kernel_ops.csr_gather(starts, counts, table, capacity=capacity)
    return hashgraph.csr_gather(starts, counts, table, capacity)


def _retrieve_runs(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    capacity_slack: float,
    use_kernel: bool,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
):
    """One layer's owner-side gather + return trip.

    Pass 1 (count): route queries to owning shards by the build splits and
    locate each routed query's contiguous match run in the local CSR.
    Pass 2 (gather): each owner prefix-sums the run lengths *per source
    block* and gathers the matched values into one static segment per source
    (the HashGraph build idiom applied to results) — a single fused Pallas
    launch over all sources on the kernel path — then a reverse all-to-all
    returns segments and run lengths to the querying shard.

    Returns ``(counts, starts, seg_flat, dropped)`` in the querier's local
    row order: row ``i``'s values are
    ``seg_flat[starts[i] : starts[i] + counts[i]]``.
    """
    axis_names = dhg.axis_names
    num_devices = exchange.device_count(axis_names)

    rq, route, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    run_starts, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = _mask_counts(run_counts, rq, tombstones, layer_epoch)

    # Owner side: one packed segment of matched values per source device.
    starts_b = run_starts.reshape(num_devices, capacity)
    counts_b = run_counts.reshape(num_devices, capacity)
    if use_kernel:
        from repro.kernels import ops as kernel_ops

        # Fused launch: one grid over (sources, capacity tiles) instead of
        # one pallas_call per source block.
        _, _, seg_values, owner_dropped = kernel_ops.csr_gather_batched(
            starts_b, counts_b, dhg.local.values, capacity=seg_capacity
        )
    else:
        _, _, seg_values, seg_dropped = jax.vmap(
            lambda s, c: hashgraph.csr_gather(s, c, dhg.local.values, seg_capacity)
        )(starts_b, counts_b)
        owner_dropped = jnp.sum(seg_dropped)

    # Querier side: segments + run lengths come home.
    counts, starts, seg_flat = exchange.combine_ragged(
        seg_values, run_counts, route, axis_names
    )
    return counts, starts, seg_flat, owner_dropped + route.num_dropped


def _retrieve_parts(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
):
    """Merged two-pass retrieval over a layer stack; returns the local CSR.

    Runs :func:`_retrieve_runs` per layer (base epoch 0, delta ``i`` epoch
    ``i``), then compacts all layers' returned runs into one output CSR in a
    single gather: the per-layer ``(start, count)`` run descriptors are
    interleaved query-major — rows ``(q*L .. q*L+L-1)`` of the gather are
    query ``q``'s runs in layer order — so the standard ``csr_gather``
    produces the merged values array directly and every L-th offset is the
    per-query merged offset.

    ``use_kernel`` selects the Pallas ``csr_gather`` kernel for both gather
    stages (None = auto: on for TPU, jnp elsewhere).
    """
    layers = tuple(layers)
    nlayers = len(layers)
    axis_names = layers[0].axis_names
    n_local = queries.shape[0]
    use_kernel = _use_kernel_default(use_kernel)
    rank = exchange.my_rank(axis_names)

    counts_l, starts_l, segs_l = [], [], []
    dropped = jnp.int32(0)
    for epoch, layer in enumerate(layers):
        counts, starts, seg_flat, drop = _retrieve_runs(
            layer,
            queries,
            seg_capacity=seg_capacity,
            capacity_slack=capacity_slack,
            use_kernel=use_kernel,
            tombstones=tombstones,
            layer_epoch=epoch,
        )
        counts_l.append(counts)
        starts_l.append(starts + epoch * seg_flat.shape[0])
        segs_l.append(seg_flat)
        dropped = dropped + drop

    seg_all = segs_l[0] if nlayers == 1 else jnp.concatenate(segs_l, axis=0)
    counts_il = jnp.stack(counts_l, axis=1).reshape(n_local * nlayers)
    starts_il = jnp.stack(starts_l, axis=1).reshape(n_local * nlayers)
    offsets_il, slot_rows, values, out_dropped = _csr_gather_any(
        starts_il, counts_il, seg_all, out_capacity, use_kernel
    )
    offsets = offsets_il[::nlayers]  # every L-th interleaved offset
    counts = counts_il.reshape(n_local, nlayers).sum(axis=1).astype(jnp.int32)
    query_idx = jnp.where(slot_rows >= 0, slot_rows // nlayers, jnp.int32(-1))
    # Overflow indicator, not an exact loss count: the stages can
    # double-count one missing result (owner segment + querier output), and
    # route drops count lost query *rows* whose result count is unknown.
    # Zero iff nothing anywhere was truncated.
    num_dropped = jax.lax.psum(dropped + out_dropped, axis_names)
    return offsets, query_idx, values, counts, num_dropped, rank, n_local


def retrieve_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardRetrieval:
    """All stored values for every occurrence of every local query key.

    Returns this device's :class:`ShardRetrieval` CSR over its ``queries``.
    Call inside ``shard_map``.
    """
    return retrieve_layers_sharded(
        (dhg,),
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )


def retrieve_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
) -> ShardRetrieval:
    """Merged retrieval over a versioned layer stack (base + deltas).

    Per-query values concatenate layer runs in epoch order; tombstoned rows
    are masked before the gather, so they consume no output capacity.  Call
    inside ``shard_map``.
    """
    offsets, _, values, counts, num_dropped, _, _ = _retrieve_parts(
        layers,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
        tombstones=tombstones,
    )
    return ShardRetrieval(
        offsets=offsets, values=values, counts=counts, num_dropped=num_dropped
    )


def inner_join_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
) -> ShardJoin:
    """Materialized inner join ``build ⋈ queries`` as global-row match pairs.

    Call inside ``shard_map``.
    """
    return inner_join_layers_sharded(
        (dhg,),
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
    )


def inner_join_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    seg_capacity: int,
    out_capacity: int,
    capacity_slack: float = 1.25,
    use_kernel: Optional[bool] = None,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
) -> ShardJoin:
    """Materialized inner join against a versioned layer stack.

    Call inside ``shard_map``.
    """
    _, query_idx, values, counts, num_dropped, rank, n_local = _retrieve_parts(
        layers,
        queries,
        seg_capacity=seg_capacity,
        out_capacity=out_capacity,
        capacity_slack=capacity_slack,
        use_kernel=use_kernel,
        tombstones=tombstones,
    )
    globl = rank.astype(jnp.int32) * n_local + query_idx
    query_idx = jnp.where(query_idx >= 0, globl, jnp.int32(-1))
    num_results = jnp.minimum(jnp.sum(counts), out_capacity).astype(jnp.int32)
    return ShardJoin(
        query_idx=query_idx,
        values=values,
        num_results=num_results[None],
        num_dropped=num_dropped,
    )


def _plan_block_totals(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float,
    tombstones: Optional[tuple[jax.Array, jax.Array]],
    layer_epoch: int,
) -> jax.Array:
    """Owner-side result totals per source device for one layer: (D,) int32.

    Entry ``s`` is the number of values this owner will return to source
    ``s`` — exactly the quantity both capacity plans are built from.  Routes
    queries exactly like :func:`_retrieve_runs` pass 1 (same splits, same
    slack, so the same slot layout).
    """
    num_devices = exchange.device_count(dhg.axis_names)
    rq, _, rbuckets, capacity = _route_queries(dhg, queries, capacity_slack)
    _, run_counts = hashgraph.query_locate(dhg.local, rq, buckets=rbuckets)
    run_counts = _mask_counts(run_counts, rq, tombstones, layer_epoch)
    return jnp.sum(run_counts.reshape(num_devices, capacity), axis=1)


def plan_seg_capacity_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
) -> jax.Array:
    """Count-only planning round: the exact ``seg_capacity`` retrieval needs.

    ``pmax`` of the owner-side per-source totals across the mesh: the
    smallest segment width for which no owner→querier return segment
    overflows.  This is the ROADMAP "ragged all-to-all" counts round — a
    cheap reduction instead of shipping ``seg_capacity``-padded value
    segments sized by worst-case guesses.  Returns a replicated () int32.

    Call inside ``shard_map``.
    """
    block_totals = _plan_block_totals(
        dhg,
        queries,
        capacity_slack=capacity_slack,
        tombstones=tombstones,
        layer_epoch=layer_epoch,
    )
    return jax.lax.pmax(jnp.max(block_totals).astype(jnp.int32), dhg.axis_names)


def plan_out_capacity_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
    layer_epoch: int = 0,
) -> jax.Array:
    """Count-first output sizing: the exact ``out_capacity`` retrieval needs.

    ``psum`` of the owner-side per-source totals gives, per querying device,
    the total number of values it will receive; the max over devices is the
    smallest output CSR that fits every shard.  Same counts round as
    :func:`plan_seg_capacity_sharded` — ``retrieve`` never needs a
    worst-case output guess.  Returns a replicated () int32.

    Call inside ``shard_map``.
    """
    block_totals = _plan_block_totals(
        dhg,
        queries,
        capacity_slack=capacity_slack,
        tombstones=tombstones,
        layer_epoch=layer_epoch,
    )
    per_device = jax.lax.psum(block_totals, dhg.axis_names)  # (D,) replicated
    return jnp.max(per_device).astype(jnp.int32)


def plan_caps_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
    tombstones: Optional[tuple[jax.Array, jax.Array]] = None,
) -> tuple[jax.Array, jax.Array]:
    """One counts round sizing both retrieval capacities over a layer stack.

    Returns replicated ``(seg_capacity, out_capacity)`` () int32 — the exact
    per-segment and per-device output widths a merged
    :func:`retrieve_layers_sharded` needs to drop nothing.  Call inside
    ``shard_map``.
    """
    axis_names = tuple(layers[0].axis_names)
    seg_need = jnp.int32(0)
    out_vec = jnp.int32(0)
    for epoch, layer in enumerate(layers):
        block_totals = _plan_block_totals(
            layer,
            queries,
            capacity_slack=capacity_slack,
            tombstones=tombstones,
            layer_epoch=epoch,
        )
        seg_need = jnp.maximum(seg_need, jnp.max(block_totals))
        out_vec = out_vec + block_totals
    seg = jax.lax.pmax(seg_need.astype(jnp.int32), axis_names)
    out = jnp.max(jax.lax.psum(out_vec, axis_names)).astype(jnp.int32)
    return seg, out


def build_query_hashgraph_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    *,
    capacity_slack: float = 1.25,
) -> HashGraph:
    """Paper-literal query phase 1: a *second* HashGraph from the query set,
    sharing the build table's splits (used by the list-intersection path and
    the build-vs-query benchmark)."""
    rq, _, rbuckets, _ = _route_queries(dhg, queries, capacity_slack)
    return hashgraph.build_from_buckets(
        rq, rbuckets, dhg.local_range_cap, seed=dhg.seed, sort_within_bucket=True
    )


def join_size_sharded(
    dhg: DistributedHashGraph,
    queries: jax.Array,
    **kw,
) -> jax.Array:
    """Global inner-join cardinality |build ⋈ query| (paper's intersection).

    Sum of per-query multiplicities, ``psum``-reduced across the mesh.
    """
    counts = query_sharded(dhg, queries, **kw)
    return jax.lax.psum(jnp.sum(counts), dhg.axis_names)


def join_size_layers_sharded(
    layers: Sequence[DistributedHashGraph],
    queries: jax.Array,
    **kw,
) -> jax.Array:
    """Global inner-join cardinality against a versioned layer stack."""
    counts = query_layers_sharded(layers, queries, **kw)
    return jax.lax.psum(jnp.sum(counts), tuple(layers[0].axis_names))
