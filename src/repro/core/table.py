"""High-level mesh-facing API for the distributed HashGraph.

Wraps the shard_map internals of ``repro.core.multi_hashgraph`` behind a
simple object: callers hold *global* jax arrays (sharded over a mesh) and
get back global arrays; all paper phases run inside one jitted shard_map.

The current API is **plan/execute over versioned state** (see
``repro.core.plans`` / ``repro.core.state``):

    table = DistributedHashTable(mesh, ("d",), hash_range=1 << 20)
    state = table.init(keys)                   # TableState (versioned)
    state = state.insert(new_keys)             # functional delta insert
    state = state.delete(dead_keys)            # tombstone delete
    plan = table.plan_retrieve(state, queries)  # capacities sized up front
    result = plan(state, queries)              # pure, jit-composable
    state = state.compact()                    # fold deltas + tombstones

The key width and payload shape are set by a :class:`~repro.core.schema.
TableSchema`: the default (uint32 keys, one int32 value column) is the
paper's layout and the exact PR-1 API; ``TableSchema("uint64", C)`` stores
keys as ``(N, 2)`` packed uint32 lanes (``schema.pack_u64``) and values as
``(N, C)`` int32 columns, threaded through every phase of the pipeline.

The pre-plan eager methods (``build``/``query``/``retrieve``/``inner_join``
…) remain as thin deprecation shims over the plan executors, accepting
either a bare ``DistributedHashGraph`` (their old state type) or a
``TableState``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.compat import shard_map

import numpy as np

from repro.core import hashing, multi_hashgraph, partition, plans
from repro.core.hashgraph import (
    EMPTY_KEY,
    HashGraph,
    is_empty_key,
    match_epochs_sorted,
)
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    ShardJoin,
    ShardRetrieval,
)
from repro.core.plans import JoinPlan, QueryPlan, RetrievePlan
from repro.core.schema import TableSchema
from repro.core.state import TableState, as_state, empty_tombstones
from repro.utils import cdiv as _cdiv


def _dhg_out_specs(
    axis_names: Sequence[str],
    hash_range: int,
    local_cap: int,
    seed: int,
    bucket_stride: int = 1,
    fingerprint: bool = False,
):
    ax = tuple(axis_names)
    shard0 = P(ax)  # stack local shards along dim 0 in the global view
    local = HashGraph(
        offsets=shard0,
        keys=shard0,
        values=shard0,
        table_size=local_cap,
        seed=seed,
        sorted_within_bucket=True,
        fingerprints=shard0 if fingerprint else None,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=P(),  # identical on every device
        num_dropped=P(),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=ax,
        bucket_stride=bucket_stride,
    )


@dataclasses.dataclass(eq=False)  # identity hash — required for jit static self
class DistributedHashTable:
    """Factory for jitted build/mutate/plan closures over a fixed mesh.

    ``schema`` selects key width and payload columns (default: the paper's
    uint32 keys + one int32 column).  ``use_kernel`` routes the retrieval
    gather through the Pallas ``csr_gather`` kernel (None = auto: on for
    TPU, jnp path elsewhere).  ``max_deltas`` bounds the insert delta ring
    and ``tombstone_capacity`` the delete buffer of the versioned state
    (see :class:`~repro.core.state.TableState`).

    ``coherent_deltas`` (default True) builds every insert delta on the
    base's *frozen* ``hash_splits`` — the partition-coherence invariant
    that lets one exchange round serve the whole layer stack (single-route
    layered execution).  ``False`` restores the pre-coherence behavior
    (each delta gets its own narrowed hash range and splits), producing
    mixed-split states that execute on the per-layer legacy path.
    ``fused_routing=False`` forces the legacy path even on coherent states
    (A/B benchmarking, parity tests); ``None`` auto-selects by state.

    ``skew_guard`` (default True) protects coherent inserts from dispatch
    overflow: a batch whose key distribution diverges from the base's
    balanced splits can overflow the per-(source, destination) exchange
    slots of the frozen-splits delta build (rows dropped, counted in
    ``num_dropped``).  The guard predicts the overflow host-side from the
    batch's histogram against the base's splits and, when it would fire,
    falls back to an *incoherent* (legacy-routed) delta whose own balanced
    splits absorb the skew — trading the fused routing invariant for zero
    dropped rows.  Fallbacks are tallied in ``skew_fallbacks`` (surfaced
    by ``serve_table`` server stats).  Eager inserts only: under an outer
    ``jax.jit`` the histogram cannot be read back, so the guard is skipped.

    ``replicate_hot_keys`` (R > 1 enables) handles the skew no split choice
    can fix: a batch dominated by ONE key value hashes to one owner, so
    duplicates beyond the dispatch slot drop no matter how the range is
    partitioned.  Eager coherent inserts detect such hot keys host-side
    (occurrence count above the per-(source, dest) dispatch slot) and
    spread each hot key's rows round-robin over ``min(R, D)`` consecutive
    owners (``dest_offsets`` in the delta build); detected keys are tallied
    in the ``hot_keys`` registry and eager ``query`` transparently sums one
    extra routed round per replica rank to merge the counts (exact for
    non-replicated keys, which count 0 off their owner).  A full
    ``compact()`` re-concentrates rows on the hash owner (the rebuild
    routes purely by hash) — re-detection on the next skewed insert
    re-spreads them; retrieve/join of replicated rows sees only the
    ``r = 0`` replica for now (counts are the serving-cache need).
    """

    mesh: jax.sharding.Mesh
    axis_names: tuple
    hash_range: int
    seed: int = hashing.DEFAULT_SEED
    capacity_slack: float = 1.25
    range_slack: float = 1.5
    num_bins: Optional[int] = None
    paper_faithful_probe: bool = False
    max_probe: int = 64
    schema: Optional[TableSchema] = None
    use_kernel: Optional[bool] = None
    max_deltas: int = 8
    tombstone_capacity: int = 1024
    coherent_deltas: bool = True
    fused_routing: Optional[bool] = None
    skew_guard: bool = True
    fingerprint: Optional[bool] = None
    replicate_hot_keys: int = 0

    def __post_init__(self):
        self.axis_names = tuple(self.axis_names)
        if self.schema is None:
            self.schema = TableSchema()
        # Probe fingerprint lane (None = auto): on for multi-lane keys, where
        # the fingerprint bisection halves the bytes of the wide-span sorted
        # search; off for 1-lane keys (the key array is already one lane).
        # Applied uniformly to base, delta, fold and compact builds so every
        # layer of a state shares one probe layout.
        if self.fingerprint is None:
            self.use_fingerprint = self.schema.key_lanes > 1
        else:
            self.use_fingerprint = bool(self.fingerprint)
        self.num_devices = 1
        for a in self.axis_names:
            self.num_devices *= self.mesh.shape[a]
        from repro.utils import cdiv

        self.local_range_cap = int(
            cdiv(self.hash_range, self.num_devices) * self.range_slack
        )
        # Diagnostics counter (not part of the static jit identity): inserts
        # routed to an incoherent delta by the skew guard.
        self.skew_fallbacks = 0
        # Hot-key registry: packed key tuple -> replica count R.  Host-side
        # bookkeeping only (queries read max(R) to size the merge rounds);
        # not part of the jit identity.
        self.hot_keys = {}
        # Compact-sizing memo, keyed by state signature (the ExecutorGrid
        # idiom): structurally identical states reuse the derived
        # (capacity, rebuild_rows) pair instead of re-running the
        # exec_live_count device round trip per fold cycle.
        self._sizing_memo = {}

    # -- sharding helpers ----------------------------------------------------
    def key_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _in_spec(self):
        return P(self.axis_names)

    def _pack_queries(self, queries) -> jax.Array:
        return self.schema.pack_keys(queries)

    def _local_cap_for(self, hash_range: int) -> int:
        return int(_cdiv(hash_range, self.num_devices) * self.range_slack)

    def _out_specs(
        self,
        hash_range: Optional[int] = None,
        local_cap: Optional[int] = None,
        bucket_stride: int = 1,
    ):
        hr = self.hash_range if hash_range is None else hash_range
        return _dhg_out_specs(
            self.axis_names,
            hr,
            self._local_cap_for(hr) if local_cap is None else local_cap,
            self.seed,
            bucket_stride,
            fingerprint=self.use_fingerprint,
        )

    # -- build ----------------------------------------------------------------
    def build(self, keys, values=None) -> DistributedHashGraph:
        """Build a (build-once) distributed graph from a global key array.

        ``keys``: ``(N,)`` uint32 for the 1-lane schema, ``(N, 2)`` packed
        uint32 (``schema.pack_u64``) for uint64; ``N % devices == 0``.
        ``values``: optional ``(N,)`` / ``(N, C)`` int32 payload matching
        ``schema.value_cols`` (default: global row ids, 1-column only).

        .. deprecated:: use :meth:`init`, which returns a versioned
           :class:`TableState` supporting insert/delete/compact.  ``build``
           returns the bare ``DistributedHashGraph`` for older call sites.
        """
        keys = self.schema.pack_keys(keys)
        if values is None:
            if self.schema.value_cols != 1:
                raise ValueError(
                    f"schema has {self.schema.value_cols} value columns; "
                    "pass explicit values (the row-id default is 1-column)"
                )
            return self._build_jit(keys, hash_range=self.hash_range)
        return self._build_values_jit(
            keys, self.schema.pack_values(values), hash_range=self.hash_range
        )

    def init(self, keys, values=None) -> TableState:
        """Build and wrap into a versioned :class:`TableState`.

        The state starts with an empty delta ring and a zero-capacity
        tombstone buffer (pure-read states pay no masking cost); the buffer
        grows to ``tombstone_capacity`` slots on the first ``delete``.
        ``state.insert`` / ``state.delete`` / ``state.compact`` are
        functional (each returns a new state) and composable under an outer
        ``jax.jit``.
        """
        return TableState(
            base=self.build(keys, values),
            deltas=(),
            tombstones=empty_tombstones(0, self.schema.key_lanes),
            table=self,
        )

    def _build_body(self, k, v, hash_range, num_bins, capacity):
        return multi_hashgraph.build_sharded(
            k,
            hash_range=hash_range,
            axis_names=self.axis_names,
            values=v,
            num_bins=num_bins,
            capacity_slack=self.capacity_slack,
            range_slack=self.range_slack,
            seed=self.seed,
            capacity=capacity,
            fingerprint=self.use_fingerprint,
        )

    def _num_bins_for(self, hash_range: int) -> Optional[int]:
        # A user-pinned bin count is sized for the table's hash range; delta
        # builds over a narrowed range fall back to the auto choice.
        return self.num_bins if hash_range == self.hash_range else None

    @partial(jax.jit, static_argnums=0, static_argnames=("hash_range", "capacity"))
    def _build_jit(
        self, keys: jax.Array, *, hash_range: int, capacity: Optional[int] = None
    ):
        return shard_map(
            lambda k: self._build_body(
                k, None, hash_range, self._num_bins_for(hash_range), capacity
            ),
            mesh=self.mesh,
            in_specs=(self._in_spec(),),
            out_specs=self._out_specs(hash_range),
            check_vma=False,
        )(keys)

    @partial(jax.jit, static_argnums=0, static_argnames=("hash_range", "capacity"))
    def _build_values_jit(
        self,
        keys: jax.Array,
        values: jax.Array,
        *,
        hash_range: int,
        capacity: Optional[int] = None,
    ):
        return shard_map(
            lambda k, v: self._build_body(
                k, v, hash_range, self._num_bins_for(hash_range), capacity
            ),
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec()),
            out_specs=self._out_specs(hash_range),
            check_vma=False,
        )(keys, values)

    # -- functional mutation (versioned state) --------------------------------
    def _delta_hash_range(self, num_keys: int) -> int:
        """Hash range for a *legacy* (incoherent) delta graph: sized to the
        batch, not the table.

        Pre-coherence behavior (``coherent_deltas=False``): each delta owns
        its own splits and bucket space, so a small insert does not pay the
        base table's O(hash_range / devices) offsets array — at the price of
        one routing round per delta on every later query.
        """
        return min(self.hash_range, max(256, 2 * num_keys))

    def _delta_bucket_geometry(self, num_keys: int) -> tuple[int, int]:
        """(local_range_cap, bucket_stride) for a partition-coherent delta.

        Coherent deltas share the base's hash range and splits (routing
        identity), but a small batch must not pay the base's
        O(hash_range / D) offsets array — so the bucket map is *strided*:
        ``stride`` consecutive base bucket slots fold into one delta bucket,
        keeping the delta's offsets at O(batch) while build and query keep
        using the identical deterministic map.  Striding only lengthens
        bucket lists; the sorted-bucket binary search absorbs it.
        """
        target = max(128, _cdiv(2 * num_keys, self.num_devices))
        stride = max(1, _cdiv(self.local_range_cap, target))
        return _cdiv(self.local_range_cap, stride), stride

    @partial(
        jax.jit, static_argnums=0, static_argnames=("local_cap", "stride", "capacity")
    )
    def _build_delta_jit(
        self,
        keys: jax.Array,
        values: jax.Array,
        splits: jax.Array,
        *,
        local_cap: int,
        stride: int,
        capacity: Optional[int] = None,
    ):
        """Build one delta graph on the base's frozen splits (no phase-1
        histogram/psum round — the splits ARE the partitioning)."""

        def body(k, v, sp):
            return multi_hashgraph.build_sharded(
                k,
                hash_range=self.hash_range,
                axis_names=self.axis_names,
                values=v,
                capacity_slack=self.capacity_slack,
                seed=self.seed,
                capacity=capacity,
                hash_splits=sp,
                local_range_cap=local_cap,
                bucket_stride=stride,
                fingerprint=self.use_fingerprint,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec(), P()),
            out_specs=self._out_specs(local_cap=local_cap, bucket_stride=stride),
            check_vma=False,
        )(keys, values, splits)

    @partial(
        jax.jit, static_argnums=0, static_argnames=("local_cap", "stride", "capacity")
    )
    def _build_delta_offsets_jit(
        self,
        keys: jax.Array,
        values: jax.Array,
        splits: jax.Array,
        offsets: jax.Array,
        *,
        local_cap: int,
        stride: int,
        capacity: Optional[int] = None,
    ):
        """Hot-key variant of :meth:`_build_delta_jit`: per-row destination
        offsets spread each hot key's rows over R consecutive owners.  A
        separate jitted program so the offset-free insert path keeps its
        jaxpr byte-identical."""

        def body(k, v, sp, offs):
            return multi_hashgraph.build_sharded(
                k,
                hash_range=self.hash_range,
                axis_names=self.axis_names,
                values=v,
                capacity_slack=self.capacity_slack,
                seed=self.seed,
                capacity=capacity,
                hash_splits=sp,
                local_range_cap=local_cap,
                bucket_stride=stride,
                fingerprint=self.use_fingerprint,
                dest_offsets=offs,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec(), P(), self._in_spec()),
            out_specs=self._out_specs(local_cap=local_cap, bucket_stride=stride),
            check_vma=False,
        )(keys, values, splits, offsets)

    def _hot_key_offsets(self, keys: jax.Array):
        """Host-side hot-key detection: per-row destination offsets, or None.

        A key is *hot* when its occurrence count in this batch exceeds the
        per-(source, destination) dispatch slot of the coherent delta build
        — beyond that, drops are guaranteed if every occurrence funnels to
        the single hash owner (the failure no split choice fixes).  Hot
        keys' rows get offsets ``occurrence_rank % R`` so the build spreads
        them over ``R = min(replicate_hot_keys, D)`` consecutive owners;
        all other rows keep offset 0.  Detected keys are registered in
        ``hot_keys`` for the query-side merge.  Eager call sites only.
        """
        d = self.num_devices
        n = keys.shape[0]
        slot = multi_hashgraph.default_capacity(n // d, d, self.capacity_slack)
        kn = np.asarray(keys)
        rows = kn if kn.ndim == 2 else kn[:, None]
        uniq, inv, counts = np.unique(
            rows, axis=0, return_inverse=True, return_counts=True
        )
        hot = (counts > slot) & ~np.all(uniq == np.uint32(EMPTY_KEY), axis=1)
        if not np.any(hot):
            return None
        r = max(2, min(self.replicate_hot_keys, d))
        offs = np.zeros(n, np.int32)
        for u in np.nonzero(hot)[0]:
            idx = np.nonzero(inv == u)[0]
            offs[idx] = np.arange(idx.shape[0], dtype=np.int32) % r
            self.hot_keys[tuple(int(x) for x in uniq[u])] = r
        return jnp.asarray(offs)

    def _coherent_dispatch_overflows(
        self, keys: jax.Array, splits, offsets=None
    ) -> bool:
        """Predict per-(source, destination) slot overflow of a coherent
        delta build for this batch (the delta-dispatch skew check).

        Replays the exact routing the frozen-splits build would use — hash,
        destination by the base's splits (plus the hot-key ``offsets`` when
        replication spread the batch), EMPTY sentinels round-robin — and
        histograms it per (source shard, destination) pair against the same
        ``default_capacity`` slot size the build would allocate.  The
        histogram and comparison run on device; only the one-boolean
        verdict crosses to host.  Eager call sites only.
        """
        d = self.num_devices
        n = keys.shape[0]
        n_local = n // d
        capacity = multi_hashgraph.default_capacity(
            n_local, d, self.capacity_slack
        )
        if offsets is None:
            offsets = jnp.zeros(n, jnp.int32)
        verdict = self._skew_verdict_jit(
            keys, jnp.asarray(splits), offsets, capacity=capacity
        )
        return bool(verdict)

    @partial(jax.jit, static_argnums=0, static_argnames=("capacity",))
    def _skew_verdict_jit(
        self,
        keys: jax.Array,
        splits: jax.Array,
        offsets: jax.Array,
        *,
        capacity: int,
    ) -> jax.Array:
        d = self.num_devices
        n = keys.shape[0]
        n_local = n // d
        h = hashing.hash_to_buckets(keys, self.hash_range, seed=self.seed)
        dest = (partition.destination_of(h, splits) + offsets) % d
        rows = jnp.arange(n, dtype=jnp.int32)
        dest = jnp.where(is_empty_key(keys), (rows % n_local) % d, dest)
        pair = (rows // n_local) * d + dest  # (source shard, destination)
        per_pair = jnp.zeros(d * d, jnp.int32).at[pair].add(1)
        return jnp.any(per_pair > capacity)

    def insert(
        self, state, keys, values=None, *, auto_compact: bool = False
    ) -> TableState:
        """Functional insert: a new state with one more delta graph.

        ``keys``/``values`` follow the :meth:`build` contract (global
        arrays, ``N % devices == 0``).  Raises when the delta ring is full —
        call :meth:`compact` first, or pass ``auto_compact=True`` to fold
        the state automatically whenever
        :meth:`~repro.core.state.TableState.should_compact` fires (ring
        full, tombstone load, or tombstone overflow; host-syncing — eager
        use only).  With ``values=None`` the default payload is the row id
        *within this batch* (0..N-1).

        With ``coherent_deltas`` (the default) the delta is built on the
        base's frozen ``hash_splits``, preserving the partition-coherence
        invariant that keeps every later query/retrieve/plan at one routing
        round regardless of delta depth.  A batch skewed enough to overflow
        the frozen-splits dispatch falls back to an incoherent delta instead
        of dropping rows (``skew_guard``; counted in ``skew_fallbacks``).
        """
        st = as_state(self, state)
        if auto_compact and st.should_compact():
            st = self.compact(st)
        if len(st.deltas) >= self.max_deltas:
            raise RuntimeError(
                f"delta ring full ({self.max_deltas} deltas); call compact() "
                "to fold deltas into the base before inserting more"
            )
        keys = self.schema.pack_keys(keys)
        if values is None:
            if self.schema.value_cols != 1:
                raise ValueError(
                    f"schema has {self.schema.value_cols} value columns; "
                    "pass explicit values (the row-id default is 1-column)"
                )
            values = jnp.arange(keys.shape[0], dtype=jnp.int32)
        else:
            values = self.schema.pack_values(values)
        coherent_build = self.coherent_deltas
        tracing = any(
            isinstance(x, jax.core.Tracer)
            for x in jax.tree_util.tree_leaves((keys, st.base.hash_splits))
        )
        offsets = None
        if coherent_build and not tracing and self.replicate_hot_keys > 1:
            # One-key skew no split choice fixes: spread each hot key's
            # rows over R consecutive owners before the guard re-checks.
            offsets = self._hot_key_offsets(keys)
        if coherent_build and self.skew_guard:
            if not tracing and self._coherent_dispatch_overflows(
                keys, st.base.hash_splits, offsets
            ):
                # Skewed batch: the frozen-splits dispatch would drop rows.
                # A legacy-routed delta re-balances its own splits instead.
                coherent_build = False
                self.skew_fallbacks += 1
        if coherent_build:
            local_cap, stride = self._delta_bucket_geometry(keys.shape[0])
            if offsets is not None:
                delta = self._build_delta_offsets_jit(
                    keys,
                    values,
                    st.base.hash_splits,
                    offsets,
                    local_cap=local_cap,
                    stride=stride,
                )
            else:
                delta = self._build_delta_jit(
                    keys,
                    values,
                    st.base.hash_splits,
                    local_cap=local_cap,
                    stride=stride,
                )
            coherent = st.coherent
        else:
            delta = self._build_values_jit(
                keys, values, hash_range=self._delta_hash_range(keys.shape[0])
            )
            coherent = False  # mixed-split stack: per-layer routing from now on
        return dataclasses.replace(
            st, deltas=st.deltas + (delta,), coherent=coherent
        )

    def delete(self, state, keys) -> TableState:
        """Functional delete: tombstone every current occurrence of ``keys``.

        The tombstones are stamped with the current epoch, hiding matches in
        the base and in every delta inserted so far; keys re-inserted
        *after* the delete are visible again.  ``keys`` is a replicated
        (unsharded) array of any length; overflow past
        ``tombstone_capacity`` is counted in ``state.num_dropped``.
        """
        st = as_state(self, state)
        if st.tombstones.capacity == 0:
            # Legacy states lifted from a bare graph carry a zero-capacity
            # buffer (zero masking cost); grow it on first delete.
            st = dataclasses.replace(
                st,
                tombstones=empty_tombstones(
                    self.tombstone_capacity, self.schema.key_lanes
                ),
            )
        keys = self.schema.pack_keys(keys)
        return dataclasses.replace(
            st, tombstones=st.tombstones.push(keys, epoch=len(st.deltas))
        )

    def upsert(
        self,
        state,
        keys,
        values=None,
        *,
        ttl: Optional[int] = None,
        auto_compact: bool = False,
    ) -> TableState:
        """Functional insert-or-replace: after it, ``keys`` map to exactly
        ``values`` (KV semantics over the multiset core).

        One delete + one insert through the existing delta/tombstone
        machinery: prior versions of every key are tombstoned at the
        current epoch (hiding layers ``0..d``) and the new rows land in a
        fresh delta at epoch ``d + 1`` — so reads resolve the newest
        version with the fused 2-all-to-all budget unchanged, and
        last-writer-wins / read-your-writes hold by construction.  Within
        a batch, later occurrences of a duplicate key win (host-side
        keep-last dedup; under an outer ``jax.jit`` the dedup is skipped —
        keep traced batches duplicate-free).

        ``ttl`` schedules expiry: a pending tombstone at the *new* epoch
        with ``expires = now + ttl``, invisible until the logical clock
        (``state.advance``) reaches it, then masking the upserted row
        exactly like a delete.  Each upsert refreshes its key's lifetime —
        the old version's pending entries keep pointing at epochs the
        delete already hides.

        Unlike :meth:`insert`, ``keys`` need not be device-aligned: the
        batch is EMPTY-padded to the device multiple (padding rows are
        routed round-robin and never tombstoned, so they cost no
        tombstone slots).  ``auto_compact`` mirrors :meth:`insert`.
        Overflowing ``tombstone_capacity`` is counted in
        ``state.num_dropped`` — compaction restores exactness.
        """
        st = as_state(self, state)
        if auto_compact and st.should_compact():
            st = self.compact(st)
        keys = self.schema.pack_keys(keys)
        if values is None:
            if self.schema.value_cols != 1:
                raise ValueError(
                    f"schema has {self.schema.value_cols} value columns; "
                    "pass explicit values (the row-id default is 1-column)"
                )
            values = jnp.arange(keys.shape[0], dtype=jnp.int32)
        else:
            values = self.schema.pack_values(values)
        tracing = any(
            isinstance(x, jax.core.Tracer)
            for x in jax.tree_util.tree_leaves((keys, values))
        )
        if not tracing:
            # Keep-last dedup: KV semantics demand ONE winner per key per
            # batch (two surviving rows would both clear the epoch-d
            # tombstone and double the count).  EMPTY rows drop here too.
            kn = np.asarray(keys)
            vn = np.asarray(values)
            rows = kn if kn.ndim == 2 else kn[:, None]
            _, first = np.unique(rows[::-1], axis=0, return_index=True)
            keep = np.sort(rows.shape[0] - 1 - first)
            keep = keep[~np.all(rows[keep] == np.uint32(EMPTY_KEY), axis=1)]
            keys = jnp.asarray(kn[keep])
            values = jnp.asarray(vn[keep])
        if keys.shape[0] == 0:
            return st
        real = keys  # unpadded: tombstoning EMPTY pads would burn slots
        pad = (-keys.shape[0]) % self.num_devices
        if pad:
            keys = jnp.concatenate(
                [keys, jnp.full((pad,) + keys.shape[1:], EMPTY_KEY, jnp.uint32)]
            )
            values = jnp.concatenate(
                [values, jnp.full((pad,) + values.shape[1:], -1, jnp.int32)]
            )
        st = self.delete(st, real)  # hide prior versions: epoch d
        st = self.insert(st, keys, values)  # the new version: epoch d + 1
        if ttl is not None:
            st = dataclasses.replace(
                st,
                tombstones=st.tombstones.push(
                    real,
                    epoch=len(st.deltas),
                    expires=st.tombstones.now + jnp.int32(ttl),
                ),
            )
        return st

    def compact(self, state, *, capacity: Optional[int] = None) -> TableState:
        """Fold base + deltas − tombstones into a fresh base; reset the ring.

        Pure rebuild (jit-composable): every layer's stored rows are masked
        to the EMPTY sentinel where tombstoned, concatenated live-rows-first,
        and pushed through the standard four-phase build.

        Sizing: with ``capacity=None`` on the eager path, one counts round
        (``plans.exec_live_count``) measures the live (non-tombstoned) row
        total and sizes both the post-exchange row budget and the rebuild's
        per-destination slots from it — so steady-state insert/delete/compact
        cycles keep the base arrays *flat* instead of growing by the
        all-rows worst case every fold.  Under an outer ``jax.jit`` the
        live count cannot be read back, so the worst-case sizing applies
        (pass an explicit ``capacity`` to pin it).  ``capacity`` overrides
        the per-destination slot size of the rebuild exchange either way.

        The derived sizing is memoized per state *signature* (structure,
        not data — the ``ExecutorGrid`` idiom): a background maintenance
        loop cycling through identical insert/delete/fold structures pays
        the ``exec_live_count`` round trip once per structure, not once
        per compaction.  A memo hit with a drifted live count only risks
        a *smaller-than-ideal* budget, and any live row it truncates is
        tallied into ``num_dropped`` — never silent.
        """
        st = as_state(self, state)
        # Per-DEVICE concatenated row count: layer arrays are global views,
        # the rebuild exchange sees one shard of each.
        n_cat = sum(layer.local.keys.shape[0] for layer in st.layers)
        n_cat_local = _cdiv(n_cat, self.num_devices)
        rebuild_rows = None
        if capacity is None:
            tracing = any(
                isinstance(x, jax.core.Tracer)
                for x in jax.tree_util.tree_leaves(st)
            )
            if not tracing:
                sig = plans.state_signature(st)
                cached = self._sizing_memo.get(sig)
                if cached is not None:
                    capacity, rebuild_rows = cached
                else:
                    live = int(plans.exec_live_count(self, st))
                    live_local = _cdiv(live, self.num_devices)
                    # Post-deal per-device row budget: balanced live share
                    # plus the slack margin (skew beyond it is truncated —
                    # counted in num_dropped, never silent).
                    rebuild_rows = max(64, int(live_local * self.capacity_slack) + 8)
                    rebuild_rows = min(_cdiv(rebuild_rows, 8) * 8, n_cat_local)
                    capacity = multi_hashgraph.default_capacity(
                        rebuild_rows, self.num_devices, self.capacity_slack
                    ) + _cdiv(rebuild_rows, self.num_devices)
                    if len(self._sizing_memo) >= 128:  # bounded, like the grid
                        self._sizing_memo.clear()
                    self._sizing_memo[sig] = (capacity, rebuild_rows)
            else:
                # Balanced share of the worst case (all rows live) plus a
                # full round-robin allowance for the sentinel rows.
                capacity = multi_hashgraph.default_capacity(
                    n_cat_local, self.num_devices, self.capacity_slack
                ) + _cdiv(n_cat_local, self.num_devices)
        capacity = _cdiv(capacity, 8) * 8
        new_base = self._compact_jit(st, capacity=capacity, rebuild_rows=rebuild_rows)
        # Tombstone carry: effective entries (deletes + expired TTLs) are
        # applied by the rebuild and spent, but *pending* TTL entries masked
        # nothing yet — their rows survive into the new base, so the entries
        # must survive too (clamped to epoch 0 by the remap).  Eagerly with
        # nothing pending the buffer resets to the zero-capacity form (reads
        # pay no masking); traced compacts keep the capacity-preserving
        # remap — shape-stable, and correct either way.
        ts = st.tombstones
        lanes = self.schema.key_lanes
        if ts.capacity == 0:
            new_ts = empty_tombstones(0, lanes, now=ts.now)
        else:
            ts_tracing = any(
                isinstance(x, jax.core.Tracer)
                for x in jax.tree_util.tree_leaves(ts)
            )
            pending = ts_tracing or bool(
                np.any(
                    (np.asarray(ts.epochs) >= 0)
                    & (int(ts.now) < np.asarray(ts.expires))
                )
            )
            if pending:
                from repro.core.maintenance import _remap_tombstones

                new_ts = _remap_tombstones(ts, len(st.deltas))
            else:
                new_ts = empty_tombstones(0, lanes, now=ts.now)
        return TableState(
            base=new_base,
            deltas=(),
            tombstones=new_ts,
            table=self,
        )

    @partial(
        jax.jit, static_argnums=0, static_argnames=("capacity", "rebuild_rows")
    )
    def _compact_jit(
        self,
        state: TableState,
        *,
        capacity: int,
        rebuild_rows: Optional[int] = None,
    ):
        from repro.core import exchange

        def body(st):
            ts_keys, ts_epochs = st.tombstones.index()
            keys_parts, vals_parts = [], []
            for epoch, layer in enumerate(st.layers):
                k = layer.local.keys
                hidden = match_epochs_sorted(k, ts_keys, ts_epochs) >= epoch
                dead = is_empty_key(k) | hidden
                dead_b = dead[:, None] if k.ndim == 2 else dead
                keys_parts.append(jnp.where(dead_b, jnp.uint32(EMPTY_KEY), k))
                vals_parts.append(layer.local.values)
            keys_cat = jnp.concatenate(keys_parts, axis=0)
            vals_cat = jnp.concatenate(vals_parts, axis=0)
            # Pre-balance: the base layer is hash-partitioned, so rebuilding
            # directly would route every device's live rows to ONE owner and
            # the per-pair slot would need to hold a whole device's rows.  A
            # deterministic round-robin all_to_all first deals every D-th
            # row to each peer — STRIDED, not contiguous: live rows cluster
            # at the front of the bucket-sorted shards, so contiguous chunks
            # would re-concentrate them on one receiver — making both the
            # receivers' live loads and the rebuild's destination
            # distribution uniform (~n/D per pair).
            d = self.num_devices
            chunk = _cdiv(keys_cat.shape[0], d)
            pad = chunk * d - keys_cat.shape[0]
            if pad:
                keys_cat = jnp.concatenate(
                    [
                        keys_cat,
                        jnp.full((pad,) + keys_cat.shape[1:], EMPTY_KEY, jnp.uint32),
                    ]
                )
                vals_cat = jnp.concatenate(
                    [vals_cat, jnp.full((pad,) + vals_cat.shape[1:], -1, jnp.int32)]
                )

            def deal(x):
                # row i -> peer i % D (strided deal), then one all_to_all
                stripes = x.reshape(chunk, d, *x.shape[1:]).swapaxes(0, 1)
                mixed = exchange.all_to_all_hierarchical(stripes, self.axis_names)
                return mixed.reshape(d * chunk, *x.shape[1:])

            keys_cat = deal(keys_cat)
            vals_cat = deal(vals_cat)
            # Live rows first: exchange-capacity drops hit sentinels before
            # any real key (pack order within a destination is stable).
            order = jnp.argsort(is_empty_key(keys_cat).astype(jnp.int32), stable=True)
            keys_cat = keys_cat[order]
            vals_cat = vals_cat[order]
            trunc_live = jnp.int32(0)
            if rebuild_rows is not None and rebuild_rows < keys_cat.shape[0]:
                # Live-count sizing: the post-deal rows beyond the budget are
                # (statistically) all sentinels; any live row lost to skew is
                # tallied into num_dropped below, never silently.
                trunc_live = jnp.sum(
                    ~is_empty_key(keys_cat[rebuild_rows:])
                ).astype(jnp.int32)
                keys_cat = keys_cat[:rebuild_rows]
                vals_cat = vals_cat[:rebuild_rows]
            built = self._build_body(
                keys_cat,
                vals_cat,
                self.hash_range,
                self.num_bins,
                capacity,
            )
            if rebuild_rows is not None:
                built = dataclasses.replace(
                    built,
                    num_dropped=built.num_dropped
                    + jax.lax.psum(trunc_live, self.axis_names),
                )
            return built

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(plans.state_specs(state),),
            out_specs=self._out_specs(),
            check_vma=False,
        )(state)

    # -- plan builders ---------------------------------------------------------
    def plan_query(self, num_queries: Optional[int] = None) -> QueryPlan:
        """A pure ``(state, queries) -> counts`` callable (no capacities).

        Also exposes ``.join_size(state, queries)`` for the replicated join
        cardinality under the same plan.
        """
        return QueryPlan(self, num_queries)

    def plan_caps(self, state, queries) -> tuple[int, int]:
        """One counts round sizing retrieval exactly: ``(seg, out)`` ints.

        Blocks on a device→host read of two scalars — call at plan time,
        never inside a jitted program (pass explicit capacities there).
        """
        st = as_state(self, state)
        q = self._pack_queries(queries)
        seg_need, out_need = plans.exec_plan_caps(self, st, q)
        return int(seg_need), int(out_need)

    def _resolve_caps(self, state, queries, out_capacity, seg_capacity):
        """Static output sizing, lane-aligned, count-first.

        Any ``None`` capacity triggers the combined counts planning round
        (:func:`repro.core.multi_hashgraph.plan_caps_sharded`):
        ``out_capacity`` is sized *exactly* (rounded to the lane multiple)
        and ``seg_capacity`` is rounded up to a power of two — at most 2×
        the exact width while quantizing the static shape so repeated calls
        with shifting duplicate structure reuse a bounded set of compiled
        programs.  The planning round blocks on a device→host read; under
        an outer ``jax.jit`` pass explicit capacities instead.
        """
        if out_capacity is None or seg_capacity is None:
            seg_need, out_need = self.plan_caps(state, queries)
            if out_capacity is None:
                out_capacity = out_need
            if seg_capacity is None:
                seg_capacity = (
                    max(8, 1 << (seg_need - 1).bit_length()) if seg_need > 0 else 8
                )
        out_cap = max(8, _cdiv(out_capacity, 8) * 8)
        seg_cap = max(8, _cdiv(seg_capacity, 8) * 8)
        return out_cap, seg_cap

    def _plan_statics(
        self, name, state, queries, num_queries, out_capacity, seg_capacity
    ):
        """Shared plan-builder resolution: ``(num_queries, out_cap, seg_cap)``.

        Capacities left ``None`` are sized by the counts round against the
        sample ``(state, queries)`` (the only host sync; the returned plan
        itself never syncs).  With both capacities explicit no sample is
        needed and plan construction is free of device work.
        """
        if out_capacity is None or seg_capacity is None:
            if state is None or queries is None:
                raise ValueError(
                    f"{name} needs a (state, queries) sample to size "
                    "capacities, or explicit out_capacity and seg_capacity"
                )
            out_capacity, seg_capacity = self._resolve_caps(
                state, queries, out_capacity, seg_capacity
            )
        else:
            out_capacity = max(8, _cdiv(out_capacity, 8) * 8)
            seg_capacity = max(8, _cdiv(seg_capacity, 8) * 8)
        if num_queries is None and queries is not None:
            num_queries = self._pack_queries(queries).shape[0]
        return num_queries, out_capacity, seg_capacity

    def plan_retrieve(
        self,
        state=None,
        queries=None,
        *,
        num_queries: Optional[int] = None,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        per_layer_counts: bool = False,
    ) -> RetrievePlan:
        """Build a pure ``(state, queries) -> ShardRetrieval`` callable.

        Capacity contract: see :meth:`_plan_statics`.  ``per_layer_counts``
        fills the result's ``layer_counts`` provenance field (same single
        all-to-all on the fused path).
        """
        return RetrievePlan(
            self,
            *self._plan_statics(
                "plan_retrieve", state, queries, num_queries, out_capacity, seg_capacity
            ),
            per_layer_counts=per_layer_counts,
        )

    def plan_join(
        self,
        state=None,
        queries=None,
        *,
        num_queries: Optional[int] = None,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> JoinPlan:
        """Build a pure ``(state, queries) -> ShardJoin`` callable.

        Capacity contract: see :meth:`_plan_statics`.
        """
        return JoinPlan(
            self,
            *self._plan_statics(
                "plan_join", state, queries, num_queries, out_capacity, seg_capacity
            ),
        )

    # -- eager shims over the plan executors -----------------------------------
    def query(self, state, queries) -> jax.Array:
        """Multiplicity of each global query key. Returns (Nq,) int32.

        With hot-key replication active (keys in the ``hot_keys``
        registry), one extra routed round per replica rank merges the
        counts of rows spread off their hash owner — non-replicated keys
        count 0 on every round but the first, so the sum is exact for
        every key.  Without registered hot keys this is the single fused
        round, jaxpr-unchanged.

        .. deprecated:: thin shim over :meth:`plan_query`; accepts a bare
           ``DistributedHashGraph`` or a ``TableState``.
        """
        st = as_state(self, state)
        q = self._pack_queries(queries)
        total = plans.exec_query(self, st, q)
        rounds = max(self.hot_keys.values(), default=1)
        for r in range(1, rounds):
            total = total + plans.exec_query(self, st, q, dest_offset=r)
        return total

    def contains(self, state, queries) -> jax.Array:
        return self.query(state, queries) > 0

    def join_size(self, state, queries) -> jax.Array:
        """Global inner-join cardinality (scalar, replicated).

        .. deprecated:: thin shim over ``plan_query().join_size``.
        """
        return plans.exec_join_size(
            self, as_state(self, state), self._pack_queries(queries)
        )

    def retrieve(
        self,
        state,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        per_layer_counts: bool = False,
    ) -> ShardRetrieval:
        """All stored values for every occurrence of every query key.

        Returns a :class:`ShardRetrieval` whose fields are *global* arrays
        sharded over the mesh — each device holds the CSR over its own query
        shard: block ``d`` of ``offsets`` (``n_local+1`` rows) indexes block
        ``d`` of ``values`` (``out_capacity`` rows; ``(out_capacity, C)``
        for multi-column schemas).  Use :func:`retrieval_to_lists` for a
        host-side per-query view.

        ``out_capacity`` bounds each device's total result count and
        ``seg_capacity`` the results any one owner shard returns to one
        querying shard; both are static.  Either left ``None`` is sized by
        the count-first planning round (exact for ``out_capacity``, next
        power of two for ``seg_capacity``); the planning round blocks on a
        device→host read, so under an outer ``jax.jit`` pass explicit
        capacities (or use :meth:`plan_retrieve`).  Overflow is reported in
        ``num_dropped`` (replicated scalar) — never silently truncated.

        ``per_layer_counts=True`` additionally returns the per-layer count
        breakdown in ``.layer_counts`` (``(Nq, L)``, base first) — layer
        provenance for versioned reads, shipped in the same all-to-all on
        the fused path.

        .. deprecated:: thin shim over :meth:`plan_retrieve`.
        """
        st = as_state(self, state)
        q = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(st, q, out_capacity, seg_capacity)
        return plans.exec_retrieve(
            self,
            st,
            q,
            out_capacity=out_cap,
            seg_capacity=seg_cap,
            per_layer_counts=per_layer_counts,
        )

    def inner_join(
        self,
        state,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> ShardJoin:
        """Materialized inner join: global ``(query_idx, value)`` match pairs.

        Each device emits its pairs into block ``d`` of the global
        ``query_idx``/``values`` arrays, with its valid-pair count in
        ``num_results[d]`` (pairs beyond it are ``-1`` padding).
        ``query_idx`` is the global query row id.  Same capacity/overflow
        contract as :meth:`retrieve`.

        .. deprecated:: thin shim over :meth:`plan_join`.
        """
        st = as_state(self, state)
        q = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(st, q, out_capacity, seg_capacity)
        return plans.exec_join(
            self, st, q, out_capacity=out_cap, seg_capacity=seg_cap
        )

    # -- dynamic output buffers (ROADMAP: auto-retry on overflow) --------------
    def _auto_retry(
        self, exec_fn, state, queries, out_capacity, seg_capacity, max_retries
    ):
        """Re-run ``exec_fn`` with doubled caps while ``num_dropped > 0``.

        Bails early when doubling stops shrinking ``num_dropped`` — drops
        from the *dispatch* stage depend on ``capacity_slack``, not on the
        output caps, so no amount of doubling (and recompiling) fixes them.
        """
        st = as_state(self, state)
        q = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(st, q, out_capacity, seg_capacity)
        res = exec_fn(self, st, q, out_capacity=out_cap, seg_capacity=seg_cap)
        dropped = int(res.num_dropped)
        for _ in range(max_retries):
            if dropped == 0:
                break
            out_cap, seg_cap = out_cap * 2, seg_cap * 2
            res = exec_fn(self, st, q, out_capacity=out_cap, seg_capacity=seg_cap)
            prev, dropped = dropped, int(res.num_dropped)
            if dropped >= prev:
                break  # not a capacity problem (e.g. route drops)
        return res

    def retrieve_auto(
        self,
        state,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        max_retries: int = 4,
    ) -> ShardRetrieval:
        """:meth:`retrieve` with bounded capacity-doubling retries.

        Re-runs with doubled ``out_capacity``/``seg_capacity`` while
        ``num_dropped > 0``, at most ``max_retries`` times (each retry is a
        fresh static shape, hence a recompile — the price of a guaranteed
        fit).  Returns the last attempt either way; callers still check
        ``num_dropped`` (nonzero only if the bound was exhausted or the
        drops are not capacity-fixable).
        """
        return self._auto_retry(
            plans.exec_retrieve, state, queries, out_capacity, seg_capacity, max_retries
        )

    def inner_join_auto(
        self,
        state,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        max_retries: int = 4,
    ) -> ShardJoin:
        """:meth:`inner_join` with bounded capacity-doubling retries."""
        return self._auto_retry(
            plans.exec_join, state, queries, out_capacity, seg_capacity, max_retries
        )


# ---------------------------------------------------------------------------
# Host-side views — vectorized numpy block slicing (no per-query Python loop)
# ---------------------------------------------------------------------------


def retrieval_to_lists(result: ShardRetrieval) -> list:
    """Host-side view of a :class:`ShardRetrieval`: one np.ndarray per query.

    Queries are sharded contiguously (device ``d`` owns rows
    ``d*n_local : (d+1)*n_local``), so global query ``i``'s values sit in
    device ``i // n_local``'s block of ``values`` at that block's local CSR
    offsets.  Multi-column schemas yield ``(k_i, C)`` arrays per query.

    Vectorized: per-shard valid prefixes are concatenated (``D`` slices) and
    one ``np.split`` at the per-query offset boundaries yields the views —
    no O(num_queries) Python loop.
    """
    counts = np.asarray(result.counts)
    offsets = np.asarray(result.offsets)
    values = np.asarray(result.values)
    num_queries = counts.shape[0]
    # len(offsets) = D*(n_local+1), len(counts) = D*n_local  =>  D:
    d = offsets.shape[0] - counts.shape[0]
    n_local = num_queries // d
    out_cap = values.shape[0] // d
    off2 = offsets.reshape(d, n_local + 1)
    flat = np.concatenate(
        [values[s * out_cap : s * out_cap + off2[s, -1]] for s in range(d)],
        axis=0,
    )
    # Per-query lengths from the (capacity-clamped) offsets, matching the
    # CSR exactly even when overflow truncated a tail.
    lens = np.diff(off2, axis=1).reshape(-1)
    return np.split(flat, np.cumsum(lens)[:-1])


def _retrieval_to_lists_loop(result: ShardRetrieval) -> list:
    """Reference implementation of :func:`retrieval_to_lists` (per-query
    Python loop) — kept for the vectorization parity tests."""
    counts = np.asarray(result.counts)
    offsets = np.asarray(result.offsets)
    values = np.asarray(result.values)
    num_queries = counts.shape[0]
    d = offsets.shape[0] - counts.shape[0]
    n_local = num_queries // d
    out_cap = values.shape[0] // d
    per_query = []
    for i in range(num_queries):
        shard, local = divmod(i, n_local)
        off = offsets[shard * (n_local + 1) + local]
        end = offsets[shard * (n_local + 1) + local + 1]
        per_query.append(values[shard * out_cap + off : shard * out_cap + end])
    return per_query


def join_to_pairs(result: ShardJoin) -> "np.ndarray":
    """Host-side view of a :class:`ShardJoin`: an (M, 1 + C) array of rows
    ``(query_idx, *value_columns)`` — ``(M, 2)`` for the 1-column schema.

    Vectorized: a single boolean mask (slot < per-shard ``num_results``)
    selects valid pairs from all shards at once.
    """
    qi = np.asarray(result.query_idx)
    vals = np.asarray(result.values)
    if vals.ndim == 1:
        vals = vals[:, None]
    nres = np.asarray(result.num_results)
    d = nres.shape[0]
    out_cap = qi.shape[0] // d
    mask = np.arange(out_cap)[None, :] < nres[:, None]
    qi_sel = qi.reshape(d, out_cap)[mask]
    vals_sel = vals.reshape(d, out_cap, -1)[mask]
    return np.concatenate([qi_sel[:, None], vals_sel], axis=1).astype(np.int32)


def _join_to_pairs_loop(result: ShardJoin) -> "np.ndarray":
    """Reference implementation of :func:`join_to_pairs` (per-shard loop) —
    kept for the vectorization parity tests."""
    qi = np.asarray(result.query_idx)
    vals = np.asarray(result.values)
    if vals.ndim == 1:
        vals = vals[:, None]
    nres = np.asarray(result.num_results)
    d = nres.shape[0]
    out_cap = qi.shape[0] // d
    parts = []
    for s in range(d):
        m = int(nres[s])
        parts.append(
            np.concatenate(
                [
                    qi[s * out_cap : s * out_cap + m, None],
                    vals[s * out_cap : s * out_cap + m],
                ],
                axis=1,
            )
        )
    ncols = 1 + vals.shape[1]
    return (
        np.concatenate(parts, axis=0)
        if parts
        else np.zeros((0, ncols), np.int32)
    )
