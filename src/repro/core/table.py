"""High-level mesh-facing API for the distributed HashGraph.

Wraps the shard_map internals of ``repro.core.multi_hashgraph`` behind a
simple object: callers hold *global* jax arrays (sharded over a mesh) and
get back global arrays; all paper phases run inside one jitted shard_map.

    table = DistributedHashTable(mesh, axis_names=("data", "model"), hash_range=1 << 20)
    state = table.build(keys)            # keys: (N,) uint32, N % devices == 0
    counts = table.query(state, queries) # multiplicity per query key
    size = table.join_size(state, queries)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.compat import shard_map

import numpy as np

from repro.core import hashing, multi_hashgraph
from repro.core.hashgraph import HashGraph
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    ShardJoin,
    ShardRetrieval,
)
from repro.utils import cdiv as _cdiv


def _dhg_out_specs(axis_names: Sequence[str], hash_range: int, local_cap: int, seed: int):
    ax = tuple(axis_names)
    shard0 = P(ax)  # stack local shards along dim 0 in the global view
    local = HashGraph(
        offsets=shard0,
        keys=shard0,
        values=shard0,
        table_size=local_cap,
        seed=seed,
        sorted_within_bucket=True,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=P(),  # identical on every device
        num_dropped=P(),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=ax,
    )


@dataclasses.dataclass(eq=False)  # identity hash — required for jit static self
class DistributedHashTable:
    """Factory for jitted build/query closures over a fixed mesh."""

    mesh: jax.sharding.Mesh
    axis_names: tuple
    hash_range: int
    seed: int = hashing.DEFAULT_SEED
    capacity_slack: float = 1.25
    range_slack: float = 1.5
    num_bins: Optional[int] = None
    paper_faithful_probe: bool = False
    max_probe: int = 64

    def __post_init__(self):
        self.axis_names = tuple(self.axis_names)
        self.num_devices = 1
        for a in self.axis_names:
            self.num_devices *= self.mesh.shape[a]
        from repro.utils import cdiv

        self.local_range_cap = int(
            cdiv(self.hash_range, self.num_devices) * self.range_slack
        )

    # -- sharding helpers ----------------------------------------------------
    def key_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _in_spec(self):
        return P(self.axis_names)

    # -- build ----------------------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def build(self, keys: jax.Array, values: Optional[jax.Array] = None):
        """Build the distributed table from a global (N,) uint32 key array."""
        out_specs = _dhg_out_specs(
            self.axis_names, self.hash_range, self.local_range_cap, self.seed
        )

        def body(k, v):
            return multi_hashgraph.build_sharded(
                k,
                hash_range=self.hash_range,
                axis_names=self.axis_names,
                values=v,
                num_bins=self.num_bins,
                capacity_slack=self.capacity_slack,
                range_slack=self.range_slack,
                seed=self.seed,
            )

        if values is None:

            def body1(k):
                return body(k, None)

            return shard_map(
                body1,
                mesh=self.mesh,
                in_specs=(self._in_spec(),),
                out_specs=out_specs,
                check_vma=False,
            )(keys)
        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec()),
            out_specs=out_specs,
            check_vma=False,
        )(keys, values)

    # -- query ----------------------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def query(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        """Multiplicity of each global query key. Returns (Nq,) int32."""
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )

        def body(dhg, q):
            return multi_hashgraph.query_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(self.axis_names),
            check_vma=False,
        )(state, queries)

    @partial(jax.jit, static_argnums=0)
    def contains(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        return self.query(state, queries) > 0

    @partial(jax.jit, static_argnums=0)
    def join_size(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        """Global inner-join cardinality (scalar, replicated)."""
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )

        def body(dhg, q):
            return multi_hashgraph.join_size_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(),
            check_vma=False,
        )(state, queries)

    # -- retrieval (two-pass count→prefix-sum→gather) --------------------------
    def _retrieve_caps(self, num_queries: int, out_capacity, seg_capacity):
        """Static output sizing: default to 2× the balanced share, lane-aligned."""
        n_local = num_queries // self.num_devices
        if out_capacity is None:
            out_capacity = 2 * max(n_local, 8)
        if seg_capacity is None:
            seg_capacity = out_capacity
        return _cdiv(out_capacity, 8) * 8, _cdiv(seg_capacity, 8) * 8

    @partial(
        jax.jit,
        static_argnums=0,
        static_argnames=("out_capacity", "seg_capacity"),
    )
    def retrieve(
        self,
        state: DistributedHashGraph,
        queries: jax.Array,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> ShardRetrieval:
        """All stored values for every occurrence of every query key.

        Returns a :class:`ShardRetrieval` whose fields are *global* arrays
        sharded over the mesh — each device holds the CSR over its own query
        shard: block ``d`` of ``offsets`` (``n_local+1`` rows) indexes block
        ``d`` of ``values`` (``out_capacity`` rows).  Use
        :func:`retrieval_to_lists` for a host-side per-query view.

        ``out_capacity`` bounds each device's total result count and
        ``seg_capacity`` the results any one owner shard returns to one
        querying shard; both are static.  Overflow is reported in
        ``num_dropped`` (replicated scalar) — never silently truncated.
        """
        out_cap, seg_cap = self._retrieve_caps(
            queries.shape[0], out_capacity, seg_capacity
        )
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )
        ax = tuple(self.axis_names)
        out_specs = ShardRetrieval(
            offsets=P(ax), values=P(ax), counts=P(ax), num_dropped=P()
        )

        def body(dhg, q):
            return multi_hashgraph.retrieve_sharded(
                dhg,
                q,
                seg_capacity=seg_cap,
                out_capacity=out_cap,
                capacity_slack=self.capacity_slack,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(state, queries)

    @partial(
        jax.jit,
        static_argnums=0,
        static_argnames=("out_capacity", "seg_capacity"),
    )
    def inner_join(
        self,
        state: DistributedHashGraph,
        queries: jax.Array,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> ShardJoin:
        """Materialized inner join: global ``(query_idx, value)`` match pairs.

        Each device emits its pairs into block ``d`` of the global
        ``query_idx``/``values`` arrays, with its valid-pair count in
        ``num_results[d]`` (pairs beyond it are ``-1`` padding).
        ``query_idx`` is the global query row id.  Same capacity/overflow
        contract as :meth:`retrieve`.
        """
        out_cap, seg_cap = self._retrieve_caps(
            queries.shape[0], out_capacity, seg_capacity
        )
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )
        ax = tuple(self.axis_names)
        out_specs = ShardJoin(
            query_idx=P(ax), values=P(ax), num_results=P(ax), num_dropped=P()
        )

        def body(dhg, q):
            return multi_hashgraph.inner_join_sharded(
                dhg,
                q,
                seg_capacity=seg_cap,
                out_capacity=out_cap,
                capacity_slack=self.capacity_slack,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )(state, queries)


def retrieval_to_lists(result: ShardRetrieval) -> list:
    """Host-side view of a :class:`ShardRetrieval`: one np.ndarray per query.

    Queries are sharded contiguously (device ``d`` owns rows
    ``d*n_local : (d+1)*n_local``), so global query ``i``'s values sit in
    device ``i // n_local``'s block of ``values`` at that block's local CSR
    offsets.
    """
    counts = np.asarray(result.counts)
    offsets = np.asarray(result.offsets)
    values = np.asarray(result.values)
    num_queries = counts.shape[0]
    # len(offsets) = D*(n_local+1), len(counts) = D*n_local  =>  D:
    d = offsets.shape[0] - counts.shape[0]
    n_local = num_queries // d
    out_cap = values.shape[0] // d
    per_query = []
    for i in range(num_queries):
        shard, local = divmod(i, n_local)
        off = offsets[shard * (n_local + 1) + local]
        end = offsets[shard * (n_local + 1) + local + 1]
        per_query.append(values[shard * out_cap + off : shard * out_cap + end])
    return per_query


def join_to_pairs(result: ShardJoin) -> "np.ndarray":
    """Host-side view of a :class:`ShardJoin`: an (M, 2) array of match pairs."""
    qi = np.asarray(result.query_idx)
    vals = np.asarray(result.values)
    nres = np.asarray(result.num_results)
    d = nres.shape[0]
    out_cap = qi.shape[0] // d
    parts = []
    for s in range(d):
        m = int(nres[s])
        parts.append(
            np.stack(
                [qi[s * out_cap : s * out_cap + m], vals[s * out_cap : s * out_cap + m]],
                axis=1,
            )
        )
    return np.concatenate(parts, axis=0) if parts else np.zeros((0, 2), np.int32)
