"""High-level mesh-facing API for the distributed HashGraph.

Wraps the shard_map internals of ``repro.core.multi_hashgraph`` behind a
simple object: callers hold *global* jax arrays (sharded over a mesh) and
get back global arrays; all paper phases run inside one jitted shard_map.

    table = DistributedHashTable(mesh, axis_names=("data", "model"), hash_range=1 << 20)
    state = table.build(keys)            # keys: (N,) uint32, N % devices == 0
    counts = table.query(state, queries) # multiplicity per query key
    size = table.join_size(state, queries)

The key width and payload shape are set by a :class:`~repro.core.schema.
TableSchema`: the default (uint32 keys, one int32 value column) is the
paper's layout and the exact PR-1 API; ``TableSchema("uint64", C)`` stores
keys as ``(N, 2)`` packed uint32 lanes (``schema.pack_u64``) and values as
``(N, C)`` int32 columns, threaded through every phase of the pipeline.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.utils.compat import shard_map

import numpy as np

from repro.core import hashing, multi_hashgraph
from repro.core.hashgraph import HashGraph
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    ShardJoin,
    ShardRetrieval,
)
from repro.core.schema import TableSchema
from repro.utils import cdiv as _cdiv


def _dhg_out_specs(axis_names: Sequence[str], hash_range: int, local_cap: int, seed: int):
    ax = tuple(axis_names)
    shard0 = P(ax)  # stack local shards along dim 0 in the global view
    local = HashGraph(
        offsets=shard0,
        keys=shard0,
        values=shard0,
        table_size=local_cap,
        seed=seed,
        sorted_within_bucket=True,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=P(),  # identical on every device
        num_dropped=P(),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=ax,
    )


@dataclasses.dataclass(eq=False)  # identity hash — required for jit static self
class DistributedHashTable:
    """Factory for jitted build/query closures over a fixed mesh.

    ``schema`` selects key width and payload columns (default: the paper's
    uint32 keys + one int32 column).  ``use_kernel`` routes the retrieval
    gather through the Pallas ``csr_gather`` kernel (None = auto: on for
    TPU, jnp path elsewhere).
    """

    mesh: jax.sharding.Mesh
    axis_names: tuple
    hash_range: int
    seed: int = hashing.DEFAULT_SEED
    capacity_slack: float = 1.25
    range_slack: float = 1.5
    num_bins: Optional[int] = None
    paper_faithful_probe: bool = False
    max_probe: int = 64
    schema: Optional[TableSchema] = None
    use_kernel: Optional[bool] = None

    def __post_init__(self):
        self.axis_names = tuple(self.axis_names)
        if self.schema is None:
            self.schema = TableSchema()
        self.num_devices = 1
        for a in self.axis_names:
            self.num_devices *= self.mesh.shape[a]
        from repro.utils import cdiv

        self.local_range_cap = int(
            cdiv(self.hash_range, self.num_devices) * self.range_slack
        )

    # -- sharding helpers ----------------------------------------------------
    def key_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _in_spec(self):
        return P(self.axis_names)

    def _pack_queries(self, queries) -> jax.Array:
        return self.schema.pack_keys(queries)

    # -- build ----------------------------------------------------------------
    def build(self, keys, values=None):
        """Build the distributed table from a global key array.

        ``keys``: ``(N,)`` uint32 for the 1-lane schema, ``(N, 2)`` packed
        uint32 (``schema.pack_u64``) for uint64; ``N % devices == 0``.
        ``values``: optional ``(N,)`` / ``(N, C)`` int32 payload matching
        ``schema.value_cols`` (default: global row ids, 1-column only).
        """
        keys = self.schema.pack_keys(keys)
        if values is None:
            if self.schema.value_cols != 1:
                raise ValueError(
                    f"schema has {self.schema.value_cols} value columns; "
                    "pass explicit values (the row-id default is 1-column)"
                )
            return self._build_jit(keys)
        return self._build_values_jit(keys, self.schema.pack_values(values))

    def _build_body(self, k, v):
        return multi_hashgraph.build_sharded(
            k,
            hash_range=self.hash_range,
            axis_names=self.axis_names,
            values=v,
            num_bins=self.num_bins,
            capacity_slack=self.capacity_slack,
            range_slack=self.range_slack,
            seed=self.seed,
        )

    def _out_specs(self):
        return _dhg_out_specs(
            self.axis_names, self.hash_range, self.local_range_cap, self.seed
        )

    @partial(jax.jit, static_argnums=0)
    def _build_jit(self, keys: jax.Array):
        return shard_map(
            lambda k: self._build_body(k, None),
            mesh=self.mesh,
            in_specs=(self._in_spec(),),
            out_specs=self._out_specs(),
            check_vma=False,
        )(keys)

    @partial(jax.jit, static_argnums=0)
    def _build_values_jit(self, keys: jax.Array, values: jax.Array):
        return shard_map(
            self._build_body,
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec()),
            out_specs=self._out_specs(),
            check_vma=False,
        )(keys, values)

    # -- query ----------------------------------------------------------------
    def query(self, state: DistributedHashGraph, queries) -> jax.Array:
        """Multiplicity of each global query key. Returns (Nq,) int32."""
        return self._query_jit(state, self._pack_queries(queries))

    @partial(jax.jit, static_argnums=0)
    def _query_jit(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        def body(dhg, q):
            return multi_hashgraph.query_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._out_specs(), self._in_spec()),
            out_specs=P(self.axis_names),
            check_vma=False,
        )(state, queries)

    def contains(self, state: DistributedHashGraph, queries) -> jax.Array:
        return self.query(state, queries) > 0

    def join_size(self, state: DistributedHashGraph, queries) -> jax.Array:
        """Global inner-join cardinality (scalar, replicated)."""
        return self._join_size_jit(state, self._pack_queries(queries))

    @partial(jax.jit, static_argnums=0)
    def _join_size_jit(self, state: DistributedHashGraph, queries: jax.Array):
        def body(dhg, q):
            return multi_hashgraph.join_size_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._out_specs(), self._in_spec()),
            out_specs=P(),
            check_vma=False,
        )(state, queries)

    # -- retrieval (two-pass count→prefix-sum→gather) --------------------------
    @partial(jax.jit, static_argnums=0)
    def _plan_seg_capacity_jit(
        self, state: DistributedHashGraph, queries: jax.Array
    ) -> jax.Array:
        def body(dhg, q):
            return multi_hashgraph.plan_seg_capacity_sharded(
                dhg, q, capacity_slack=self.capacity_slack
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._out_specs(), self._in_spec()),
            out_specs=P(),
            check_vma=False,
        )(state, queries)

    def _resolve_caps(self, state, queries, out_capacity, seg_capacity):
        """Static output sizing, lane-aligned.

        ``out_capacity=None`` defaults to 2× the balanced per-device share.
        ``seg_capacity=None`` runs the cheap psum'd-counts planning round
        (``plan_seg_capacity_sharded``) and sizes the return segments
        *exactly*, cutting the padded return traffic of the old
        ``seg = out`` default.
        """
        n_local = queries.shape[0] // self.num_devices
        if out_capacity is None:
            out_capacity = 2 * max(n_local, 8)
        out_cap = _cdiv(out_capacity, 8) * 8
        if seg_capacity is None:
            planned = int(self._plan_seg_capacity_jit(state, queries))
            # Round up to a power of two: at most 2x the exact width (still
            # far below the old seg=out worst case) while quantizing the
            # static shape so repeated calls with shifting duplicate
            # structure reuse a bounded set of compiled programs.
            seg_cap = max(8, 1 << (planned - 1).bit_length()) if planned > 0 else 8
        else:
            seg_cap = _cdiv(seg_capacity, 8) * 8
        return out_cap, seg_cap

    def retrieve(
        self,
        state: DistributedHashGraph,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> ShardRetrieval:
        """All stored values for every occurrence of every query key.

        Returns a :class:`ShardRetrieval` whose fields are *global* arrays
        sharded over the mesh — each device holds the CSR over its own query
        shard: block ``d`` of ``offsets`` (``n_local+1`` rows) indexes block
        ``d`` of ``values`` (``out_capacity`` rows; ``(out_capacity, C)``
        for multi-column schemas).  Use :func:`retrieval_to_lists` for a
        host-side per-query view.

        ``out_capacity`` bounds each device's total result count and
        ``seg_capacity`` the results any one owner shard returns to one
        querying shard; both are static.  ``seg_capacity=None`` sizes the
        segments from a count-only planning round (rounded up to a power of
        two); the planning round blocks on a device→host read, so under an
        outer ``jax.jit`` pass explicit capacities instead.  Overflow is
        reported in ``num_dropped`` (replicated scalar) — never silently
        truncated.
        """
        queries = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(state, queries, out_capacity, seg_capacity)
        return self._retrieve_jit(
            state, queries, out_capacity=out_cap, seg_capacity=seg_cap
        )

    @partial(
        jax.jit,
        static_argnums=0,
        static_argnames=("out_capacity", "seg_capacity"),
    )
    def _retrieve_jit(
        self,
        state: DistributedHashGraph,
        queries: jax.Array,
        *,
        out_capacity: int,
        seg_capacity: int,
    ) -> ShardRetrieval:
        ax = tuple(self.axis_names)
        out_specs = ShardRetrieval(
            offsets=P(ax), values=P(ax), counts=P(ax), num_dropped=P()
        )

        def body(dhg, q):
            return multi_hashgraph.retrieve_sharded(
                dhg,
                q,
                seg_capacity=seg_capacity,
                out_capacity=out_capacity,
                capacity_slack=self.capacity_slack,
                use_kernel=self.use_kernel,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._out_specs(), self._in_spec()),
            out_specs=out_specs,
            check_vma=False,
        )(state, queries)

    def inner_join(
        self,
        state: DistributedHashGraph,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
    ) -> ShardJoin:
        """Materialized inner join: global ``(query_idx, value)`` match pairs.

        Each device emits its pairs into block ``d`` of the global
        ``query_idx``/``values`` arrays, with its valid-pair count in
        ``num_results[d]`` (pairs beyond it are ``-1`` padding).
        ``query_idx`` is the global query row id.  Same capacity/overflow
        contract as :meth:`retrieve`.
        """
        queries = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(state, queries, out_capacity, seg_capacity)
        return self._inner_join_jit(
            state, queries, out_capacity=out_cap, seg_capacity=seg_cap
        )

    @partial(
        jax.jit,
        static_argnums=0,
        static_argnames=("out_capacity", "seg_capacity"),
    )
    def _inner_join_jit(
        self,
        state: DistributedHashGraph,
        queries: jax.Array,
        *,
        out_capacity: int,
        seg_capacity: int,
    ) -> ShardJoin:
        ax = tuple(self.axis_names)
        out_specs = ShardJoin(
            query_idx=P(ax), values=P(ax), num_results=P(ax), num_dropped=P()
        )

        def body(dhg, q):
            return multi_hashgraph.inner_join_sharded(
                dhg,
                q,
                seg_capacity=seg_capacity,
                out_capacity=out_capacity,
                capacity_slack=self.capacity_slack,
                use_kernel=self.use_kernel,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._out_specs(), self._in_spec()),
            out_specs=out_specs,
            check_vma=False,
        )(state, queries)

    # -- dynamic output buffers (ROADMAP: auto-retry on overflow) --------------
    def _auto_retry(
        self, jit_fn, state, queries, out_capacity, seg_capacity, max_retries
    ):
        """Re-run ``jit_fn`` with doubled caps while ``num_dropped > 0``.

        Bails early when doubling stops shrinking ``num_dropped`` — drops
        from the *dispatch* stage depend on ``capacity_slack``, not on the
        output caps, so no amount of doubling (and recompiling) fixes them.
        """
        queries = self._pack_queries(queries)
        out_cap, seg_cap = self._resolve_caps(state, queries, out_capacity, seg_capacity)
        res = jit_fn(state, queries, out_capacity=out_cap, seg_capacity=seg_cap)
        dropped = int(res.num_dropped)
        for _ in range(max_retries):
            if dropped == 0:
                break
            out_cap, seg_cap = out_cap * 2, seg_cap * 2
            res = jit_fn(state, queries, out_capacity=out_cap, seg_capacity=seg_cap)
            prev, dropped = dropped, int(res.num_dropped)
            if dropped >= prev:
                break  # not a capacity problem (e.g. route drops)
        return res

    def retrieve_auto(
        self,
        state: DistributedHashGraph,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        max_retries: int = 4,
    ) -> ShardRetrieval:
        """:meth:`retrieve` with bounded capacity-doubling retries.

        Re-runs with doubled ``out_capacity``/``seg_capacity`` while
        ``num_dropped > 0``, at most ``max_retries`` times (each retry is a
        fresh static shape, hence a recompile — the price of a guaranteed
        fit).  Returns the last attempt either way; callers still check
        ``num_dropped`` (nonzero only if the bound was exhausted or the
        drops are not capacity-fixable).
        """
        return self._auto_retry(
            self._retrieve_jit, state, queries, out_capacity, seg_capacity, max_retries
        )

    def inner_join_auto(
        self,
        state: DistributedHashGraph,
        queries,
        *,
        out_capacity: Optional[int] = None,
        seg_capacity: Optional[int] = None,
        max_retries: int = 4,
    ) -> ShardJoin:
        """:meth:`inner_join` with bounded capacity-doubling retries."""
        return self._auto_retry(
            self._inner_join_jit, state, queries, out_capacity, seg_capacity, max_retries
        )


def retrieval_to_lists(result: ShardRetrieval) -> list:
    """Host-side view of a :class:`ShardRetrieval`: one np.ndarray per query.

    Queries are sharded contiguously (device ``d`` owns rows
    ``d*n_local : (d+1)*n_local``), so global query ``i``'s values sit in
    device ``i // n_local``'s block of ``values`` at that block's local CSR
    offsets.  Multi-column schemas yield ``(k_i, C)`` arrays per query.
    """
    counts = np.asarray(result.counts)
    offsets = np.asarray(result.offsets)
    values = np.asarray(result.values)
    num_queries = counts.shape[0]
    # len(offsets) = D*(n_local+1), len(counts) = D*n_local  =>  D:
    d = offsets.shape[0] - counts.shape[0]
    n_local = num_queries // d
    out_cap = values.shape[0] // d
    per_query = []
    for i in range(num_queries):
        shard, local = divmod(i, n_local)
        off = offsets[shard * (n_local + 1) + local]
        end = offsets[shard * (n_local + 1) + local + 1]
        per_query.append(values[shard * out_cap + off : shard * out_cap + end])
    return per_query


def join_to_pairs(result: ShardJoin) -> "np.ndarray":
    """Host-side view of a :class:`ShardJoin`: an (M, 1 + C) array of rows
    ``(query_idx, *value_columns)`` — ``(M, 2)`` for the 1-column schema."""
    qi = np.asarray(result.query_idx)
    vals = np.asarray(result.values)
    if vals.ndim == 1:
        vals = vals[:, None]
    nres = np.asarray(result.num_results)
    d = nres.shape[0]
    out_cap = qi.shape[0] // d
    parts = []
    for s in range(d):
        m = int(nres[s])
        parts.append(
            np.concatenate(
                [
                    qi[s * out_cap : s * out_cap + m, None],
                    vals[s * out_cap : s * out_cap + m],
                ],
                axis=1,
            )
        )
    ncols = 1 + vals.shape[1]
    return (
        np.concatenate(parts, axis=0)
        if parts
        else np.zeros((0, ncols), np.int32)
    )
