"""High-level mesh-facing API for the distributed HashGraph.

Wraps the shard_map internals of ``repro.core.multi_hashgraph`` behind a
simple object: callers hold *global* jax arrays (sharded over a mesh) and
get back global arrays; all paper phases run inside one jitted shard_map.

    table = DistributedHashTable(mesh, axis_names=("data", "model"), hash_range=1 << 20)
    state = table.build(keys)            # keys: (N,) uint32, N % devices == 0
    counts = table.query(state, queries) # multiplicity per query key
    size = table.join_size(state, queries)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax import shard_map

from repro.core import hashing, multi_hashgraph
from repro.core.hashgraph import HashGraph
from repro.core.multi_hashgraph import DistributedHashGraph


def _dhg_out_specs(axis_names: Sequence[str], hash_range: int, local_cap: int, seed: int):
    ax = tuple(axis_names)
    shard0 = P(ax)  # stack local shards along dim 0 in the global view
    local = HashGraph(
        offsets=shard0,
        keys=shard0,
        values=shard0,
        table_size=local_cap,
        seed=seed,
        sorted_within_bucket=True,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=P(),  # identical on every device
        num_dropped=P(),
        hash_range=hash_range,
        seed=seed,
        local_range_cap=local_cap,
        axis_names=ax,
    )


@dataclasses.dataclass(eq=False)  # identity hash — required for jit static self
class DistributedHashTable:
    """Factory for jitted build/query closures over a fixed mesh."""

    mesh: jax.sharding.Mesh
    axis_names: tuple
    hash_range: int
    seed: int = hashing.DEFAULT_SEED
    capacity_slack: float = 1.25
    range_slack: float = 1.5
    num_bins: Optional[int] = None
    paper_faithful_probe: bool = False
    max_probe: int = 64

    def __post_init__(self):
        self.axis_names = tuple(self.axis_names)
        self.num_devices = 1
        for a in self.axis_names:
            self.num_devices *= self.mesh.shape[a]
        from repro.utils import cdiv

        self.local_range_cap = int(
            cdiv(self.hash_range, self.num_devices) * self.range_slack
        )

    # -- sharding helpers ----------------------------------------------------
    def key_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.axis_names))

    def _in_spec(self):
        return P(self.axis_names)

    # -- build ----------------------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def build(self, keys: jax.Array, values: Optional[jax.Array] = None):
        """Build the distributed table from a global (N,) uint32 key array."""
        out_specs = _dhg_out_specs(
            self.axis_names, self.hash_range, self.local_range_cap, self.seed
        )

        def body(k, v):
            return multi_hashgraph.build_sharded(
                k,
                hash_range=self.hash_range,
                axis_names=self.axis_names,
                values=v,
                num_bins=self.num_bins,
                capacity_slack=self.capacity_slack,
                range_slack=self.range_slack,
                seed=self.seed,
            )

        if values is None:

            def body1(k):
                return body(k, None)

            return shard_map(
                body1,
                mesh=self.mesh,
                in_specs=(self._in_spec(),),
                out_specs=out_specs,
                check_vma=False,
            )(keys)
        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self._in_spec(), self._in_spec()),
            out_specs=out_specs,
            check_vma=False,
        )(keys, values)

    # -- query ----------------------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def query(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        """Multiplicity of each global query key. Returns (Nq,) int32."""
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )

        def body(dhg, q):
            return multi_hashgraph.query_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(self.axis_names),
            check_vma=False,
        )(state, queries)

    @partial(jax.jit, static_argnums=0)
    def contains(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        return self.query(state, queries) > 0

    @partial(jax.jit, static_argnums=0)
    def join_size(self, state: DistributedHashGraph, queries: jax.Array) -> jax.Array:
        """Global inner-join cardinality (scalar, replicated)."""
        in_specs = (
            _dhg_out_specs(
                self.axis_names, self.hash_range, self.local_range_cap, self.seed
            ),
            self._in_spec(),
        )

        def body(dhg, q):
            return multi_hashgraph.join_size_sharded(
                dhg,
                q,
                capacity_slack=self.capacity_slack,
                paper_faithful_probe=self.paper_faithful_probe,
                max_probe=self.max_probe,
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=P(),
            check_vma=False,
        )(state, queries)
