"""Single-device HashGraph (Green [12]) — CSR hash table, TPU-native build.

A HashGraph stores a static hash table as the CSR of the bipartite graph
(hash values × keys):

* ``offsets`` — length ``V + 2``; bucket ``v``'s keys live at
  ``keys[offsets[v] : offsets[v+1]]``.  Bucket ``V`` is a *trash* bucket that
  holds padding sentinels (used when this table is one shard of a
  distributed HashGraph and the all-to-all delivered capacity padding).
* ``keys``   — the input keys grouped by bucket.
* ``values`` — payload per key (defaults to the original input index, the
  "value" the paper attaches for join operations).

TPU adaptation (see DESIGN.md §2): the CUDA build uses ``AtomicAdd`` for the
bucket histogram and for placement (Alg. 1).  TPUs expose no global-memory
atomics, so the build is a **counting sort realized with ``jax.lax.sort``**:
a stable lexicographic sort by (bucket, key) produces exactly the CSR
``keys`` array, and ``searchsorted`` over the sorted bucket ids produces
``offsets``.  The output is identical to the atomic build up to intra-bucket
order (which CUDA atomics leave nondeterministic; ours is deterministic).

Sorting *within* the bucket (``num_keys=2``) is a beyond-paper refinement:
it lets queries use per-bucket binary search (:func:`query_count_sorted`)
instead of the paper's linear bucket scan (:func:`query_count_probe`), which
matters once duplicate counts grow (paper §5.4 observes quadratic decay for
the linear-scan intersection).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import hashing

# Sentinel key marking capacity padding (reserved; valid keys must be < 2^32-1
# for 1-lane keys, < 2^64-1 for 2-lane packed keys — the sentinel is all-ones
# in every lane).
EMPTY_KEY = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# Lane helpers — the schema layer (repro.core.schema) stores keys as (N,)
# uint32 or (N, L) packed uint32 lanes (lane 0 least significant) and values
# as (N,) or (N, C) int32.  Every routine below is polymorphic over both
# layouts; the 1-D forms are bit-identical to the original 32-bit path.
# ---------------------------------------------------------------------------


def is_empty_key(keys: jax.Array) -> jax.Array:
    """Padding-sentinel mask: all lanes equal ``EMPTY_KEY``."""
    if keys.ndim == 1:
        return keys == jnp.uint32(EMPTY_KEY)
    return jnp.all(keys == jnp.uint32(EMPTY_KEY), axis=-1)


def _cols(arr: jax.Array) -> tuple:
    """View a (N,) or (N, L) array as a tuple of (N,) lane/column arrays."""
    if arr.ndim == 1:
        return (arr,)
    return tuple(arr[:, i] for i in range(arr.shape[-1]))


def _from_cols(cols: Sequence, ndim: int) -> jax.Array:
    """Inverse of :func:`_cols` for the given original ndim."""
    if ndim == 1:
        return cols[0]
    return jnp.stack(cols, axis=-1)


def _rows_lt_eq(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Elementwise row comparison ``(a < b, a == b)``.

    1-D arrays compare directly; (..., L) lane arrays compare as packed
    big integers (lane L-1 most significant — numeric uint64 order for the
    2-lane packing).
    """
    if a.ndim == 1:
        return a < b, a == b
    lt = jnp.zeros(jnp.broadcast_shapes(a.shape[:-1], b.shape[:-1]), bool)
    eq = jnp.ones_like(lt)
    for l in reversed(range(a.shape[-1])):
        al, bl = a[..., l], b[..., l]
        lt = lt | (eq & (al < bl))
        eq = eq & (al == bl)
    return lt, eq


def match_epochs(
    keys: jax.Array, ts_keys: jax.Array, ts_epochs: jax.Array
) -> jax.Array:
    """Newest tombstone epoch matching each key; ``-1`` where none match.

    ``keys`` is ``(M,)`` / ``(M, L)``; ``ts_keys`` a ``(T,)`` / ``(T, L)``
    tombstone buffer whose unused slots hold the EMPTY sentinel with epoch
    ``-1``.  A layer of the versioned table with epoch ``e`` must hide key
    ``k`` iff ``match_epochs(k) >= e`` — deletions mask every layer that
    existed when they were issued, and nothing inserted after.  ``O(M * T)``
    vectorized compares; the tombstone ring is small and bounded.
    """
    if ts_keys.shape[0] == 0:
        return jnp.full(keys.shape[:1], -1, jnp.int32)
    if keys.ndim == 1:
        eq = keys[:, None] == ts_keys[None, :]
    else:
        eq = jnp.all(keys[:, None, :] == ts_keys[None, :, :], axis=-1)
    stamped = jnp.where(eq, ts_epochs[None, :].astype(jnp.int32), jnp.int32(-1))
    return jnp.max(stamped, axis=1)


def sort_tombstones(
    ts_keys: jax.Array, ts_epochs: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Sort a tombstone buffer by (key, epoch) for binary-search lookup.

    Duplicate keys (the same key deleted at several epochs) sort with epochs
    ascending, so the *last* entry of a key's run carries its newest epoch —
    exactly what :func:`match_epochs_sorted` reads.  Unused slots (EMPTY key,
    epoch ``-1``) sort to the end: EMPTY is the maximal key value and valid
    keys are required to be strictly smaller.
    """
    if ts_keys.shape[0] == 0:
        return ts_keys, ts_epochs
    key_cols = _cols(ts_keys)
    sort_ops = tuple(reversed(key_cols))  # most-significant lane first
    out = jax.lax.sort(
        (*sort_ops, ts_epochs.astype(jnp.int32)), num_keys=len(sort_ops) + 1
    )
    sorted_keys = _from_cols(tuple(reversed(out[: len(key_cols)])), ts_keys.ndim)
    return sorted_keys, out[-1]


def match_epochs_sorted(
    keys: jax.Array, ts_keys: jax.Array, ts_epochs: jax.Array
) -> jax.Array:
    """Newest tombstone epoch matching each key; ``-1`` where none match.

    Sorted-index counterpart of :func:`match_epochs`: ``ts_keys``/``ts_epochs``
    must come from :func:`sort_tombstones` (keys ascending, epochs ascending
    within duplicate-key runs).  One branchless bisection per key —
    ``O(M log T)`` instead of the broadcast compare's ``O(M * T)`` — which is
    what keeps tombstone masking off the critical path for large delete
    volumes (ROADMAP "tombstone scaling").
    """
    t = ts_keys.shape[0]
    if t == 0:
        return jnp.full(keys.shape[:1], -1, jnp.int32)
    m = keys.shape[0]
    lo = jnp.zeros((m,), jnp.int32)
    hi = jnp.full((m,), t, jnp.int32)
    right = _segment_searchsorted(ts_keys, lo, hi, keys, side="right")
    idx = jnp.clip(right - 1, 0, t - 1)
    hit = (right > 0) & rows_equal(ts_keys[idx], keys)
    return jnp.where(hit, ts_epochs[idx].astype(jnp.int32), jnp.int32(-1))


def rows_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """Row equality for 1-D or multi-lane key arrays (broadcasting)."""
    if a.ndim == 1 and b.ndim == 1:
        return a == b
    if a.ndim == 1 or b.ndim == 1:
        raise ValueError("cannot compare 1-lane with multi-lane keys")
    return jnp.all(a == b, axis=-1)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("offsets", "keys", "values", "fingerprints"),
    meta_fields=("table_size", "seed", "sorted_within_bucket"),
)
@dataclasses.dataclass(frozen=True)
class HashGraph:
    """CSR hash table.  ``offsets.shape == (table_size + 2,)``.

    When ``fingerprints`` is present the rows of a bucket are ordered by
    ``(fingerprint, key)`` instead of plain ``(key)``: the probe path
    bisects the single-lane fingerprint array first and touches the full
    key lanes only inside the (typically 0- or 1-key) run of rows whose
    fingerprint matched — the compact-probe layout of "Compact Parallel
    Hash Tables on the GPU".  Occurrences of one key stay contiguous
    either way (equal keys share a fingerprint), so every CSR invariant
    and the multiset query semantics are unchanged.
    """

    offsets: jax.Array  # (V+2,) int32, monotone
    keys: jax.Array  # (N,) uint32 or (N, L) packed lanes, grouped by bucket
    values: jax.Array  # (N,) or (N, C) int32 payload
    table_size: int  # V (static)
    seed: int  # murmur seed (static)
    sorted_within_bucket: bool  # True => binary-search queries are valid
    fingerprints: Optional[jax.Array] = None  # (N,) uint32 probe lane, or None

    @property
    def capacity(self) -> int:
        return int(self.keys.shape[0])

    @property
    def key_lanes(self) -> int:
        return 1 if self.keys.ndim == 1 else int(self.keys.shape[-1])

    @property
    def value_cols(self) -> int:
        return 1 if self.values.ndim == 1 else int(self.values.shape[-1])

    @property
    def num_valid(self) -> jax.Array:
        """Number of non-padding keys (start of the trash bucket)."""
        return self.offsets[self.table_size]

    def bucket_of(self, queries: jax.Array) -> jax.Array:
        return hashing.hash_to_buckets(queries, self.table_size, seed=self.seed)


def build_from_buckets(
    keys: jax.Array,
    buckets: jax.Array,
    table_size: int,
    values: Optional[jax.Array] = None,
    *,
    seed: int = hashing.DEFAULT_SEED,
    sort_within_bucket: bool = True,
    fingerprint: Optional[bool] = None,
) -> HashGraph:
    """Build a HashGraph given precomputed bucket ids.

    ``buckets`` may contain ``table_size`` to mark padding entries (they land
    in the trash bucket and are excluded from every query).

    ``fingerprint=None`` (auto) stores a probe fingerprint lane exactly when
    the keys are multi-lane — where the fingerprint halves (or better) the
    bytes the sorted search touches.  ``True``/``False`` force it.  A
    fingerprint lane requires ``sort_within_bucket`` (the linear-probe
    layout never bisects, so the lane would be dead weight); it is dropped
    silently otherwise.
    """
    keys = keys.astype(jnp.uint32)
    buckets = buckets.astype(jnp.int32)
    if values is None:
        values = jnp.arange(keys.shape[0], dtype=jnp.int32)
    if fingerprint is None:
        fingerprint = keys.ndim == 2
    fingerprint = bool(fingerprint) and sort_within_bucket
    # Lexicographic sort by (bucket, [fingerprint,] key) with multi-lane keys
    # compared as packed big integers: lane L-1 (most significant) first,
    # lane 0 last.  Value columns ride along unsorted-by.  With the
    # fingerprint lane enabled the within-bucket order is (fp, key) — equal
    # keys share a fingerprint, so per-key runs stay contiguous and the
    # stable sort keeps their input order, same as the plain (key) order.
    key_cols = _cols(keys)
    val_cols = _cols(values)
    fp_ops: tuple = ()
    if fingerprint:
        fp_ops = (hashing.fingerprint32(keys),)
    sort_key_ops = (*fp_ops, *reversed(key_cols))
    num_keys = 1 + len(sort_key_ops) if sort_within_bucket else 1
    out = jax.lax.sort(
        (buckets, *sort_key_ops, *val_cols), num_keys=num_keys, is_stable=True
    )
    sorted_buckets = out[0]
    nf = len(fp_ops)
    sorted_fp = out[1] if fingerprint else None
    sorted_keys = _from_cols(
        tuple(reversed(out[1 + nf : 1 + nf + len(key_cols)])), keys.ndim
    )
    sorted_values = _from_cols(out[1 + nf + len(key_cols) :], values.ndim)
    # offsets[v] = first index whose bucket id >= v ;  offsets[V+1] = N.
    offsets = jnp.searchsorted(
        sorted_buckets, jnp.arange(table_size + 2, dtype=jnp.int32), side="left"
    ).astype(jnp.int32)
    return HashGraph(
        offsets=offsets,
        keys=sorted_keys,
        values=sorted_values,
        table_size=table_size,
        seed=seed,
        sorted_within_bucket=sort_within_bucket,
        fingerprints=sorted_fp,
    )


def build(
    keys: jax.Array,
    table_size: int,
    values: Optional[jax.Array] = None,
    *,
    seed: int = hashing.DEFAULT_SEED,
    sort_within_bucket: bool = True,
    fingerprint: Optional[bool] = None,
) -> HashGraph:
    """Hash ``keys`` and build the CSR table (Alg. 1, TPU-native form)."""
    buckets = hashing.hash_to_buckets(keys, table_size, seed=seed)
    return build_from_buckets(
        keys,
        buckets,
        table_size,
        values,
        seed=seed,
        sort_within_bucket=sort_within_bucket,
        fingerprint=fingerprint,
    )


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------


def _segment_searchsorted(
    sorted_keys: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    q: jax.Array,
    side: str,
) -> jax.Array:
    """Vectorized binary search of ``q[i]`` within ``sorted_keys[lo[i]:hi[i]]``.

    Branchless bisection with a fixed iteration count (log2 of array size),
    so it lowers to a small unrolled loop of gathers — no data-dependent
    control flow, TPU-friendly.  Multi-lane keys compare as packed big
    integers (lane L-1 most significant), gathering every lane at ``mid``.
    """
    n = sorted_keys.shape[0]
    # A range of length L needs bit_length(L) halvings to reach lo == hi
    # (bit_length(n-1) is one short when a bucket spans the whole array —
    # found by hypothesis on a 2-key table with both keys in one bucket).
    iters = max(1, int(n).bit_length())
    lo = lo.astype(jnp.int32)
    hi = hi.astype(jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = (lo + hi) >> 1
        v = sorted_keys[jnp.clip(mid, 0, n - 1)]
        v_lt, v_eq = _rows_lt_eq(v, q)
        go_right = v_lt if side == "left" else (v_lt | v_eq)
        active = lo < hi
        new_lo = jnp.where(active & go_right, mid + 1, lo)
        new_hi = jnp.where(active & ~go_right, mid, hi)
        return new_lo, new_hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def query_locate(
    hg: HashGraph,
    queries: jax.Array,
    buckets: Optional[jax.Array] = None,
    qfp: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Locate each query's match run: ``(starts, counts)``.

    All occurrences of a key are contiguous in a bucket-sorted HashGraph, so
    a query's matches are exactly ``hg.keys[starts[i] : starts[i]+counts[i]]``
    (and its payloads the same slice of ``hg.values``).  This is the counting
    pass of the two-pass count→prefix-sum→gather retrieval pipeline.

    ``buckets`` overrides the bucket mapping (distributed shards map keys to
    local buckets through the global split points, not ``hash % V``).

    When the table carries a fingerprint lane, the bucket window is bisected
    on the single-lane uint32 fingerprints first; the full key lanes are
    only gathered by the verification bisection *inside* the fingerprint
    run, which resolves fingerprint collisions exactly.  ``qfp`` supplies
    precomputed query fingerprints (the fused distributed route hashes each
    routed batch once and probes every layer with it); left ``None`` they
    are derived here.  Ignored for tables without the lane.
    """
    if not hg.sorted_within_bucket:
        raise ValueError("query_locate needs a bucket-sorted HashGraph")
    q = queries.astype(jnp.uint32)
    b = hg.bucket_of(q) if buckets is None else buckets.astype(jnp.int32)
    starts = hg.offsets[b]
    ends = hg.offsets[b + 1]
    if hg.fingerprints is not None:
        if qfp is None:
            qfp = hashing.fingerprint32(q)
        qfp = qfp.astype(jnp.uint32)
        fl = _segment_searchsorted(hg.fingerprints, starts, ends, qfp, side="left")
        fr = _segment_searchsorted(hg.fingerprints, starts, ends, qfp, side="right")
        # Verification pass: exact key bisection confined to [fl, fr) — the
        # run of rows whose fingerprint matched (usually 0 or 1 distinct key).
        starts, ends = fl, fr
    left = _segment_searchsorted(hg.keys, starts, ends, q, side="left")
    right = _segment_searchsorted(hg.keys, starts, ends, q, side="right")
    return left.astype(jnp.int32), (right - left).astype(jnp.int32)


def query_count_sorted(
    hg: HashGraph,
    queries: jax.Array,
    buckets: Optional[jax.Array] = None,
    qfp: Optional[jax.Array] = None,
) -> jax.Array:
    """Exact multiplicity of each query key via per-bucket binary search.

    Requires ``sorted_within_bucket=True``.  O(log bucket_len) gathers per
    query with no cap on duplicates — the beyond-paper query path.
    """
    _, counts = query_locate(hg, queries, buckets, qfp=qfp)
    return counts


def csr_gather(
    starts: jax.Array,
    counts: jax.Array,
    table: jax.Array,
    capacity: int,
    *,
    fill=jnp.int32(-1),
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Second pass of the retrieval pipeline: CSR compaction of match runs.

    Row ``i`` owns ``table[starts[i] : starts[i]+counts[i]]``; the runs are
    concatenated row-major into a static ``(capacity,)`` buffer (HashGraph's
    CSR-build idiom applied to the *output*: prefix-sum the counts, then one
    vectorized gather resolves every output slot).

    Returns ``(offsets, row_idx, gathered, num_dropped)``:

    * ``offsets``  — ``(N+1,)`` int32, clamped to ``capacity``; row ``i``'s
      results are ``gathered[offsets[i]:offsets[i+1]]``.
    * ``row_idx``  — ``(capacity,)`` int32, source row per output slot
      (``-1`` in unused slots).
    * ``gathered`` — ``(capacity,)`` (or ``(capacity, C)`` when ``table``
      has payload columns) same dtype as ``table``; unused slots carry
      ``fill``.
    * ``num_dropped`` — ``()`` int32, ``max(0, total - capacity)``.  Overflow
      is *reported*, never silent: callers must treat ``num_dropped > 0`` as
      "re-run with a larger capacity".
    """
    counts = counts.astype(jnp.int32)
    n_rows = counts.shape[0]
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts, dtype=jnp.int32)]
    )
    total = offsets[-1]
    slot = jnp.arange(capacity, dtype=jnp.int32)
    row = jnp.clip(
        jnp.searchsorted(offsets, slot, side="right").astype(jnp.int32) - 1,
        0,
        n_rows - 1,
    )
    src = starts.astype(jnp.int32)[row] + (slot - offsets[row])
    valid = slot < total
    tn = table.shape[0]
    # table may carry trailing payload columns (N, C); broadcast the mask.
    valid_b = valid.reshape((-1,) + (1,) * (table.ndim - 1))
    gathered = jnp.where(
        valid_b, table[jnp.clip(src, 0, tn - 1)], jnp.asarray(fill, table.dtype)
    )
    row_idx = jnp.where(valid, row, jnp.int32(-1))
    num_dropped = jnp.maximum(total - capacity, 0).astype(jnp.int32)
    return jnp.minimum(offsets, capacity), row_idx, gathered, num_dropped


def retrieve(
    hg: HashGraph,
    queries: jax.Array,
    *,
    capacity: int,
    buckets: Optional[jax.Array] = None,
    fill=jnp.int32(-1),
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Values stored under every occurrence of every query key, CSR-shaped.

    Two-pass count→prefix-sum→gather (the HashGraph build idiom, §3.2,
    applied to the query side — the WarpSpeed-style retrieval API).  Returns
    ``(offsets, values, num_dropped)`` with ``offsets`` of shape
    ``(len(queries)+1,)``: query ``i``'s values are
    ``values[offsets[i]:offsets[i+1]]`` (within-key order is the table's
    deterministic bucket order, not insertion order).  ``capacity`` is the
    static output size; overflow is reported via ``num_dropped``.
    """
    starts, counts = query_locate(hg, queries, buckets)
    offsets, _, values, num_dropped = csr_gather(
        starts, counts, hg.values, capacity, fill=fill
    )
    return offsets, values, num_dropped


def inner_join(
    hg: HashGraph,
    queries: jax.Array,
    *,
    capacity: int,
    buckets: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Materialized inner join: every ``(query_idx, build_value)`` match pair.

    Returns ``(query_idx, values, num_results, num_dropped)``, each output
    array of shape ``(capacity,)`` with ``-1`` / fill beyond ``num_results``.
    """
    starts, counts = query_locate(hg, queries, buckets)
    _, query_idx, values, num_dropped = csr_gather(
        starts, counts, hg.values, capacity
    )
    num_results = jnp.minimum(jnp.sum(counts), capacity).astype(jnp.int32)
    return query_idx, values, num_results, num_dropped


def query_count_probe(
    hg: HashGraph,
    queries: jax.Array,
    max_probe: int = 64,
    buckets: Optional[jax.Array] = None,
) -> jax.Array:
    """Paper-faithful query: linear scan of the query's bucket.

    ``max_probe`` statically caps the scanned bucket length (buckets longer
    than the cap under-count — callers size the cap from the duplicate
    statistics, as the paper sizes its experiments).  This is the access
    pattern the ``bucket_probe`` Pallas kernel implements in VMEM blocks.
    """
    q = queries.astype(jnp.uint32)
    b = hg.bucket_of(q) if buckets is None else buckets.astype(jnp.int32)
    starts = hg.offsets[b]
    ends = hg.offsets[b + 1]
    n = hg.keys.shape[0]
    idx = starts[:, None] + jnp.arange(max_probe, dtype=jnp.int32)[None, :]
    in_bucket = idx < ends[:, None]
    vals = hg.keys[jnp.clip(idx, 0, n - 1)]
    if q.ndim == 1:
        eq = vals == q[:, None]  # (nq, max_probe)
    else:
        eq = jnp.all(vals == q[:, None, :], axis=-1)  # lanes reduced
    hits = in_bucket & eq
    return jnp.sum(hits, axis=1).astype(jnp.int32)


def lookup_first(
    hg: HashGraph, queries: jax.Array, buckets: Optional[jax.Array] = None
) -> jax.Array:
    """Value row of the first matching key per query, or -1 fill (join probe).

    Returns ``(Nq,)`` int32 for single-column payloads, ``(Nq, C)`` for
    multi-column (every column filled with -1 on a miss).
    """
    if not hg.sorted_within_bucket:
        raise ValueError("lookup_first needs a bucket-sorted HashGraph")
    q = queries.astype(jnp.uint32)
    b = hg.bucket_of(q) if buckets is None else buckets.astype(jnp.int32)
    starts = hg.offsets[b]
    ends = hg.offsets[b + 1]
    if hg.fingerprints is not None:
        qfp = hashing.fingerprint32(q)
        starts = _segment_searchsorted(
            hg.fingerprints, starts, ends, qfp, side="left"
        )
        ends = _segment_searchsorted(
            hg.fingerprints, starts, ends, qfp, side="right"
        )
    left = _segment_searchsorted(hg.keys, starts, ends, q, side="left")
    n = hg.keys.shape[0]
    found = (left < ends) & rows_equal(hg.keys[jnp.clip(left, 0, n - 1)], q)
    found_b = found.reshape((-1,) + (1,) * (hg.values.ndim - 1))
    return jnp.where(found_b, hg.values[jnp.clip(left, 0, n - 1)], jnp.int32(-1))


def contains(hg: HashGraph, queries: jax.Array) -> jax.Array:
    """Membership test per query key."""
    return query_count_sorted(hg, queries) > 0


def intersect_join_size(hg_build: HashGraph, hg_query: HashGraph) -> jax.Array:
    """Total inner-join size between two HashGraphs sharing a bucket space.

    The paper's query phase (§3.3): for every key in the query table, count
    its occurrences in the build table; the sum is the join cardinality.
    Padding (trash-bucket) entries contribute zero.
    """
    valid = jnp.arange(hg_query.keys.shape[0]) < hg_query.num_valid
    counts = query_count_sorted(hg_build, hg_query.keys)
    return jnp.sum(jnp.where(valid, counts, 0).astype(jnp.int64 if jax.config.jax_enable_x64 else jnp.int32))
