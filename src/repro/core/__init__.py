"""Core HashGraph library — the paper's contribution.

Single-device CSR hash table (``hashgraph``), global binned partitioning
(``partition``), capacity-padded hierarchical all-to-all (``exchange``),
and the multi-device build/query (``multi_hashgraph``).
"""
from repro.core.hashing import (
    murmur3_u32,
    murmur3_stream,
    murmur3_packed,
    hash_to_buckets,
    fmix32,
)
from repro.core.hashgraph import (
    EMPTY_KEY,
    is_empty_key,
    rows_equal,
    HashGraph,
    build,
    build_from_buckets,
    csr_gather,
    query_locate,
    query_count_sorted,
    query_count_probe,
    lookup_first,
    contains,
    inner_join,
    intersect_join_size,
    retrieve,
)
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    ShardJoin,
    ShardRetrieval,
    build_sharded,
    query_sharded,
    contains_sharded,
    inner_join_sharded,
    join_size_sharded,
    retrieve_sharded,
    plan_seg_capacity_sharded,
)
from repro.core.schema import TableSchema, pack_u64, unpack_u64

__all__ = [
    "EMPTY_KEY",
    "is_empty_key",
    "rows_equal",
    "TableSchema",
    "pack_u64",
    "unpack_u64",
    "murmur3_packed",
    "plan_seg_capacity_sharded",
    "HashGraph",
    "DistributedHashGraph",
    "ShardJoin",
    "ShardRetrieval",
    "murmur3_u32",
    "murmur3_stream",
    "hash_to_buckets",
    "fmix32",
    "build",
    "build_from_buckets",
    "csr_gather",
    "query_locate",
    "query_count_sorted",
    "query_count_probe",
    "lookup_first",
    "contains",
    "inner_join",
    "intersect_join_size",
    "retrieve",
    "build_sharded",
    "query_sharded",
    "contains_sharded",
    "inner_join_sharded",
    "join_size_sharded",
    "retrieve_sharded",
]
