"""Core HashGraph library — the paper's contribution.

Single-device CSR hash table (``hashgraph``), global binned partitioning
(``partition``), capacity-padded hierarchical all-to-all (``exchange``),
and the multi-device build/query (``multi_hashgraph``).
"""
from repro.core.hashing import murmur3_u32, murmur3_stream, hash_to_buckets, fmix32
from repro.core.hashgraph import (
    EMPTY_KEY,
    HashGraph,
    build,
    build_from_buckets,
    query_count_sorted,
    query_count_probe,
    lookup_first,
    contains,
    intersect_join_size,
)
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    build_sharded,
    query_sharded,
    contains_sharded,
    join_size_sharded,
)

__all__ = [
    "EMPTY_KEY",
    "HashGraph",
    "DistributedHashGraph",
    "murmur3_u32",
    "murmur3_stream",
    "hash_to_buckets",
    "fmix32",
    "build",
    "build_from_buckets",
    "query_count_sorted",
    "query_count_probe",
    "lookup_first",
    "contains",
    "intersect_join_size",
    "build_sharded",
    "query_sharded",
    "contains_sharded",
    "join_size_sharded",
]
