"""Plan/execute API — pure, cache-keyed callables over versioned state.

The eager table methods each hid a jit boundary and, for ``retrieve``/
``inner_join`` with unplanned capacities, a device→host sync inside the
call.  A *plan* hoists every static decision — output and segment
capacities, query count, schema — to plan-build time:

    plan = table.plan_retrieve(state, queries)        # counts round, syncs once
    plan = table.plan_retrieve(num_queries=n,         # or fully explicit:
                               out_capacity=4096, seg_capacity=512)
    result = plan(state2, queries2)                   # pure; zero host syncs

The returned callables are ``(state, queries) -> result`` pytree functions:
they accept any :class:`~repro.core.state.TableState` (or bare
``DistributedHashGraph``) with compatible shapes, and compose under an
outer ``jax.jit`` —

    @jax.jit
    def program(keys, new_keys, dead_keys, queries):
        state = table.init(keys)
        state = state.insert(new_keys)
        state = state.delete(dead_keys)
        return plan(state, queries)

— with no recompilation across calls: execution is cache-keyed by (table,
static capacities, state structure) through ``jax.jit``'s cache, so
repeated calls with shifting data reuse one compiled program per delta
depth.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import multi_hashgraph
from repro.core.hashgraph import HashGraph
from repro.core.multi_hashgraph import (
    DistributedHashGraph,
    ShardJoin,
    ShardRetrieval,
)
from repro.core.state import TableState, Tombstones, as_state
from repro.utils.compat import shard_map


# ---------------------------------------------------------------------------
# shard_map spec builders — structure mirrors the pytrees, metadata copied
# from the live values so treedefs match exactly.
# ---------------------------------------------------------------------------


def dhg_specs(dhg: DistributedHashGraph) -> DistributedHashGraph:
    """Partition specs for one graph: local CSR sharded, splits replicated."""
    ax = tuple(dhg.axis_names)
    shard0 = P(ax)  # stack local shards along dim 0 in the global view
    local = HashGraph(
        offsets=shard0,
        keys=shard0,
        values=shard0,
        table_size=dhg.local.table_size,
        seed=dhg.local.seed,
        sorted_within_bucket=dhg.local.sorted_within_bucket,
        fingerprints=shard0 if dhg.local.fingerprints is not None else None,
    )
    return DistributedHashGraph(
        local=local,
        hash_splits=P(),  # identical on every device
        num_dropped=P(),
        hash_range=dhg.hash_range,
        seed=dhg.seed,
        local_range_cap=dhg.local_range_cap,
        axis_names=ax,
        bucket_stride=dhg.bucket_stride,
    )


def state_specs(state: TableState) -> TableState:
    """Partition specs for a whole :class:`TableState` pytree."""
    return TableState(
        base=dhg_specs(state.base),
        deltas=tuple(dhg_specs(d) for d in state.deltas),
        tombstones=Tombstones(
            keys=P(),
            epochs=P(),
            expires=P(),
            count=P(),
            num_dropped=P(),
            now=P(),
        ),
        table=state.table,
        coherent=state.coherent,
    )


def _fused(table, state: TableState) -> bool:
    """Single-route layered execution?  Requires the partition-coherence
    invariant (every delta on the base's splits); ``table.fused_routing=
    False`` forces the per-layer legacy path (parity tests, A/B benches).
    Static — both inputs are jit cache keys."""
    if table.fused_routing is False:
        return False
    return state.coherent or len(state.deltas) == 0


# ---------------------------------------------------------------------------
# jitted executors — the pure (state, queries) -> result programs plans bind.
# ``table`` is a static arg (identity-hashed config), so each (table, caps,
# state structure) triple compiles once and is reused by every plan call.
# ---------------------------------------------------------------------------


def _in_spec(table):
    return P(tuple(table.axis_names))


@partial(jax.jit, static_argnums=(0,), static_argnames=("dest_offset",))
def exec_query(
    table, state: TableState, queries: jax.Array, *, dest_offset: int = 0
) -> jax.Array:
    """Merged multiplicity per query over base + deltas − tombstones.

    ``dest_offset`` (static, default 0 — the guarded hot path) counts
    replica ``r`` of hot-key-replicated rows; ``table.query`` sums rounds
    over ``r = 0..R-1`` to merge replica counts (non-replicated keys count
    0 on every round but the first).
    """

    def body(st, q):
        return multi_hashgraph.query_layers_sharded(
            st.layers,
            q,
            tombstones=st.tombstones.index(),
            fused=_fused(table, st),
            capacity_slack=table.capacity_slack,
            paper_faithful_probe=table.paper_faithful_probe,
            max_probe=table.max_probe,
            dest_offset=dest_offset,
        )

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state), _in_spec(table)),
        out_specs=_in_spec(table),
        check_vma=False,
    )(state, queries)


@partial(jax.jit, static_argnums=(0,))
def exec_join_size(table, state: TableState, queries: jax.Array) -> jax.Array:
    """Global join cardinality over the versioned stack (replicated ())."""

    def body(st, q):
        return multi_hashgraph.join_size_layers_sharded(
            st.layers,
            q,
            tombstones=st.tombstones.index(),
            fused=_fused(table, st),
            capacity_slack=table.capacity_slack,
            paper_faithful_probe=table.paper_faithful_probe,
            max_probe=table.max_probe,
        )

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state), _in_spec(table)),
        out_specs=P(),
        check_vma=False,
    )(state, queries)


@partial(
    jax.jit,
    static_argnums=(0,),
    static_argnames=("out_capacity", "seg_capacity", "per_layer_counts"),
)
def exec_retrieve(
    table,
    state: TableState,
    queries: jax.Array,
    *,
    out_capacity: int,
    seg_capacity: int,
    per_layer_counts: bool = False,
) -> ShardRetrieval:
    """Merged CSR retrieval over the versioned stack.

    ``per_layer_counts=True`` fills the result's ``layer_counts`` provenance
    field (``(Nq, L)`` per-layer result counts); on the fused path the
    breakdown ships inside the same single all-to-all as the values, so the
    collective budget is unchanged (CI-asserted).
    """
    ax = tuple(table.axis_names)
    out_specs = ShardRetrieval(
        offsets=P(ax),
        values=P(ax),
        counts=P(ax),
        num_dropped=P(),
        layer_counts=P(ax) if per_layer_counts else None,
    )

    def body(st, q):
        return multi_hashgraph.retrieve_layers_sharded(
            st.layers,
            q,
            seg_capacity=seg_capacity,
            out_capacity=out_capacity,
            capacity_slack=table.capacity_slack,
            use_kernel=table.use_kernel,
            tombstones=st.tombstones.index(),
            fused=_fused(table, st),
            per_layer_counts=per_layer_counts,
        )

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state), _in_spec(table)),
        out_specs=out_specs,
        check_vma=False,
    )(state, queries)


@partial(
    jax.jit, static_argnums=(0,), static_argnames=("out_capacity", "seg_capacity")
)
def exec_join(
    table,
    state: TableState,
    queries: jax.Array,
    *,
    out_capacity: int,
    seg_capacity: int,
) -> ShardJoin:
    """Materialized inner join over the versioned stack."""
    ax = tuple(table.axis_names)
    out_specs = ShardJoin(
        query_idx=P(ax), values=P(ax), num_results=P(ax), num_dropped=P()
    )

    def body(st, q):
        return multi_hashgraph.inner_join_layers_sharded(
            st.layers,
            q,
            seg_capacity=seg_capacity,
            out_capacity=out_capacity,
            capacity_slack=table.capacity_slack,
            use_kernel=table.use_kernel,
            tombstones=st.tombstones.index(),
            fused=_fused(table, st),
        )

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state), _in_spec(table)),
        out_specs=out_specs,
        check_vma=False,
    )(state, queries)


@partial(jax.jit, static_argnums=(0,))
def exec_plan_caps(table, state: TableState, queries: jax.Array):
    """The one counts round sizing both capacities: ((), ()) int32."""

    def body(st, q):
        return multi_hashgraph.plan_caps_sharded(
            st.layers,
            q,
            capacity_slack=table.capacity_slack,
            tombstones=st.tombstones.index(),
            fused=_fused(table, st),
        )

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state), _in_spec(table)),
        out_specs=(P(), P()),
        check_vma=False,
    )(state, queries)


@partial(jax.jit, static_argnums=(0,))
def exec_live_count(table, state: TableState) -> jax.Array:
    """Global live (non-tombstoned, non-sentinel) row count: replicated ().

    The counts round behind compaction sizing: ``compact()`` sizes the
    rebuild from the rows that will actually survive instead of the
    all-rows worst case, so steady-state insert/delete/compact cycles keep
    the base arrays flat.
    """

    def body(st):
        from repro.core.hashgraph import is_empty_key, match_epochs_sorted

        ts_keys, ts_epochs = st.tombstones.index()
        live = jnp.int32(0)
        for epoch, layer in enumerate(st.layers):
            k = layer.local.keys
            dead = is_empty_key(k)
            if ts_keys.shape[0]:
                dead = dead | (match_epochs_sorted(k, ts_keys, ts_epochs) >= epoch)
            live = live + jnp.sum(~dead).astype(jnp.int32)
        return jax.lax.psum(live, tuple(table.axis_names))

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state),),
        out_specs=P(),
        check_vma=False,
    )(state)


@partial(jax.jit, static_argnums=(0,))
def exec_layer_live(table, state: TableState) -> jax.Array:
    """Per-layer global live row counts: replicated ``(num_layers,)`` int32.

    The per-layer breakdown of :func:`exec_live_count` (same masking, not
    summed across layers), feeding stats-driven fold scheduling: a delta
    whose live fraction has collapsed is cold — mostly superseded or
    expired rows — and is the cheapest capacity to reclaim with
    ``fold_oldest``.  Index 0 is the base; index ``i>0`` is delta ``i-1``.
    """

    def body(st):
        from repro.core.hashgraph import is_empty_key, match_epochs_sorted

        ts_keys, ts_epochs = st.tombstones.index()
        per_layer = []
        for epoch, layer in enumerate(st.layers):
            k = layer.local.keys
            dead = is_empty_key(k)
            if ts_keys.shape[0]:
                dead = dead | (match_epochs_sorted(k, ts_keys, ts_epochs) >= epoch)
            per_layer.append(jnp.sum(~dead).astype(jnp.int32))
        return jax.lax.psum(jnp.stack(per_layer), tuple(table.axis_names))

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(state_specs(state),),
        out_specs=P(),
        check_vma=False,
    )(state)


# ---------------------------------------------------------------------------
# AOT executor handles — lowered/compiled executables a serving front end can
# call with zero live tracing.
# ---------------------------------------------------------------------------


def state_signature(state: TableState) -> tuple:
    """Structural identity of a state for executor-handle keying.

    Two states with equal signatures (same pytree structure — delta depth,
    coherence, static graph metadata — and identical leaf shapes/dtypes)
    execute through the same compiled program; the signature is exactly the
    dynamic part of ``jax.jit``'s cache key, so an AOT executable compiled
    against one is callable with the other.
    """
    leaves, treedef = jax.tree_util.tree_flatten(state)
    return (treedef, tuple((tuple(x.shape), jnp.result_type(x).name) for x in leaves))


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """An AOT-compiled ``(state, queries) -> result`` executable.

    Built by :meth:`QueryPlan.compile` / :meth:`RetrievePlan.compile` —
    the ``jit(...).lower(...).compile()`` idiom: the trace/compile cost is
    paid at *construction*, and calls run the XLA executable directly (the
    jit dispatch cache is never consulted, so a warmed serving path does
    zero live tracing by construction).  Calls require the exact structure
    the plan was lowered for: a state matching :func:`state_signature` and
    a query batch of ``num_queries`` packed keys.
    """

    compiled: object  # jax.stages.Compiled
    kind: str  # "query" | "retrieve"
    num_queries: int
    signature: tuple  # state_signature the executable was lowered against

    def __call__(self, state, queries):
        return self.compiled(state, queries)


def _proto_queries(table, num_queries: int) -> jax.Array:
    """An all-sentinel query batch with the schema's packed shape."""
    from repro.core.hashgraph import EMPTY_KEY

    lanes = table.schema.key_lanes
    shape = (num_queries,) if lanes == 1 else (num_queries, lanes)
    return jnp.full(shape, EMPTY_KEY, jnp.uint32)


# ---------------------------------------------------------------------------
# Plans — small frozen descriptors binding a table to resolved statics.
# ---------------------------------------------------------------------------


class _PlanBase:
    def _prep(self, state, queries):
        st = as_state(self.table, state)
        q = self.table.schema.pack_keys(queries)
        if self.num_queries is not None and q.shape[0] != self.num_queries:
            raise ValueError(
                f"plan was built for {self.num_queries} queries, got {q.shape[0]}"
            )
        return st, q

    def _proto_q(self, queries):
        if queries is not None:
            return self.table.schema.pack_keys(queries)
        if self.num_queries is None:
            raise ValueError("plan has no num_queries; pass a queries sample")
        return _proto_queries(self.table, self.num_queries)


@dataclasses.dataclass(frozen=True)
class QueryPlan(_PlanBase):
    """``(state, queries) -> (Nq,) int32`` merged multiplicities."""

    table: object
    num_queries: Optional[int] = None

    def __call__(self, state, queries) -> jax.Array:
        st, q = self._prep(state, queries)
        return exec_query(self.table, st, q)

    def join_size(self, state, queries) -> jax.Array:
        """Global join cardinality under the same plan (replicated ())."""
        st, q = self._prep(state, queries)
        return exec_join_size(self.table, st, q)

    def lower(self, state, queries=None):
        """AOT-lower the query executor against ``state``'s structure.

        ``queries`` defaults to an all-sentinel batch of ``num_queries``
        keys.  Returns a ``jax.stages.Lowered``; ``.compile()`` it (or use
        :meth:`compile`) to get the executable — tracing happens here, not
        on the first live request.
        """
        st = as_state(self.table, state)
        return exec_query.lower(self.table, st, self._proto_q(queries))

    def compile(self, state, queries=None) -> CompiledPlan:
        """AOT-compile: a :class:`CompiledPlan` callable with zero live
        tracing for any state matching ``state_signature(state)``."""
        st = as_state(self.table, state)
        q = self._proto_q(queries)
        return CompiledPlan(
            compiled=exec_query.lower(self.table, st, q).compile(),
            kind="query",
            num_queries=q.shape[0],
            signature=state_signature(st),
        )


@dataclasses.dataclass(frozen=True)
class RetrievePlan(_PlanBase):
    """``(state, queries) -> ShardRetrieval`` with capacities fixed."""

    table: object
    num_queries: Optional[int]
    out_capacity: int
    seg_capacity: int
    per_layer_counts: bool = False

    def __call__(self, state, queries) -> ShardRetrieval:
        st, q = self._prep(state, queries)
        return exec_retrieve(
            self.table,
            st,
            q,
            out_capacity=self.out_capacity,
            seg_capacity=self.seg_capacity,
            per_layer_counts=self.per_layer_counts,
        )

    def lower(self, state, queries=None):
        """AOT-lower the retrieve executor (capacities baked in) against
        ``state``'s structure; see :meth:`QueryPlan.lower`."""
        st = as_state(self.table, state)
        return exec_retrieve.lower(
            self.table,
            st,
            self._proto_q(queries),
            out_capacity=self.out_capacity,
            seg_capacity=self.seg_capacity,
            per_layer_counts=self.per_layer_counts,
        )

    def compile(self, state, queries=None) -> CompiledPlan:
        """AOT-compile: see :meth:`QueryPlan.compile`."""
        st = as_state(self.table, state)
        q = self._proto_q(queries)
        return CompiledPlan(
            compiled=self.lower(st, q).compile(),
            kind="retrieve",
            num_queries=q.shape[0],
            signature=state_signature(st),
        )


@dataclasses.dataclass(frozen=True)
class JoinPlan(_PlanBase):
    """``(state, queries) -> ShardJoin`` with capacities fixed."""

    table: object
    num_queries: Optional[int]
    out_capacity: int
    seg_capacity: int

    def __call__(self, state, queries) -> ShardJoin:
        st, q = self._prep(state, queries)
        return exec_join(
            self.table,
            st,
            q,
            out_capacity=self.out_capacity,
            seg_capacity=self.seg_capacity,
        )
