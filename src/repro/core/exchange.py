"""Phases 2–3 of Alg. 2 — capacity-padded, hierarchical all-to-all exchange.

The paper reorganizes each device's keys into per-destination contiguous
partitions (a counting sort, Alg. 2 lines 19-34) and then issues a ragged
NCCL all-to-all (line 38).  XLA collectives are static-shape, so the TPU
adaptation packs each destination partition into a fixed ``capacity`` slot
padded with a sentinel — precisely the MoE token-dispatch trick, which is
why :func:`dispatch` / :func:`combine` here also back the MoE layer in
``repro.models.moe`` (the paper's technique as a first-class framework
primitive).

On a multi-axis mesh the exchange is *hierarchical*: one dense
``lax.all_to_all`` per mesh axis, transposing a ``(A, B, ..., capacity)``
partition grid one axis at a time.  This maps onto per-axis ICI rings
instead of emulating NVSwitch's flat crossbar (DESIGN.md §2).

Everything in this module runs *inside* ``shard_map`` — arrays are the
per-device shards.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.utils.compat import axis_size as _axis_size


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("perm", "slot", "keep", "num_dropped"),
    meta_fields=("num_dest", "capacity"),
)
@dataclasses.dataclass(frozen=True)
class Route:
    """Bookkeeping to reverse a dispatch (answers → original local order)."""

    perm: jax.Array  # (N,) argsort-by-destination permutation
    slot: jax.Array  # (N,) flat slot index in the packed buffer (kept rows)
    keep: jax.Array  # (N,) bool, False for capacity-dropped rows
    num_dropped: jax.Array  # () int32 — overflow diagnostics
    num_dest: int
    capacity: int


def axis_sizes(axis_names: Sequence[str]) -> tuple[int, ...]:
    return tuple(_axis_size(a) for a in axis_names)


def device_count(axis_names: Sequence[str]) -> int:
    n = 1
    for a in axis_names:
        n *= _axis_size(a)
    return n


def my_rank(axis_names: Sequence[str]) -> jax.Array:
    """Row-major composite rank over ``axis_names`` (major axis first)."""
    rank = jnp.int32(0)
    for a in axis_names:
        rank = rank * _axis_size(a) + jax.lax.axis_index(a)
    return rank


def pack_by_destination(
    payloads: Sequence[jax.Array],
    dest: jax.Array,
    num_dest: int,
    capacity: int,
    fills: Sequence,
    count_mask: jax.Array = None,
) -> tuple[list[jax.Array], Route]:
    """Counting-sort ``payloads`` by destination into a (num_dest*capacity,) buffer.

    Mirrors Alg. 2 lines 19-31: the stable argsort by destination *is* the
    ``BuffCounter``/``BuffOffset`` counting sort (same output, no atomics).
    Rows beyond ``capacity`` per destination are dropped and counted
    (``num_dropped``) — Phase 1's balanced split keeps this at zero for any
    sane slack; callers assert on it in tests.  ``count_mask`` marks the
    rows whose loss matters: True rows count toward ``num_dropped`` when
    dropped, False rows (padding a caller routes only for load spreading,
    e.g. compaction rebuilds) drop silently.
    """
    n = dest.shape[0]
    dest = dest.astype(jnp.int32)
    perm = jnp.argsort(dest, stable=True)
    sdest = dest[perm]
    # First row of each destination partition in the sorted order.
    starts = jnp.searchsorted(sdest, jnp.arange(num_dest, dtype=jnp.int32), side="left")
    rank_in_part = jnp.arange(n, dtype=jnp.int32) - starts[sdest]
    keep = rank_in_part < capacity
    slot = sdest * capacity + jnp.where(keep, rank_in_part, 0)
    scatter_idx = jnp.where(keep, slot, num_dest * capacity)  # OOB -> dropped
    packed = []
    for p, fill in zip(payloads, fills):
        p = jnp.asarray(p)
        buf = jnp.full((num_dest * capacity,) + p.shape[1:], fill, dtype=p.dtype)
        packed.append(buf.at[scatter_idx].set(p[perm], mode="drop"))
    counted = ~keep if count_mask is None else (~keep & count_mask[perm])
    route = Route(
        perm=perm,
        slot=slot,
        keep=keep,
        num_dropped=jnp.sum(counted).astype(jnp.int32),
        num_dest=num_dest,
        capacity=capacity,
    )
    return packed, route


def all_to_all_hierarchical(
    x: jax.Array, axis_names: Sequence[str]
) -> jax.Array:
    """Dense all-to-all of ``x`` of shape (D, capacity, ...) over ≥1 mesh axes.

    ``D`` must equal the product of the axis sizes, partitions ordered
    row-major by ``axis_names`` (major first — matching :func:`my_rank`).
    One ``lax.all_to_all`` per axis; after all hops, row ``r`` holds the
    partition sent by device ``r``.
    """
    sizes = axis_sizes(axis_names)
    d = 1
    for s in sizes:
        d *= s
    if x.shape[0] != d:
        raise ValueError(f"leading dim {x.shape[0]} != prod(axis sizes) {d}")
    rest = x.shape[1:]
    x = x.reshape(*sizes, *rest)
    for i, a in enumerate(axis_names):
        x = jax.lax.all_to_all(x, a, split_axis=i, concat_axis=i, tiled=True)
    return x.reshape(d, *rest)


def dispatch(
    payloads: Sequence[jax.Array],
    dest: jax.Array,
    axis_names: Sequence[str],
    capacity: int,
    fills: Sequence,
    count_mask: jax.Array = None,
) -> tuple[list[jax.Array], Route]:
    """Send each payload row to device ``dest[row]``.

    Returns per-device received buffers of shape ``(D * capacity,)`` —
    row-major by *source* device — plus the :class:`Route` to send answers
    back.  Padding rows carry the corresponding ``fills`` sentinel.
    ``count_mask`` restricts overflow accounting to the rows it marks
    (see :func:`pack_by_destination`).
    """
    num_dest = device_count(axis_names)
    packed, route = pack_by_destination(
        payloads, dest, num_dest, capacity, fills, count_mask=count_mask
    )
    received = []
    for buf in packed:
        b = buf.reshape(num_dest, capacity, *buf.shape[1:])
        b = all_to_all_hierarchical(b, axis_names)
        received.append(b.reshape(num_dest * capacity, *buf.shape[1:]))
    return received, route


def combine(
    answers: jax.Array,
    route: Route,
    axis_names: Sequence[str],
    fill,
) -> jax.Array:
    """Inverse of :func:`dispatch` for per-slot answers.

    ``answers`` is laid out like the received buffers ``(D*capacity,)``;
    the reverse all-to-all restores the sender's packed layout, then the
    route unpacks to the original local row order.  Dropped rows get
    ``fill``.
    """
    d, cap = route.num_dest, route.capacity
    rest = answers.shape[1:]
    back = all_to_all_hierarchical(answers.reshape(d, cap, *rest), axis_names)
    back = back.reshape(d * cap, *rest)
    keep = route.keep.reshape((-1,) + (1,) * len(rest))
    ans_sorted = jnp.where(keep, back[route.slot], fill)
    out = jnp.empty_like(ans_sorted)
    return out.at[route.perm].set(ans_sorted)


def combine_ragged(
    seg_values: jax.Array,
    slot_counts: jax.Array,
    route: Route,
    axis_names: Sequence[str],
    layer_counts: jax.Array = None,
):
    """Inverse of :func:`dispatch` for *variable-fanout* answers (retrieval).

    :func:`combine` returns exactly one answer per dispatched row; retrieval
    returns ``count[i]`` values for row ``i``.  The owner packs, for each
    source device ``s``, the concatenation of its block's answer runs (slot
    order) into ``seg_values[s]`` of static width ``seg_capacity`` and
    reports per-slot run lengths in ``slot_counts`` (laid out like the
    received buffers, ``(D*capacity,)``).  Every device runs this
    symmetrically: one reverse all-to-all ships the segments home, a second
    ships the counts, and the exclusive prefix sum of the returned counts
    reconstructs — without any extra communication — the exact offsets the
    owner used when packing.

    Returns ``(counts, starts, values)`` in the dispatcher's original row
    order:

    * ``counts`` — ``(N,)`` int32 result count per row (0 for capacity-dropped
      rows).
    * ``starts`` — ``(N,)`` int32 start of row ``i``'s run inside ``values``;
      row ``i``'s answers are ``values[starts[i] : starts[i]+counts[i]]``.
    * ``values`` — ``(D*seg_capacity,)`` returned segments, row-major by
      owner device (``(D*seg_capacity, C)`` when ``seg_values`` carries
      trailing payload columns ``(D, seg_capacity, C)``).

    Segment overflow (a block's runs exceeding ``seg_capacity``) is the
    *owner's* to report (see ``multi_hashgraph.retrieve_sharded``); this
    routine never hides it — the counts it returns are the true run lengths.

    The whole return trip is **one** collective round: per peer, the int32
    slot counts are bitcast into the segment's dtype and concatenated onto
    the flattened value segment, so a single all-to-all ships both (split
    and bitcast back on arrival).  Non-32-bit payloads fall back to two
    rounds.

    ``layer_counts`` extends the same trick to the *per-layer* count
    breakdown of a fused layered retrieval: an optional ``(L, D*capacity)``
    int32 array of per-layer run lengths (laid out like ``slot_counts``,
    one plane per layer) is bitcast and concatenated onto the same packed
    buffer — still ONE all-to-all — and a fourth output ``per_layer`` of
    shape ``(N, L)`` is returned, giving each dispatched row its result
    count split by layer (zero for dropped rows).  The caller remains
    responsible for ``slot_counts`` equalling the plane sum; this routine
    ships both independently.
    """
    d, cap = route.num_dest, route.capacity
    seg_cap = seg_values.shape[1]
    rest = seg_values.shape[2:]
    counts_i32 = slot_counts.astype(jnp.int32).reshape(d, cap)
    nlayers = 0
    if layer_counts is not None:
        nlayers = layer_counts.shape[0]
        # (L, D*cap) -> (D, L*cap): each destination's planes pack together.
        planes = (
            layer_counts.astype(jnp.int32)
            .reshape(nlayers, d, cap)
            .swapaxes(0, 1)
            .reshape(d, nlayers * cap)
        )
    if seg_values.dtype.itemsize == 4:
        # Fused return: values and counts share one 32-bit lane buffer.
        vals_flat = seg_values.reshape(d, -1)
        cast = (
            (lambda c: c)
            if vals_flat.dtype == jnp.int32
            else (lambda c: jax.lax.bitcast_convert_type(c, vals_flat.dtype))
        )
        parts = [vals_flat, cast(counts_i32)]
        if nlayers:
            parts.append(cast(planes))
        back = all_to_all_hierarchical(jnp.concatenate(parts, axis=1), axis_names)
        split = vals_flat.shape[1]
        back_vals = back[:, :split].reshape(d, seg_cap, *rest)
        back_counts = back[:, split : split + cap]
        back_planes = back[:, split + cap :]
        if back_counts.dtype != jnp.int32:
            back_counts = jax.lax.bitcast_convert_type(back_counts, jnp.int32)
            back_planes = jax.lax.bitcast_convert_type(back_planes, jnp.int32)
    else:  # pragma: no cover - no 64-bit payloads in the current stack
        back_counts = all_to_all_hierarchical(counts_i32, axis_names)
        back_vals = all_to_all_hierarchical(seg_values, axis_names)
        back_planes = (
            all_to_all_hierarchical(planes, axis_names) if nlayers else None
        )
    # Owner o packed my block by the exclusive cumsum of my slots' counts —
    # recompute the identical offsets from the returned counts.
    block_off = jnp.cumsum(back_counts, axis=1) - back_counts
    flat_counts = back_counts.reshape(-1)
    flat_off = block_off.reshape(-1)
    owner = route.slot // cap
    starts_packed = owner * seg_cap + flat_off[route.slot]
    counts_sorted = jnp.where(route.keep, flat_counts[route.slot], 0)
    starts_sorted = jnp.where(route.keep, starts_packed, 0)
    counts = jnp.empty_like(counts_sorted).at[route.perm].set(counts_sorted)
    starts = jnp.empty_like(starts_sorted).at[route.perm].set(starts_sorted)
    values = back_vals.reshape(d * seg_cap, *rest)
    if not nlayers:
        return counts, starts, values
    # Per-layer breakdown: owner o's plane for my slot j sits at
    # back_planes[o, l*cap + j]; unsort exactly like the totals.
    bp = back_planes.reshape(d, nlayers, cap)
    pl_sorted = bp[owner[:, None], jnp.arange(nlayers)[None, :], (route.slot % cap)[:, None]]
    pl_sorted = jnp.where(route.keep[:, None], pl_sorted, 0)
    per_layer = jnp.empty_like(pl_sorted).at[route.perm].set(pl_sorted)
    return counts, starts, values, per_layer
