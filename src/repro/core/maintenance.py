"""Incremental background compaction — fold the oldest layers, off the read path.

``compact()`` folds the *whole* stack (all deltas + tombstones) into a
fresh base through a full four-phase rebuild: a pre-balance all-to-all,
the build exchange, and a re-histogram.  That is the right periodic
flattening pass, but it is exactly what a serving loop must not run
inline — the pause is proportional to the whole table.

:func:`fold_oldest` is the incremental alternative: merge only the ``k``
oldest delta layers into the base.  On a partition-coherent stack (the
default — every delta built on the base's frozen ``hash_splits``) this is
a *layer-local* rebuild (``multi_hashgraph.fold_layers_local``): each
device already owns its hash range's rows in every layer, so the fold is
pure local compute — **zero collective rounds** (regression-tested) and a
pause proportional to the folded layers only, not the table.  The
remaining deltas and the surviving tombstones shift down by ``k`` epochs
and the stack keeps serving unchanged.

:class:`CompactionPolicy` decides *when*: delta-depth, tombstone-load and
dropped-rows triggers over a cheap :class:`TableStats` snapshot.  It
generalizes ``TableState.should_compact()`` (which is now a thin shim over
it) and is shared with the ``repro.serve_table`` server, which runs the
policy against its shadow state between write batches — readers never see
a fold, only the atomically published result.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import multi_hashgraph, plans
from repro.core.hashgraph import EMPTY_KEY
from repro.core.state import TableState, Tombstones
from repro.utils.compat import shard_map


# ---------------------------------------------------------------------------
# Cheap state snapshot for policy decisions and server metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Host-side snapshot of a :class:`TableState`'s maintenance signals.

    Static structure (delta depth, allocated rows) comes for free; the
    device reads are three scalars (tombstone fill, tombstone overflow,
    total drops) — cheap enough to poll between update batches, never call
    inside a jitted program.
    """

    delta_depth: int  # live deltas (static)
    base_rows: int  # base local CSR rows × devices (allocated, static)
    delta_rows: int  # sum of delta CSR rows (allocated, static)
    tombstone_count: int  # used tombstone slots
    tombstone_capacity: int  # allocated tombstone slots (static)
    tombstone_dropped: int  # deletes lost to tombstone capacity
    num_dropped: int  # total drops across builds + tombstones

    @property
    def tombstone_load(self) -> float:
        """Tombstone fill fraction (0.0 on a zero-capacity buffer)."""
        if not self.tombstone_capacity:
            return 0.0
        return self.tombstone_count / self.tombstone_capacity


def collect_stats(state: TableState) -> TableStats:
    """Read a :class:`TableStats` snapshot off ``state`` (host-syncing)."""
    ts = state.tombstones
    return TableStats(
        delta_depth=len(state.deltas),
        base_rows=int(state.base.local.keys.shape[0]),
        delta_rows=sum(int(d.local.keys.shape[0]) for d in state.deltas),
        tombstone_count=int(ts.count),
        tombstone_capacity=ts.capacity,
        tombstone_dropped=int(ts.num_dropped),
        num_dropped=int(state.num_dropped),
    )


# ---------------------------------------------------------------------------
# Compaction policy — when to fold, and how much
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Trigger thresholds for (incremental) compaction.

    * ``max_delta_depth`` — fold when the delta ring reaches this depth
      (``None`` disables; servers default it to ``table.max_deltas`` so an
      insert never hits the ring-full error).
    * ``tombstone_load`` — fold when the tombstone buffer's fill fraction
      reaches this value.
    * ``tombstone_overflow`` — fold when deletes were lost to tombstone
      capacity (``num_dropped > 0`` on the buffer); only a *full* fold
      frees every tombstone slot, so :meth:`fold_amount` escalates.
    * ``max_dropped`` — fold when total dropped rows exceed this
      (``None`` disables).
    * ``fold_k`` — how many of the oldest deltas an incremental
      maintenance pass merges (:func:`fold_oldest`'s ``k``).
    """

    max_delta_depth: Optional[int] = None
    tombstone_load: float = 0.5
    tombstone_overflow: bool = True
    max_dropped: Optional[int] = None
    fold_k: int = 2

    def due(self, stats: TableStats) -> bool:
        """Is a state with these stats due for compaction?"""
        if (
            self.max_delta_depth is not None
            and stats.delta_depth >= self.max_delta_depth
        ):
            return True
        return self.escalates(stats)

    def escalates(self, stats: TableStats) -> bool:
        """Does this state need a FULL compaction (not an incremental fold)?

        True under tombstone or dropped-row pressure: partial folds only
        free tombstones with epochs inside the folded prefix and *carry*
        the folded layers' drop tally into the new base, so both pressures
        want the full rebuild — and that holds even at delta depth 0
        (tombstones and drops fold away only through ``compact()``).
        """
        if self.tombstone_overflow and stats.tombstone_dropped > 0:
            return True
        if (
            stats.tombstone_capacity
            and stats.tombstone_load >= self.tombstone_load
        ):
            return True
        return self.max_dropped is not None and stats.num_dropped > self.max_dropped

    def fold_amount(self, stats: TableStats) -> int:
        """How many oldest layers to fold for a state with these stats.

        Incremental (``fold_k``) by default; :meth:`escalates` promotes to
        every delta (callers run the full ``compact()`` there, which also
        handles the depth-0 tombstone-only case an oldest-k fold cannot).
        """
        if self.escalates(stats):
            return stats.delta_depth
        if not stats.delta_depth:
            return 0
        return min(max(1, self.fold_k), stats.delta_depth)


# ---------------------------------------------------------------------------
# fold_oldest — the incremental fold
# ---------------------------------------------------------------------------


def _remap_tombstones(ts: Tombstones, k: int) -> Tombstones:
    """Shift a tombstone buffer past a fold of the ``k`` oldest deltas.

    A tombstone with epoch ``e`` hides layers ``0..e``.  After the fold,
    layers ``0..k`` are one new base with the masking already applied:
    tombstones with ``e <= k`` are spent (and MUST be discarded — kept,
    they would wrongly hide folded rows of later epochs), tombstones with
    ``e > k`` keep hiding the surviving deltas at ``e - k``.  Survivors are
    repacked to the front so ``push`` keeps appending densely; the
    overflow tally is preserved (lost deletes stay lost until a caller
    decides to trust a full rebuild).  Pure and traceable.
    """
    keep = ts.epochs > k
    order = jnp.argsort(~keep, stable=True)  # survivors first
    kept = keep[order]
    keys = ts.keys[order]
    kept_b = kept[:, None] if keys.ndim == 2 else kept
    return Tombstones(
        keys=jnp.where(kept_b, keys, jnp.uint32(EMPTY_KEY)),
        epochs=jnp.where(kept, ts.epochs[order] - k, jnp.int32(-1)),
        count=jnp.sum(keep).astype(jnp.int32),
        num_dropped=ts.num_dropped,
    )


@partial(jax.jit, static_argnums=(0,), static_argnames=("k",))
def exec_fold(table, state: TableState, *, k: int):
    """Jitted layer-local fold: ``(new_base, remapped_tombstones)``.

    Collective-free by construction (``fold_layers_local`` never leaves
    the device) — the property the serving smoke test asserts on this
    executor's jaxpr.
    """

    def body(st):
        new_base = multi_hashgraph.fold_layers_local(
            st.layers[: k + 1], tombstones=st.tombstones.index()
        )
        return new_base, _remap_tombstones(st.tombstones, k)

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(plans.state_specs(state),),
        out_specs=(
            plans.dhg_specs(state.base),
            Tombstones(keys=P(), epochs=P(), count=P(), num_dropped=P()),
        ),
        check_vma=False,
    )(state)


def fold_oldest(state: TableState, k: int) -> TableState:
    """Merge the ``k`` oldest delta layers into the base; keep the rest.

    The incremental counterpart of ``state.compact()``: the new state has
    ``depth - k`` deltas, the surviving tombstones shifted down ``k``
    epochs, and answers every query identically (oracle-tested against the
    full compaction).  On a coherent stack the fold is layer-local — zero
    collective rounds, pause proportional to the folded layers only — so a
    server can run it against a shadow state while readers keep hitting
    the previous snapshot.

    The folded base's row allocation grows by the folded deltas' rows
    (tombstoned rows become sentinels but keep their slots); a periodic
    full ``compact()`` (live-count sized) re-flattens it.  Mixed-split
    (incoherent) stacks cannot fold locally and fall back to the full
    ``compact()``.  ``k <= 0`` is the identity; ``k`` is clamped to the
    delta depth.
    """
    k = min(int(k), len(state.deltas))
    if k <= 0:
        return state
    table = state.table
    if not state.coherent:
        return table.compact(state)
    new_base, new_ts = exec_fold(table, state, k=k)
    return TableState(
        base=new_base,
        deltas=state.deltas[k:],
        tombstones=new_ts,
        table=table,
        coherent=True,
    )
