"""Incremental background compaction — fold the oldest layers, off the read path.

``compact()`` folds the *whole* stack (all deltas + tombstones) into a
fresh base through a full four-phase rebuild: a pre-balance all-to-all,
the build exchange, and a re-histogram.  That is the right periodic
flattening pass, but it is exactly what a serving loop must not run
inline — the pause is proportional to the whole table.

:func:`fold_oldest` is the incremental alternative: merge only the ``k``
oldest delta layers into the base.  On a partition-coherent stack (the
default — every delta built on the base's frozen ``hash_splits``) this is
a *layer-local* rebuild (``multi_hashgraph.fold_layers_local``): each
device already owns its hash range's rows in every layer, so the fold is
pure local compute — **zero collective rounds** (regression-tested) and a
pause proportional to the folded layers only, not the table.  The
remaining deltas and the surviving tombstones shift down by ``k`` epochs
and the stack keeps serving unchanged.

:class:`CompactionPolicy` decides *when*: delta-depth, tombstone-load and
dropped-rows triggers over a cheap :class:`TableStats` snapshot.  It
generalizes ``TableState.should_compact()`` (which is now a thin shim over
it) and is shared with the ``repro.serve_table`` server, which runs the
policy against its shadow state between write batches — readers never see
a fold, only the atomically published result.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import multi_hashgraph, plans
from repro.core.hashgraph import EMPTY_KEY
from repro.core.state import TableState, Tombstones
from repro.utils.compat import shard_map


# ---------------------------------------------------------------------------
# Cheap state snapshot for policy decisions and server metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TableStats:
    """Host-side snapshot of a :class:`TableState`'s maintenance signals.

    Static structure (delta depth, allocated rows) comes for free; the
    device reads are three scalars (tombstone fill, tombstone overflow,
    total drops) — cheap enough to poll between update batches, never call
    inside a jitted program.
    """

    delta_depth: int  # live deltas (static)
    base_rows: int  # base local CSR rows × devices (allocated, static)
    delta_rows: int  # sum of delta CSR rows (allocated, static)
    tombstone_count: int  # used tombstone slots
    tombstone_capacity: int  # allocated tombstone slots (static)
    tombstone_dropped: int  # deletes lost to tombstone capacity
    num_dropped: int  # total drops across builds + tombstones
    tombstone_expired: int = 0  # entries already effective at the clock

    @property
    def tombstone_load(self) -> float:
        """Tombstone fill fraction (0.0 on a zero-capacity buffer)."""
        if not self.tombstone_capacity:
            return 0.0
        return self.tombstone_count / self.tombstone_capacity

    @property
    def expired_load(self) -> float:
        """Expired-entry fill fraction — the TTL-eviction pressure signal.

        Every expired entry names rows that reads already mask but whose
        slots (table rows + the tombstone slot itself) only a fold/compact
        reclaims; this is the fraction :class:`CompactionPolicy`'s
        eviction trigger watches.
        """
        if not self.tombstone_capacity:
            return 0.0
        return self.tombstone_expired / self.tombstone_capacity


def collect_stats(state: TableState) -> TableStats:
    """Read a :class:`TableStats` snapshot off ``state`` (host-syncing)."""
    ts = state.tombstones
    if ts.capacity:
        expired = int(
            np.count_nonzero(
                (np.asarray(ts.epochs) >= 0)
                & (int(ts.now) >= np.asarray(ts.expires))
            )
        )
    else:
        expired = 0
    return TableStats(
        delta_depth=len(state.deltas),
        base_rows=int(state.base.local.keys.shape[0]),
        delta_rows=sum(int(d.local.keys.shape[0]) for d in state.deltas),
        tombstone_count=int(ts.count),
        tombstone_capacity=ts.capacity,
        tombstone_dropped=int(ts.num_dropped),
        num_dropped=int(state.num_dropped),
        tombstone_expired=expired,
    )


def collect_layer_live(state: TableState) -> tuple:
    """Per-layer ``(live_rows, allocated_rows)`` pairs, base first.

    One jitted counts round (:func:`repro.core.plans.exec_layer_live`) —
    the signal behind stats-driven fold sizing (``fold_k=None``): a delta
    whose live fraction has decayed (rows superseded by upserts, deleted,
    or TTL-expired) is *cold* and folds away almost for free, so the
    policy folds the longest cold prefix first.  Host-syncing; call
    eagerly between batches, never inside ``jax.jit``.
    """
    live = [int(x) for x in plans.exec_layer_live(state.table, state)]
    alloc = [int(layer.local.keys.shape[0]) for layer in state.layers]
    return tuple(zip(live, alloc))


# ---------------------------------------------------------------------------
# Compaction policy — when to fold, and how much
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Trigger thresholds for (incremental) compaction.

    * ``max_delta_depth`` — fold when the delta ring reaches this depth
      (``None`` disables; servers default it to ``table.max_deltas`` so an
      insert never hits the ring-full error).
    * ``tombstone_load`` — fold when the tombstone buffer's fill fraction
      reaches this value.
    * ``tombstone_overflow`` — fold when deletes were lost to tombstone
      capacity (``num_dropped > 0`` on the buffer); only a *full* fold
      frees every tombstone slot, so :meth:`fold_amount` escalates.
    * ``max_dropped`` — fold when total dropped rows exceed this
      (``None`` disables).
    * ``fold_k`` — how many of the oldest deltas an incremental
      maintenance pass merges (:func:`fold_oldest`'s ``k``).  ``None``
      selects **stats-driven** sizing: the caller passes the per-layer
      live-row measurement (:func:`collect_layer_live`) to
      :meth:`fold_amount`, which folds the longest prefix of *cold*
      deltas (live rows at or below ``cold_live_ratio`` of the hottest
      delta's) — cold layers are mostly superseded/expired rows, so
      folding them first reclaims the most capacity per unit of fold
      pause.
    * ``cold_live_ratio`` — fraction of the hottest delta's live count
      at or below which a delta counts as cold for the stats-driven fold
      (``fold_k=None``).
    * ``expired_load`` — TTL-eviction trigger: escalate to a full compact
      when the fraction of tombstone entries already *expired* (effective
      at the clock — rows reads mask but whose capacity is still held)
      reaches this value.  ``None`` disables; irrelevant without TTLs
      (plain deletes also count as expired entries, but the plain
      ``tombstone_load`` trigger fires first at the default settings).
    """

    max_delta_depth: Optional[int] = None
    tombstone_load: float = 0.5
    tombstone_overflow: bool = True
    max_dropped: Optional[int] = None
    fold_k: Optional[int] = 2
    cold_live_ratio: float = 0.5
    expired_load: Optional[float] = None

    def due(self, stats: TableStats) -> bool:
        """Is a state with these stats due for compaction?"""
        if (
            self.max_delta_depth is not None
            and stats.delta_depth >= self.max_delta_depth
        ):
            return True
        return self.escalates(stats)

    def escalates(self, stats: TableStats) -> bool:
        """Does this state need a FULL compaction (not an incremental fold)?

        True under tombstone or dropped-row pressure: partial folds only
        free tombstones with epochs inside the folded prefix and *carry*
        the folded layers' drop tally into the new base, so both pressures
        want the full rebuild — and that holds even at delta depth 0
        (tombstones and drops fold away only through ``compact()``).  The
        ``expired_load`` eviction trigger escalates for the same reason:
        only the live-count-sized full rebuild returns the capacity that
        expired rows hold.
        """
        if self.tombstone_overflow and stats.tombstone_dropped > 0:
            return True
        if (
            stats.tombstone_capacity
            and stats.tombstone_load >= self.tombstone_load
        ):
            return True
        if (
            self.expired_load is not None
            and stats.tombstone_capacity
            and stats.expired_load >= self.expired_load
        ):
            return True
        return self.max_dropped is not None and stats.num_dropped > self.max_dropped

    def fold_amount(self, stats: TableStats, layer_live=None) -> int:
        """How many oldest layers to fold for a state with these stats.

        Incremental (``fold_k``) by default; :meth:`escalates` promotes to
        every delta (callers run the full ``compact()`` there, which also
        handles the depth-0 tombstone-only case an oldest-k fold cannot).

        With ``fold_k=None`` the size is derived from ``layer_live`` (the
        :func:`collect_layer_live` measurement, base first): fold the
        longest prefix of deltas that are *cold* — live rows at or below
        ``cold_live_ratio`` of the hottest delta's live count.  Coldness
        is relative to the stack's peak, not to allocated rows: allocation
        carries the capacity slack and lane rounding, so even a fully-live
        delta sits well under 1.0 of its allocation, while peak-relative
        comparison is scale- and slack-free (an all-dead stack folds
        entirely, a uniformly-hot stack folds the minimum).  Always at
        least one delta, so a due fold makes progress even when every
        delta is hot.  Without a measurement the stats-driven mode
        degrades to a minimal fold of 1.
        """
        if self.escalates(stats):
            return stats.delta_depth
        if not stats.delta_depth:
            return 0
        if self.fold_k is not None:
            return min(max(1, self.fold_k), stats.delta_depth)
        k = 1
        if layer_live is not None:
            # layer_live[0] is the base; deltas start at index 1.  Extend
            # the folded prefix while the next-oldest delta is cold.
            deltas = layer_live[1:]
            peak = max((live for live, _ in deltas), default=0)
            if peak == 0:
                k = len(deltas)  # nothing live anywhere: fold them all
            else:
                for j, (live, _alloc) in enumerate(deltas, start=1):
                    if live <= self.cold_live_ratio * peak:
                        k = j
                    else:
                        break
        return min(max(1, k), stats.delta_depth)


# ---------------------------------------------------------------------------
# fold metrics — one recording helper shared by every fold driver
# ---------------------------------------------------------------------------


def allocated_rows(state: TableState) -> int:
    """Total allocated CSR rows (base + deltas) — static, no device sync."""
    return int(state.base.local.keys.shape[0]) + sum(
        int(d.local.keys.shape[0]) for d in state.deltas
    )


def record_fold(
    metrics,
    *,
    kind: str,
    seconds: float,
    rows_before: int,
    rows_after: int,
) -> None:
    """Fold pause-time + reclaimed-rows into a metrics registry.

    ``kind`` is ``"fold"`` (incremental) or ``"full"`` (compact
    escalation).  Reclaimed rows are clamped at zero: an incremental fold
    *grows* the base by the folded deltas' rows by design — only the full
    rebuild reclaims — and a negative "reclaimed" count would poison the
    counter's monotonicity.  One recording site per fold; drivers
    (``TableServer._apply_fold``, ``KVCache.maintain``) call this rather
    than passing a registry down into :func:`fold_oldest`, so a fold is
    never double-counted.
    """
    if metrics is None:
        return
    metrics.counter(
        "maintenance_folds_total",
        labels={"kind": kind},
        help="Fold/compact passes by kind (fold=incremental, full=rebuild).",
    ).inc()
    metrics.histogram(
        "maintenance_fold_seconds",
        labels={"kind": kind},
        help="Fold pause time (the write-path stall a fold costs).",
    ).observe(seconds)
    reclaimed = max(0, int(rows_before) - int(rows_after))
    metrics.counter(
        "maintenance_reclaimed_rows_total",
        help="Allocated CSR rows returned by folds/compactions.",
    ).inc(reclaimed)
    metrics.gauge(
        "maintenance_last_reclaimed_rows",
        help="Rows reclaimed by the most recent fold (0 when it grew).",
    ).set(reclaimed)


# ---------------------------------------------------------------------------
# fold_oldest — the incremental fold
# ---------------------------------------------------------------------------


def _remap_tombstones(ts: Tombstones, k: int) -> Tombstones:
    """Shift a tombstone buffer past a fold of the ``k`` oldest deltas.

    A tombstone with epoch ``e`` hides layers ``0..e``.  After the fold,
    layers ``0..k`` are one new base with the masking already applied:
    *effective* tombstones with ``e <= k`` are spent (and MUST be
    discarded — kept, they would wrongly hide folded rows of later
    epochs), tombstones with ``e > k`` keep hiding the surviving deltas
    at ``e - k``.  TTL entries still **pending** at the current clock
    (``now < expires``) were NOT applied by the fold (they masked
    nothing — ``index()`` resolves them to epoch ``-1``), so they must
    survive regardless of their stamped epoch: a pending entry with
    ``e <= k`` now guards rows living in the folded base and is clamped
    to epoch ``0``.  Survivors are repacked to the front so ``push``
    keeps appending densely; the overflow tally and the clock are
    preserved (lost deletes stay lost until a caller decides to trust a
    full rebuild).  Pure and traceable.
    """
    spent = ts.now >= ts.expires  # effective (delete or expired TTL)
    keep = (ts.epochs > k) | ((ts.epochs >= 0) & ~spent)
    order = jnp.argsort(~keep, stable=True)  # survivors first
    kept = keep[order]
    keys = ts.keys[order]
    kept_b = kept[:, None] if keys.ndim == 2 else kept
    new_epochs = jnp.maximum(ts.epochs[order] - k, jnp.int32(0))
    return Tombstones(
        keys=jnp.where(kept_b, keys, jnp.uint32(EMPTY_KEY)),
        epochs=jnp.where(kept, new_epochs, jnp.int32(-1)),
        expires=jnp.where(kept, ts.expires[order], jnp.int32(0)),
        count=jnp.sum(keep).astype(jnp.int32),
        num_dropped=ts.num_dropped,
        now=ts.now,
    )


@partial(jax.jit, static_argnums=(0,), static_argnames=("k",))
def exec_fold(table, state: TableState, *, k: int):
    """Jitted layer-local fold: ``(new_base, remapped_tombstones)``.

    Collective-free by construction (``fold_layers_local`` never leaves
    the device) — the property the serving smoke test asserts on this
    executor's jaxpr.
    """

    def body(st):
        new_base = multi_hashgraph.fold_layers_local(
            st.layers[: k + 1], tombstones=st.tombstones.index()
        )
        return new_base, _remap_tombstones(st.tombstones, k)

    return shard_map(
        body,
        mesh=table.mesh,
        in_specs=(plans.state_specs(state),),
        out_specs=(
            plans.dhg_specs(state.base),
            Tombstones(
                keys=P(), epochs=P(), expires=P(),
                count=P(), num_dropped=P(), now=P(),
            ),
        ),
        check_vma=False,
    )(state)


def fold_oldest(state: TableState, k: int, *, metrics=None) -> TableState:
    """Merge the ``k`` oldest delta layers into the base; keep the rest.

    ``metrics`` (a :class:`~repro.obs.registry.MetricsRegistry`) records
    the fold's pause time and reclaimed rows via :func:`record_fold` —
    for *direct* callers only; the server and cache drivers time their
    folds themselves and must not also pass a registry here.

    The incremental counterpart of ``state.compact()``: the new state has
    ``depth - k`` deltas, the surviving tombstones shifted down ``k``
    epochs, and answers every query identically (oracle-tested against the
    full compaction).  On a coherent stack the fold is layer-local — zero
    collective rounds, pause proportional to the folded layers only — so a
    server can run it against a shadow state while readers keep hitting
    the previous snapshot.

    The folded base's row allocation grows by the folded deltas' rows
    (tombstoned rows become sentinels but keep their slots); a periodic
    full ``compact()`` (live-count sized) re-flattens it.  Mixed-split
    (incoherent) stacks cannot fold locally and fall back to the full
    ``compact()``.  ``k <= 0`` is the identity; ``k`` is clamped to the
    delta depth.
    """
    k = min(int(k), len(state.deltas))
    if k <= 0:
        return state
    table = state.table
    t0 = time.perf_counter()
    rows_before = allocated_rows(state)
    if not state.coherent:
        out = table.compact(state)
        record_fold(
            metrics,
            kind="full",
            seconds=time.perf_counter() - t0,
            rows_before=rows_before,
            rows_after=allocated_rows(out),
        )
        return out
    new_base, new_ts = exec_fold(table, state, k=k)
    out = TableState(
        base=new_base,
        deltas=state.deltas[k:],
        tombstones=new_ts,
        table=table,
        coherent=True,
    )
    record_fold(
        metrics,
        kind="fold",
        seconds=time.perf_counter() - t0,
        rows_before=rows_before,
        rows_after=allocated_rows(out),
    )
    return out
