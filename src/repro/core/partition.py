"""Phase 1 of Alg. 2 — global binning and balanced hash-range partitioning.

Each device histograms its local keys into ``BINS_G`` coarse bins over the
global hash range, the histograms are ``psum``-reduced across the device
axis, and the global CDF is searched for split points so each device owns a
contiguous hash range holding ≈ ``N / DEVICES`` keys (paper §3.3 Phase 1).

Differences from the CUDA version (DESIGN.md §2):

* the histogram increment is a deterministic XLA scatter-add (the Pallas
  kernel in ``repro.kernels.histogram`` provides the VPU compare-tile
  version for the hot path);
* ``Reduce``/``BCast`` over PCIe (Alg. 2 lines 10/16) collapse into a single
  ``psum`` — under SPMD every device computes identical split points from
  the reduced histogram, so no broadcast is needed;
* the binary search is ``jnp.searchsorted`` instead of a host-side search.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.utils import cdiv


def choose_num_bins(hash_range: int, num_devices: int, align: int = 128) -> int:
    """Paper's guidance: ``BINS_G = O(sqrt(HR))``, with ``BINS_G > DEVICES``.

    Rounded to a multiple of ``align`` (lane width) for the histogram kernel.
    """
    raw = int(math.isqrt(max(1, hash_range)))
    raw = max(raw, 4 * num_devices, align)
    raw = min(raw, hash_range)  # never more bins than hash values
    return cdiv(raw, align) * align


def bin_size_for(hash_range: int, num_bins: int) -> int:
    return cdiv(hash_range, num_bins)


def local_bin_histogram(
    buckets: jax.Array, num_bins: int, hash_range: int, valid: jax.Array = None
) -> jax.Array:
    """Histogram of hash values into ``num_bins`` coarse bins (Alg. 2 l.6-8).

    ``valid`` masks rows out of the count (padding sentinels in a compaction
    rebuild must not skew the balanced splits).
    """
    bsz = bin_size_for(hash_range, num_bins)
    bins = (buckets.astype(jnp.int32) // jnp.int32(bsz)).clip(0, num_bins - 1)
    weights = (
        jnp.ones(bins.shape, jnp.int32)
        if valid is None
        else valid.astype(jnp.int32)
    )
    return jnp.zeros((num_bins,), jnp.int32).at[bins].add(weights)


def _balanced_targets(total: jax.Array, num_devices: int) -> jax.Array:
    """``floor(d * total / DEVICES)`` for d = 1..DEVICES-1 without overflow.

    ``d * total`` can exceed int32; decompose ``total = q*D + r`` so every
    intermediate stays below ``2^31`` (d, r < DEVICES <= 4096).
    """
    d = jnp.arange(1, num_devices, dtype=jnp.int32)
    q = total // num_devices
    r = total % num_devices
    return d * q + (d * r) // num_devices


def balanced_hash_splits(
    global_hist: jax.Array, num_devices: int, hash_range: int
) -> jax.Array:
    """Split the hash range so each device receives ≈ N/DEVICES keys.

    Returns ``splits`` of shape ``(DEVICES + 1,)`` with ``splits[0] == 0`` and
    ``splits[-1] == hash_range``; device ``d`` owns hash values in
    ``[splits[d], splits[d+1])``.  Splits land on bin boundaries (the paper's
    ``BinSplits``), which is what makes the coarse histogram sufficient.
    """
    num_bins = global_hist.shape[0]
    bsz = bin_size_for(hash_range, num_bins)
    prefix = jnp.cumsum(global_hist.astype(jnp.int32))  # inclusive CDF
    total = prefix[-1]
    targets = _balanced_targets(total, num_devices)
    # First bin index whose inclusive CDF reaches the target → device boundary
    # is the *end* of that bin.
    split_bins = jnp.searchsorted(prefix, targets, side="left").astype(jnp.int32) + 1
    # bin_index * bin_size can slightly exceed int32 when HR ~ 2^31; the true
    # value always fits uint32, so compute there and clamp before casting back.
    prod = split_bins.astype(jnp.uint32) * jnp.uint32(bsz)
    hash_splits = jnp.minimum(prod, jnp.uint32(hash_range)).astype(jnp.int32)
    # Monotone repair under extreme skew (empty devices allowed).
    hash_splits = jax.lax.cummax(hash_splits)
    zero = jnp.zeros((1,), jnp.int32)
    top = jnp.full((1,), hash_range, jnp.int32)
    return jnp.concatenate([zero, hash_splits, top])


def destination_of(buckets: jax.Array, hash_splits: jax.Array) -> jax.Array:
    """Owning device of each hash value (Alg. 2 ``Search``, vectorized).

    The paper uses a linear search over split points (O(P) work per key);
    ``searchsorted`` is the log(P) equivalent with identical output.
    """
    d = jnp.searchsorted(hash_splits, buckets.astype(jnp.int32), side="right") - 1
    return jnp.clip(d, 0, hash_splits.shape[0] - 2).astype(jnp.int32)
