"""Serving layer: sharded KV-cache steps and a continuous batcher."""
from repro.serve.engine import make_prefill_step, make_serve_step, ServeMesh
from repro.serve.batcher import ContinuousBatcher, Request

__all__ = [
    "make_prefill_step",
    "make_serve_step",
    "ServeMesh",
    "ContinuousBatcher",
    "Request",
]
