"""Jitted prefill/decode steps with mesh-aware cache sharding.

``serve_step`` is the function the decode shape-cells lower: ONE new token
per sequence against a ``seq_len``-sized KV cache.  Cache shardings come
from ``repro.distributed.sharding.cache_pspecs``: batch on the dp axes and
heads on ``model`` when ``kv_heads % tp == 0``; otherwise the cache is
**sequence-sharded** over ``model`` and XLA's partitioner turns the
attention contraction into partial-softmax combines (flash-decode style) —
required for kv_heads=1 archs (granite, recurrentgemma).

Cache buffers are donated, so decode is in-place at steady state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.distributed import sharding as shd
from repro.models.api import ModelBundle


@dataclasses.dataclass(frozen=True)
class ServeMesh:
    """Shardings for one (bundle × batch × cache_len) serving configuration."""

    params: Any
    caches: Any
    token: Any
    pos: Any
    logits: Any

    @staticmethod
    def build(bundle: ModelBundle, batch: int, cache_len: int) -> "ServeMesh":
        parallel = bundle.parallel
        mesh = parallel.mesh
        pshapes = bundle.param_shapes()
        pspecs = shd.param_pspecs(pshapes, parallel)
        cache_shapes = jax.eval_shape(lambda: bundle.init_cache(batch, cache_len))
        cspecs = shd.cache_pspecs(cache_shapes, parallel)
        return ServeMesh(
            params=shd.to_named(mesh, pspecs),
            caches=shd.to_named(mesh, cspecs),
            token=NamedSharding(mesh, shd.batch_pspec(2, parallel)),
            pos=NamedSharding(mesh, shd.batch_pspec(1, parallel)),
            logits=NamedSharding(mesh, shd.batch_pspec(2, parallel)),
        )


def serving_compute_copy(params):
    """bf16 view of f32 master weights for inference paths.

    Weight all-gathers (FSDP dims) then move bf16 on the wire — measured
    2× on the prefill collective term (§Perf iter 8).  Matrices only; norm
    vectors stay f32.
    """
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if p.dtype == jnp.float32 and p.ndim >= 2
        else p,
        params,
    )


def make_prefill_step(bundle: ModelBundle, cache_len: Optional[int] = None):
    """jit'd prefill: batch dict → (last-token logits, caches)."""

    def prefill(params, batch):
        return bundle.prefill(serving_compute_copy(params), batch, cache_len=cache_len)

    return jax.jit(prefill)


def make_serve_step(bundle: ModelBundle, donate: bool = True):
    """jit'd single-token decode: (params, caches, token, pos) → (logits, caches)."""

    def serve_step(params, caches, token, pos):
        return bundle.decode_step(params, caches, token, pos)

    return jax.jit(serve_step, donate_argnums=(1,) if donate else ())


def make_sharded_serve_step(bundle: ModelBundle, batch: int, cache_len: int):
    """serve_step with explicit in/out shardings for the production mesh."""
    sm = ServeMesh.build(bundle, batch, cache_len)

    def serve_step(params, caches, token, pos):
        return bundle.decode_step(params, caches, token, pos)

    return (
        jax.jit(
            serve_step,
            in_shardings=(sm.params, sm.caches, sm.token, sm.pos),
            out_shardings=(sm.logits, sm.caches),
            donate_argnums=(1,),
        ),
        sm,
    )
