"""Continuous batching over a fixed slot grid.

The engine keeps ``num_slots`` decode lanes hot; finished/empty lanes are
refilled from the request queue between decode steps (prefill writes the
new sequence's KV into the lane's cache region).  All jitted shapes are
static — admission is pure host-side bookkeeping, the standard
continuous-batching design (vLLM-style, minus paging: lanes own fixed
cache windows).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (L,) int32
    max_new_tokens: int
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Drives (prefill_fn, decode_fn) over a slot grid.

    ``prefill_fn(params, tokens (1, L)) -> (logits (1, V), caches_for_one)``
    ``decode_fn(params, caches, token (B,1), pos (B,)) -> (logits, caches)``

    The batcher owns the batched cache pytree; per-slot prefill caches are
    scattered into slot ``i`` with ``lax.dynamic_update_index_in_dim``.
    """

    def __init__(
        self,
        params,
        init_caches,  # batched cache pytree for num_slots lanes
        prefill_fn: Callable,
        decode_fn: Callable,
        num_slots: int,
        eos_id: int = -1,
        greedy: bool = True,
    ):
        self.params = params
        self.caches = init_caches
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.num_slots = num_slots
        self.eos_id = eos_id
        self.greedy = greedy
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.pos = np.zeros((num_slots,), np.int32)
        self.next_token = np.zeros((num_slots,), np.int32)
        self.completed: list[Request] = []

    # -- admission ---------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.num_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                logits, one_cache = self.prefill_fn(
                    self.params, {"tokens": jnp.asarray(req.prompt[None, :])}
                )
                tok = int(jnp.argmax(logits[-1] if logits.ndim == 1 else logits[0]))
                req.out_tokens.append(tok)
                self.caches = _write_slot(self.caches, one_cache, i)
                self.slots[i] = req
                self.pos[i] = len(req.prompt)
                self.next_token[i] = tok

    # -- decode loop --------------------------------------------------------------
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def step(self) -> None:
        """Admit, then decode one token for every live lane."""
        self._admit()
        if self.active() == 0:
            return
        token = jnp.asarray(self.next_token[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.caches = self.decode_fn(self.params, self.caches, token, pos)
        new = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            tok = int(new[i])
            req.out_tokens.append(tok)
            self.pos[i] += 1
            self.next_token[i] = tok
            if tok == self.eos_id or len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.completed.append(req)
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.active()) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed


def _write_slot(batched_caches, one_cache, slot: int):
    """Scatter a single-sequence cache pytree into slot ``slot``.

    Cache leaves are scanned stacks ``(num_periods, B, ...)`` — the batch
    dim is axis 1.
    """

    def f(dst, src):
        if dst.ndim < 2:
            return dst
        return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0], slot, axis=1)

    return jax.tree.map(f, batched_caches, one_cache)
