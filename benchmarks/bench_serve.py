"""Serving benchmark — request-stream throughput, tail latency, fold pauses.

Three measurements of the serve_table engine:

1. **Request stream vs batching window**: a stream of ragged query
   requests runs through the :class:`MicroBatcher` at several coalescing
   windows (requests per fused execution).  Larger windows amortize the
   executor launch over more requests (throughput up) but every request
   in a batch waits for the whole flush (latency up) — the knob the
   README's serving section documents.  Reported: keys/sec, request p50
   and p99 latency per window.
2. **Fold vs full compact pause**: the maintenance pause a background
   thread pays on a delta-deep state — incremental
   ``fold_oldest(state, k)`` (layer-local, zero collectives) against the
   full live-count-sized ``compact()``.
3. **``--smoke``** (CI): a server applies a mixed insert/delete stream,
   then runs a background fold while the main thread keeps reading.  The
   step *asserts* zero read-path stalls: reads issued during the fold
   complete against the pre-fold seqno, at least one lands while the fold
   is in flight, and no during-fold read takes as long as the fold itself
   (reads never waited on it).  A torn read, a blocked read path, or a
   missing publish fails CI loudly.
4. **``--open-loop``**: the async front end under open-loop Poisson
   arrivals.  The server is AOT-warmed (:func:`repro.serve_table.warm_server`)
   and then offered a configurable request rate; latency is measured from
   the *intended* arrival instant to future resolution, so queueing and
   admission delay count against the server, not the generator.  Reported
   per offered rate: p50/p99/p999 latency, goodput (responses inside the
   ``--slo-ms`` budget per second), and the traced per-phase breakdown
   (admission/linger/dispatch/device/scatter) out of the observability
   registry.  Each rate also runs a **tracing-overhead control pair**: a
   read-only stream with tracing disabled vs enabled, on frozen table
   geometry, isolating what the span bookkeeping itself costs (under
   ``--smoke`` each mode runs interleaved repeats and scores its best
   p99 — single-run tails on a 1-core CI box are scheduler noise).  With
   ``--smoke`` the mixed stream (writes + a policy-triggered fold through
   the front end) additionally *asserts*, by scraping the rendered
   Prometheus export the way an external monitor would: zero live traces,
   zero dropped rows, zero AOT misses and a flat jit dispatch cache
   (:func:`benchmarks.common.assert_clean_run`), the fused two-all-to-all
   budget on every profiled executor, a generous p99 bound, and < 5%
   tracing overhead on the control pair.
"""
import argparse
import threading
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=256, help="stream length")
    ap.add_argument("--req-min", type=int, default=4)
    ap.add_argument("--req-max", type=int, default=256)
    ap.add_argument("--windows", type=str, default="1,4,16,64")
    ap.add_argument("--depth", type=int, default=8, help="deltas for the fold bench")
    ap.add_argument("--fold-k", type=int, default=2)
    ap.add_argument("--smoke", action="store_true", help="CI no-stall assertion run")
    ap.add_argument("--json", type=str, default=None)
    ap.add_argument(
        "--open-loop",
        action="store_true",
        help="async front end under Poisson arrivals (only this part runs)",
    )
    ap.add_argument("--rates", type=str, default="100,400,1600", help="req/s sweep")
    ap.add_argument("--duration", type=float, default=2.0, help="seconds per rate")
    ap.add_argument("--req-keys", type=int, default=8, help="keys per request")
    ap.add_argument("--slo-ms", type=float, default=50.0, help="goodput latency budget")
    args = ap.parse_args()

    if args.open_loop:
        _open_loop(args)
        return

    if args.smoke:
        args.keys = min(args.keys, 1 << 13)
        args.requests = min(args.requests, 64)
        args.req_max = min(args.req_max, 64)
        args.windows = "1,8"
        args.depth = 4

    import jax
    import numpy as np

    from benchmarks.common import emit, time_fn, write_bench_json
    from repro.core import maintenance
    from repro.core.table import DistributedHashTable
    from repro.serve_table import CompactionPolicy, MicroBatcher, TableServer

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(11)
    keys = rng.integers(0, n, size=n, dtype=np.uint32)
    vals = np.arange(n, dtype=np.int32)

    rows = []

    # ---- 1. request stream: throughput + latency vs batching window --------
    table = DistributedHashTable(mesh, ("d",), hash_range=n, capacity_slack=2.0)
    state = table.init(jax.numpy.asarray(keys), jax.numpy.asarray(vals))
    sizes = rng.integers(args.req_min, args.req_max + 1, size=args.requests)
    stream = [rng.choice(keys, size=s).astype(np.uint32) for s in sizes]
    total_keys = int(sizes.sum())

    for window in [int(w) for w in args.windows.split(",")]:
        batcher = MicroBatcher(table)
        # warmup pass populates the plan caches (compiles excluded from the
        # serving numbers, as in steady traffic)
        for i in range(0, len(stream), window):
            batcher.query_many(state, stream[i : i + window])
        lat = []
        t_all0 = time.perf_counter()
        for i in range(0, len(stream), window):
            t0 = time.perf_counter()
            batcher.query_many(state, stream[i : i + window])
            dt = time.perf_counter() - t0
            lat.extend([dt] * len(stream[i : i + window]))
        total_sec = time.perf_counter() - t_all0
        st = batcher.stats()
        row = {
            "part": "stream",
            "window": window,
            "keys_per_sec": total_keys / total_sec,
            "requests_per_sec": len(stream) / total_sec,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "pad_fraction": st.pad_fraction,
            "cache_hit_rate": st.cache_hits / max(1, st.cache_hits + st.cache_misses),
        }
        rows.append(row)
        emit(
            "serve_stream",
            total_sec,
            window=window,
            keys_per_sec=f"{row['keys_per_sec']:.3e}",
            p50_ms=f"{row['p50_ms']:.3f}",
            p99_ms=f"{row['p99_ms']:.3f}",
            pad_fraction=f"{row['pad_fraction']:.3f}",
        )

    # ---- 2. fold_oldest vs full compact pause -------------------------------
    deep = table.init(jax.numpy.asarray(keys), jax.numpy.asarray(vals))
    batch = max(d * 8, min(1 << 10, n // 8))
    for _ in range(args.depth):
        deep = deep.insert(
            jax.numpy.asarray(rng.integers(0, n, size=batch, dtype=np.uint32)),
            jax.numpy.asarray(np.arange(batch, dtype=np.int32)),
        )
    deep = deep.delete(jax.numpy.asarray(keys[:64]))

    sec_fold = time_fn(
        lambda: maintenance.fold_oldest(deep, args.fold_k), iters=3
    )
    sec_full = time_fn(lambda: deep.compact(), iters=3)
    rows.append(
        {
            "part": "fold",
            "depth": args.depth,
            "fold_k": args.fold_k,
            "fold_sec": sec_fold,
            "full_compact_sec": sec_full,
            "pause_ratio": sec_fold / sec_full,
        }
    )
    emit(
        "serve_fold",
        sec_fold,
        depth=args.depth,
        fold_k=args.fold_k,
        full_compact_sec=f"{sec_full:.6f}",
        pause_ratio=f"{sec_fold / sec_full:.3f}",
    )

    # ---- 3. smoke: background fold must not stall reads ---------------------
    server = None
    if args.smoke:
        policy = CompactionPolicy(max_delta_depth=64, fold_k=2)  # manual folds
        server = TableServer(table, keys, vals, policy=policy)
        oracle_keys = keys[:32]
        for _ in range(args.depth):
            server.submit_insert(
                rng.integers(0, n, size=batch, dtype=np.uint32),
                np.arange(batch, dtype=np.int32),
            )
        server.submit_delete(keys[n - 64 :])
        server.drain()
        want = np.asarray(server.query_many([oracle_keys])[0][0])

        # warm both read depths so the during-fold loop measures serving,
        # not compilation: current depth, and depth - fold_k (post-fold)
        post = maintenance.fold_oldest(server.current().state, 2)
        server.batcher.query_many(post, [oracle_keys])

        # Up to 3 attempts guard against two benign timing flukes: a fast
        # fold landing before the first read can be issued (nothing to
        # observe), and a GIL-contended single read outlasting a warm fold
        # (stall >= fold_sec without the read path actually blocking).
        # Each retry restores the folded depth with two fresh inserts.
        for attempt in range(3):
            pre_seq = server.current().seqno
            t0 = time.perf_counter()
            t = server.fold_async(k=2)
            reads_during = 0
            stall = 0.0
            while t.is_alive():
                r0 = time.perf_counter()
                counts, seq = server.query_many([oracle_keys])
                dt = time.perf_counter() - r0
                assert seq == pre_seq, (
                    f"torn read: seqno {seq} during fold of {pre_seq}"
                )
                np.testing.assert_array_equal(np.asarray(counts[0]), want)
                reads_during += 1
                stall = max(stall, dt)
            t.join()
            fold_sec = time.perf_counter() - t0
            assert server.current().seqno == pre_seq + 1, "fold did not publish"
            counts, seq = server.query_many([oracle_keys])
            assert seq == pre_seq + 1
            np.testing.assert_array_equal(np.asarray(counts[0]), want)
            if reads_during >= 1 and stall < fold_sec:
                break
            for _ in range(2):  # restore depth for the retry
                server.submit_insert(
                    rng.integers(0, n, size=batch, dtype=np.uint32),
                    np.arange(batch, dtype=np.int32),
                )
            server.drain()
            want = np.asarray(server.query_many([oracle_keys])[0][0])
        assert reads_during >= 1, "no read completed while the fold was in flight"
        assert stall < fold_sec, (
            f"a read ({stall:.3f}s) waited as long as the fold ({fold_sec:.3f}s) "
            "on every attempt: the read path blocked on compaction"
        )
        print(
            f"smoke: {reads_during} reads served during a {fold_sec * 1e3:.0f}ms "
            f"background fold (max read {stall * 1e3:.1f}ms), all at seqno "
            f"{pre_seq}, fold published {pre_seq + 1}; zero read-path stalls"
        )

    if args.json:
        write_bench_json(
            args.json,
            "serve",
            rows,
            snapshot=server.metrics() if server is not None else None,
            devices=d,
            keys=n,
        )


def _open_loop(args) -> None:
    """Async front end under open-loop Poisson arrivals (see module doc, part 4)."""
    import jax
    import numpy as np

    from benchmarks.common import assert_clean_run, emit, write_bench_json
    from repro.core import plans
    from repro.core.table import DistributedHashTable
    from repro.obs import parse_prometheus, render_prometheus
    from repro.obs.registry import HistogramSnapshot
    from repro.obs.tracing import PHASES
    from repro.serve_table import (
        AsyncFrontend,
        CompactionPolicy,
        MicroBatcher,
        TableServer,
    )

    if args.smoke:
        # Rate sized for a single-core worst case: ~4ms/fused exec on one
        # CPU core caps a flush_keys=16 front end near 250 req/s, so 100/s
        # keeps utilization < 50% and the p99 bound meaningful (a retrace
        # costs ~seconds and blows it regardless of queueing noise).
        args.keys = min(args.keys, 1 << 13)
        args.rates = "100"
        args.duration = min(args.duration, 1.5)

    d = len(jax.devices())
    n = args.keys
    rng = np.random.default_rng(23)
    seed_keys = rng.integers(0, n, size=n, dtype=np.uint32)
    seed_vals = np.arange(n, dtype=np.int32)

    write_bucket = max(8, d)
    table = DistributedHashTable(
        jax.make_mesh((d,), ("d",)),
        ("d",),
        hash_range=n,
        capacity_slack=2.0,
        max_deltas=4,
        tombstone_capacity=max(256, 4 * write_bucket),
    )
    policy = CompactionPolicy(max_delta_depth=2, fold_k=1, tombstone_load=0.9)
    server = TableServer(
        table,
        seed_keys,
        seed_vals,
        policy=policy,
        batcher=MicroBatcher(table, min_bucket=write_bucket),
        write_bucket=write_bucket,
    )
    flush_keys = 2 * write_bucket if args.smoke else 8 * write_bucket
    warm_buckets = tuple(
        write_bucket << i for i in range((flush_keys // write_bucket).bit_length())
    )
    warm = server.warm(buckets=warm_buckets, depths=(0, 1, 2), fold_horizon=2)
    emit(
        "serve_async_warmup",
        warm.compile_seconds,
        entries=warm.entries,
        buckets=",".join(str(b) for b in warm_buckets),
        fold_horizon=warm.fold_horizon,
    )
    cache_size = getattr(plans.exec_query, "_cache_size", None)

    rows = [
        {
            "part": "open_loop_warmup",
            "entries": warm.entries,
            "compile_seconds": warm.compile_seconds,
            "buckets": list(warm.buckets),
            "depths": list(warm.depths),
            "fold_horizon": warm.fold_horizon,
        }
    ]

    # Per-executor device-cost profiles out of the warmup's jaxpr walk —
    # the per-artifact record that the routing stayed inside the paper's
    # two-all-to-all budget at every warmed delta depth.
    profiles = server.batcher.executors.cost_profile()
    for p in profiles:
        rows.append({"part": "executor_cost", **p.as_dict()})
        emit(
            "serve_async_executor_cost",
            0.0,
            kind=p.kind,
            bucket=p.bucket,
            depth=p.depth,
            all_to_alls=p.all_to_alls,
            collective_bytes=p.total_collective_bytes,
        )
    if args.smoke:
        assert profiles, "warmup produced no executor cost profiles"
        for p in profiles:
            assert p.all_to_alls == 2, (
                f"{p.kind} executor (bucket {p.bucket}, depth {p.depth}) uses "
                f"{p.all_to_alls} all-to-alls — fused 2-round budget broken"
            )

    def drive(fe, rate: float, duration: float, write_ops: dict):
        """One open-loop stream; returns (lat, failures, submitted, wall)."""
        lat: list = []
        failures: list = []
        done_lock = threading.Lock()
        t0 = time.perf_counter()
        next_t = t0
        submitted = 0
        while next_t - t0 < duration:
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            op = write_ops.get(submitted)
            if op is not None:
                (fe.submit_insert if op[0] == "insert" else fe.submit_delete)(
                    op[1], timeout=30.0
                )
            q = rng.choice(seed_keys, size=args.req_keys).astype(np.uint32)
            t_arr = next_t  # intended arrival: open-loop latency epoch

            def _done(fut, t=t_arr):
                dt = time.perf_counter() - t
                with done_lock:
                    if fut.exception() is None:
                        lat.append(dt)
                    else:
                        failures.append(fut.exception())

            fe.submit_query(q, timeout=30.0).add_done_callback(_done)
            submitted += 1
            next_t += rng.exponential(1.0 / rate)
        deadline = time.perf_counter() + 60.0
        while True:
            with done_lock:
                if len(lat) + len(failures) >= submitted:
                    break
            if time.perf_counter() > deadline:
                raise TimeoutError(
                    f"open loop: {submitted - len(lat) - len(failures)} "
                    "responses never resolved"
                )
            time.sleep(0.002)
        server.drain(timeout=60.0)
        return lat, failures, submitted, time.perf_counter() - t0

    def phase_breakdown(before, after) -> dict:
        """Per-phase latency stats from the delta of two registry snapshots."""
        out = {}
        for phase in PHASES:
            a = after.histogram("trace_phase_seconds", {"phase": phase})
            if a is None:
                continue
            b = before.histogram("trace_phase_seconds", {"phase": phase})
            if b is not None and b.count:
                a = HistogramSnapshot(
                    count=a.count - b.count,
                    sum=a.sum - b.sum,
                    min=a.min,
                    max=a.max,
                    bounds=a.bounds,
                    counts=tuple(x - y for x, y in zip(a.counts, b.counts)),
                )
            if not a.count:
                continue
            out[phase] = {
                "count": a.count,
                "mean_ms": a.mean * 1e3,
                "p50_ms": a.p50 * 1e3,
                "p99_ms": a.p99 * 1e3,
            }
        return out

    slo = args.slo_ms / 1e3
    for rate in [float(r) for r in args.rates.split(",")]:
        expected = max(1, int(rate * args.duration))
        # Mixed stream (smoke): writes + a policy-triggered incremental fold
        # land mid-stream through the front end — same op sequence the
        # no-retrace regression test pins down, all inside the warmed grid.
        write_ops = {}
        if args.smoke:
            fresh = rng.integers(n, 2 * n, size=4 * write_bucket, dtype=np.uint32)
            ins = [
                fresh[i * write_bucket : (i + 1) * write_bucket] for i in range(4)
            ]
            write_ops = {
                max(1, expected // 5): ("insert", ins[0]),
                max(2, 2 * expected // 5): ("insert", ins[1]),
                max(3, 3 * expected // 5): ("delete", ins[0][: write_bucket // 2]),
                max(4, 4 * expected // 5): ("insert", ins[2]),
            }

        cache0 = cache_size() if cache_size else None
        snap_before = server.metrics(refresh=False)
        with AsyncFrontend(
            server,
            linger=0.002,
            flush_keys=flush_keys,
            default_deadline=slo,
            write_backlog=32,
        ) as fe:
            lat, failures, submitted, wall = drive(
                fe, rate, args.duration, write_ops
            )
        fe.metrics()  # refresh trace_live / queue-depth gauges post-drain
        snap = server.metrics()  # ONE atomic sample, state gauges refreshed
        st = fe.stats(snapshot=snap)
        wstats = server.stats()
        row = {
            "part": "open_loop",
            "rate_offered": rate,
            "req_keys": args.req_keys,
            "submitted": submitted,
            "completed": len(lat),
            "failed": len(failures),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "p999_ms": float(np.percentile(lat, 99.9) * 1e3),
            "goodput_rps": sum(1 for x in lat if x <= slo) / wall,
            "slo_ms": args.slo_ms,
            "batches_fill": st.batches_fill,
            "batches_due": st.batches_due,
            "aot_hits": wstats.warmup.aot_hits,
            "aot_misses": wstats.warmup.aot_misses,
            "phases": phase_breakdown(snap_before, snap),
        }
        rows.append(row)
        emit(
            "serve_async_open_loop",
            wall,
            rate=rate,
            p50_ms=f"{row['p50_ms']:.3f}",
            p99_ms=f"{row['p99_ms']:.3f}",
            p999_ms=f"{row['p999_ms']:.3f}",
            goodput_rps=f"{row['goodput_rps']:.1f}",
            aot_misses=row["aot_misses"],
        )

        if args.smoke:
            assert not failures, f"{len(failures)} requests failed: {failures[:3]}"
            assert len(lat) == submitted, "lost responses"
            # The shared smoke gate off one snapshot (AOT misses, dropped
            # rows, skew fallbacks, failed requests, live traces, flat jit
            # cache) ...
            assert_clean_run(
                snap, baseline_cache_size=cache0, context=f"rate {rate:.0f}"
            )
            # ... re-asserted through the scrape path an external monitor
            # would use: render the Prometheus text and parse it back.
            scraped = parse_prometheus(render_prometheus(snap))
            assert scraped.get(("trace_live", ()), 0) == 0, (
                "Prometheus export shows live traces after drain"
            )
            assert scraped.get(("serve_dropped_rows", ()), 0) == 0, (
                "Prometheus export shows dropped rows"
            )
            assert scraped.get(("aot_misses_total", ()), 0) == 0, (
                "Prometheus export shows AOT misses"
            )
            assert wstats.folds >= 1, "mixed stream never triggered a fold"
            assert row["p99_ms"] < 500.0, (
                f"p99 {row['p99_ms']:.1f}ms over the smoke bound (500ms): "
                "retrace or read-path stall"
            )
            print(
                f"open-loop smoke: {submitted} requests at {rate:.0f}/s, "
                f"p99 {row['p99_ms']:.1f}ms, {wstats.folds} fold(s), "
                f"0 traces after warmup ({wstats.warmup.aot_hits} AOT hits)"
            )

        # ---- tracing-overhead control pair (read-only, frozen geometry) ----
        # No writes, no folds: both runs serve identical warmed executors,
        # so the only difference is the span bookkeeping itself.  A single
        # run's p99 on a 1-core CI box is scheduler-noise-dominated (the
        # fake 8-device mesh time-slices one core), so each mode runs
        # ``repeats`` times interleaved (control, traced, control, ...) and
        # scores its *best* p99 — the run least disturbed by the scheduler,
        # which is the one that isolates the bookkeeping cost.
        repeats = 3 if args.smoke else 1
        ro = {"control": [], "traced": []}
        for _ in range(repeats):
            for mode, tracing in (("control", False), ("traced", True)):
                with AsyncFrontend(
                    server,
                    linger=0.002,
                    flush_keys=flush_keys,
                    default_deadline=slo,
                    write_backlog=32,
                    tracing=tracing,
                ) as fe2:
                    run = drive(fe2, rate, args.duration, {})
                assert not run[1], f"{mode} run had failures: {run[1][:3]}"
                ro[mode].append(run)

        def best(mode, q):
            return min(
                float(np.percentile(run[0], q) * 1e3) for run in ro[mode]
            )

        c_p50, c_p99 = best("control", 50), best("control", 99)
        t_p50, t_p99 = best("traced", 50), best("traced", 99)
        row2 = {
            "part": "tracing_overhead",
            "rate_offered": rate,
            "control_p50_ms": c_p50,
            "control_p99_ms": c_p99,
            "traced_p50_ms": t_p50,
            "traced_p99_ms": t_p99,
            "overhead_p99_pct": (t_p99 / c_p99 - 1.0) * 100.0 if c_p99 else 0.0,
        }
        rows.append(row2)
        emit(
            "serve_async_tracing_overhead",
            ro["traced"][-1][3],
            rate=rate,
            control_p99_ms=f"{c_p99:.3f}",
            traced_p99_ms=f"{t_p99:.3f}",
            overhead_p99_pct=f"{row2['overhead_p99_pct']:.2f}",
        )
        if args.smoke:
            # < 5% p99 regression, with a 2ms absolute floor so scheduler
            # noise on a 1-core CI box can't fail a microsecond-level cost.
            assert t_p99 <= c_p99 * 1.05 + 2.0, (
                f"tracing overhead too high: p99 {c_p99:.2f}ms -> {t_p99:.2f}ms "
                f"({row2['overhead_p99_pct']:.1f}%, budget 5% + 2ms)"
            )
            print(
                f"tracing overhead: p99 {c_p99:.2f}ms untraced -> {t_p99:.2f}ms "
                f"traced ({row2['overhead_p99_pct']:+.1f}%)"
            )

    if args.json:
        write_bench_json(
            args.json,
            "serve_async",
            rows,
            snapshot=server.metrics(),
            devices=d,
            keys=n,
            slo_ms=args.slo_ms,
        )


if __name__ == "__main__":
    main()
