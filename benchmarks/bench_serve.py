"""Serving benchmark — request-stream throughput, tail latency, fold pauses.

Three measurements of the serve_table engine:

1. **Request stream vs batching window**: a stream of ragged query
   requests runs through the :class:`MicroBatcher` at several coalescing
   windows (requests per fused execution).  Larger windows amortize the
   executor launch over more requests (throughput up) but every request
   in a batch waits for the whole flush (latency up) — the knob the
   README's serving section documents.  Reported: keys/sec, request p50
   and p99 latency per window.
2. **Fold vs full compact pause**: the maintenance pause a background
   thread pays on a delta-deep state — incremental
   ``fold_oldest(state, k)`` (layer-local, zero collectives) against the
   full live-count-sized ``compact()``.
3. **``--smoke``** (CI): a server applies a mixed insert/delete stream,
   then runs a background fold while the main thread keeps reading.  The
   step *asserts* zero read-path stalls: reads issued during the fold
   complete against the pre-fold seqno, at least one lands while the fold
   is in flight, and no during-fold read takes as long as the fold itself
   (reads never waited on it).  A torn read, a blocked read path, or a
   missing publish fails CI loudly.
"""
import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 15)
    ap.add_argument("--requests", type=int, default=256, help="stream length")
    ap.add_argument("--req-min", type=int, default=4)
    ap.add_argument("--req-max", type=int, default=256)
    ap.add_argument("--windows", type=str, default="1,4,16,64")
    ap.add_argument("--depth", type=int, default=8, help="deltas for the fold bench")
    ap.add_argument("--fold-k", type=int, default=2)
    ap.add_argument("--smoke", action="store_true", help="CI no-stall assertion run")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args()

    if args.smoke:
        args.keys = min(args.keys, 1 << 13)
        args.requests = min(args.requests, 64)
        args.req_max = min(args.req_max, 64)
        args.windows = "1,8"
        args.depth = 4

    import jax
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core import maintenance
    from repro.core.table import DistributedHashTable
    from repro.serve_table import CompactionPolicy, MicroBatcher, TableServer

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(11)
    keys = rng.integers(0, n, size=n, dtype=np.uint32)
    vals = np.arange(n, dtype=np.int32)

    rows = []

    # ---- 1. request stream: throughput + latency vs batching window --------
    table = DistributedHashTable(mesh, ("d",), hash_range=n, capacity_slack=2.0)
    state = table.init(jax.numpy.asarray(keys), jax.numpy.asarray(vals))
    sizes = rng.integers(args.req_min, args.req_max + 1, size=args.requests)
    stream = [rng.choice(keys, size=s).astype(np.uint32) for s in sizes]
    total_keys = int(sizes.sum())

    for window in [int(w) for w in args.windows.split(",")]:
        batcher = MicroBatcher(table)
        # warmup pass populates the plan caches (compiles excluded from the
        # serving numbers, as in steady traffic)
        for i in range(0, len(stream), window):
            batcher.query_many(state, stream[i : i + window])
        lat = []
        t_all0 = time.perf_counter()
        for i in range(0, len(stream), window):
            t0 = time.perf_counter()
            batcher.query_many(state, stream[i : i + window])
            dt = time.perf_counter() - t0
            lat.extend([dt] * len(stream[i : i + window]))
        total_sec = time.perf_counter() - t_all0
        st = batcher.stats()
        row = {
            "part": "stream",
            "window": window,
            "keys_per_sec": total_keys / total_sec,
            "requests_per_sec": len(stream) / total_sec,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "pad_fraction": st.pad_fraction,
            "cache_hit_rate": st.cache_hits / max(1, st.cache_hits + st.cache_misses),
        }
        rows.append(row)
        emit(
            "serve_stream",
            total_sec,
            window=window,
            keys_per_sec=f"{row['keys_per_sec']:.3e}",
            p50_ms=f"{row['p50_ms']:.3f}",
            p99_ms=f"{row['p99_ms']:.3f}",
            pad_fraction=f"{row['pad_fraction']:.3f}",
        )

    # ---- 2. fold_oldest vs full compact pause -------------------------------
    deep = table.init(jax.numpy.asarray(keys), jax.numpy.asarray(vals))
    batch = max(d * 8, min(1 << 10, n // 8))
    for _ in range(args.depth):
        deep = deep.insert(
            jax.numpy.asarray(rng.integers(0, n, size=batch, dtype=np.uint32)),
            jax.numpy.asarray(np.arange(batch, dtype=np.int32)),
        )
    deep = deep.delete(jax.numpy.asarray(keys[:64]))

    sec_fold = time_fn(
        lambda: maintenance.fold_oldest(deep, args.fold_k), iters=3
    )
    sec_full = time_fn(lambda: deep.compact(), iters=3)
    rows.append(
        {
            "part": "fold",
            "depth": args.depth,
            "fold_k": args.fold_k,
            "fold_sec": sec_fold,
            "full_compact_sec": sec_full,
            "pause_ratio": sec_fold / sec_full,
        }
    )
    emit(
        "serve_fold",
        sec_fold,
        depth=args.depth,
        fold_k=args.fold_k,
        full_compact_sec=f"{sec_full:.6f}",
        pause_ratio=f"{sec_fold / sec_full:.3f}",
    )

    # ---- 3. smoke: background fold must not stall reads ---------------------
    if args.smoke:
        policy = CompactionPolicy(max_delta_depth=64, fold_k=2)  # manual folds
        server = TableServer(table, keys, vals, policy=policy)
        oracle_keys = keys[:32]
        for _ in range(args.depth):
            server.submit_insert(
                rng.integers(0, n, size=batch, dtype=np.uint32),
                np.arange(batch, dtype=np.int32),
            )
        server.submit_delete(keys[n - 64 :])
        server.drain()
        want = np.asarray(server.query_many([oracle_keys])[0][0])

        # warm both read depths so the during-fold loop measures serving,
        # not compilation: current depth, and depth - fold_k (post-fold)
        post = maintenance.fold_oldest(server.current().state, 2)
        server.batcher.query_many(post, [oracle_keys])

        # Up to 3 attempts guard against two benign timing flukes: a fast
        # fold landing before the first read can be issued (nothing to
        # observe), and a GIL-contended single read outlasting a warm fold
        # (stall >= fold_sec without the read path actually blocking).
        # Each retry restores the folded depth with two fresh inserts.
        for attempt in range(3):
            pre_seq = server.current().seqno
            t0 = time.perf_counter()
            t = server.fold_async(k=2)
            reads_during = 0
            stall = 0.0
            while t.is_alive():
                r0 = time.perf_counter()
                counts, seq = server.query_many([oracle_keys])
                dt = time.perf_counter() - r0
                assert seq == pre_seq, (
                    f"torn read: seqno {seq} during fold of {pre_seq}"
                )
                np.testing.assert_array_equal(np.asarray(counts[0]), want)
                reads_during += 1
                stall = max(stall, dt)
            t.join()
            fold_sec = time.perf_counter() - t0
            assert server.current().seqno == pre_seq + 1, "fold did not publish"
            counts, seq = server.query_many([oracle_keys])
            assert seq == pre_seq + 1
            np.testing.assert_array_equal(np.asarray(counts[0]), want)
            if reads_during >= 1 and stall < fold_sec:
                break
            for _ in range(2):  # restore depth for the retry
                server.submit_insert(
                    rng.integers(0, n, size=batch, dtype=np.uint32),
                    np.arange(batch, dtype=np.int32),
                )
            server.drain()
            want = np.asarray(server.query_many([oracle_keys])[0][0])
        assert reads_during >= 1, "no read completed while the fold was in flight"
        assert stall < fold_sec, (
            f"a read ({stall:.3f}s) waited as long as the fold ({fold_sec:.3f}s) "
            "on every attempt: the read path blocked on compaction"
        )
        print(
            f"smoke: {reads_during} reads served during a {fold_sec * 1e3:.0f}ms "
            f"background fold (max read {stall * 1e3:.1f}ms), all at seqno "
            f"{pre_seq}, fold published {pre_seq + 1}; zero read-path stalls"
        )

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {"bench": "serve", "devices": d, "keys": n, "rows": rows},
                f,
                indent=2,
            )
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
