"""Schema-width benchmark — uint32 vs uint64 keys, 1 vs 4 value columns.

Sweeps the :class:`~repro.core.schema.TableSchema` grid and reports
per-key build/query/retrieve throughput so the cost of the two-lane
64-bit key packing and of multi-column payload movement is a number, not
a guess.  WarpCore/WarpSpeed treat configurable key/value widths as
table-stakes for a reusable GPU hash table; this is the TPU-side scorecard.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 17)
    ap.add_argument("--dup", type=int, default=4, help="average key multiplicity")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.schema import TableSchema, pack_u64
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = (args.keys // d) * d
    rng = np.random.default_rng(1)
    distinct = max(1, n // args.dup)

    for key_dtype in ("uint32", "uint64"):
        for value_cols in (1, 4):
            sch = TableSchema(key_dtype, value_cols)
            if key_dtype == "uint64":
                # spread keys across the full 64-bit range so the two-lane
                # compare/hash path is actually exercised
                raw = rng.integers(0, distinct, size=n).astype(np.uint64)
                raw |= raw << np.uint64(32)
                keys = pack_u64(raw)
            else:
                keys = jnp.asarray(
                    rng.integers(0, distinct, size=n, dtype=np.uint32)
                )
            if value_cols == 1:
                values = jnp.arange(n, dtype=jnp.int32)
            else:
                values = jnp.asarray(
                    rng.integers(-(1 << 20), 1 << 20, size=(n, value_cols)).astype(
                        np.int32
                    )
                )
            table = DistributedHashTable(
                mesh, ("d",), hash_range=n, capacity_slack=2.0, schema=sch
            )
            state = table.build(keys, values=values)
            out_cap = 8 * ((4 * args.dup * (n // d) + 64) // 8)

            def run_build():
                return table.build(keys, values=values)

            def run_retrieve(state, q):
                return table.retrieve(
                    state, q, out_capacity=out_cap, seg_capacity=out_cap
                )

            res = run_retrieve(state, keys)
            assert int(res.num_dropped) == 0, "benchmark capacity sizing bug"
            sec_b = time_fn(run_build)
            sec_q = time_fn(table.query, state, keys)
            sec_r = time_fn(run_retrieve, state, keys)
            results = int(np.asarray(res.counts).sum())
            emit(
                "widths",
                sec_r,
                key_dtype=key_dtype,
                value_cols=value_cols,
                keys=n,
                results=results,
                build_keys_per_sec=f"{n / sec_b:.3e}",
                query_keys_per_sec=f"{n / sec_q:.3e}",
                retrieve_keys_per_sec=f"{n / sec_r:.3e}",
                retrieve_results_per_sec=f"{results / sec_r:.3e}",
            )


if __name__ == "__main__":
    main()
