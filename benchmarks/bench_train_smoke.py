"""Framework extra — smoke-scale train/decode step wall time per arch.

Not a paper table; tracks end-to-end step cost of the LM stack so §Perf
regressions show up in ``benchmarks.run`` output.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="qwen3_4b,mixtral_8x22b,xlstm_1_3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit, time_fn
    from repro.configs.base import get_smoke_config
    from repro.distributed.parallel import single_device_parallel
    from repro.models.api import build_model
    from repro.train.step import TrainStepConfig, make_train_state, make_train_step

    for arch in args.archs.split(","):
        cfg = get_smoke_config(arch)
        bundle = build_model(cfg, single_device_parallel())
        params, opt = make_train_state(bundle, TrainStepConfig(), jax.random.key(0))
        step = jax.jit(make_train_step(bundle, TrainStepConfig()))
        if cfg.is_encoder_decoder:
            batch = {
                "tokens": jnp.zeros((args.batch, args.seq + 1), jnp.int32),
                "frames": jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16
                ),
            }
        elif cfg.frontend == "patch_stub":
            batch = {
                "tokens": jnp.zeros((args.batch, args.seq + 1), jnp.int32),
                "patch_emb": jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16
                ),
            }
        else:
            batch = {"tokens": jnp.zeros((args.batch, args.seq + 1), jnp.int32)}

        def run(p, o, b):
            return step(p, o, b)[2]["loss"]

        sec = time_fn(run, params, opt, batch, warmup=1, iters=3)
        toks = args.batch * args.seq
        emit(
            f"train_step_smoke_{arch}", sec, tokens=toks,
            tokens_per_sec=f"{toks/sec:.3e}",
        )


if __name__ == "__main__":
    main()
