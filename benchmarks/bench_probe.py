"""Probe-path benchmark — fingerprint lane vs full-key bisection.

Sweeps the probe+gather path (query multiplicities and plan-executed
retrieve) over the schema grid the fingerprint lane targets: u32x1 (1-lane
keys, fingerprints off by default) and u64x2 (2-lane keys, fingerprints on
by default), each at delta depth 0 and 8, with the fingerprint lane forced
on and off so the two probe layouts run the identical workload.

What to expect: the fingerprint path narrows every bucket window with a
1-lane uint32 bisection before the full-key verification pass, so per
probe step it compares 4 bytes where the u64x2 full-key path compares 8
(the ``probe_lane_bytes`` column).  On TPU that is the memory-bound win;
on this CPU/interpret validation vehicle the fixed-trip bisection cost is
ALU-bound and the two paths land at parity — the committed
``BENCH_probe.json`` documents the measured ratio alongside the bytes
moved per probe step, which is the honest CPU-side scorecard.

``--smoke`` shrinks sizes for CI and **asserts** the fingerprint path is
byte-identical to the full-key path on a mixed workload (build + inserts
+ deletes, hit/miss queries, both schemas) — offsets, values, counts, and
drop counters all equal.  ``--json PATH`` writes the machine-readable
baseline.
"""
import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 20)
    ap.add_argument("--queries", type=int, default=1 << 14)
    ap.add_argument("--dup", type=int, default=4, help="average key multiplicity")
    ap.add_argument("--depths", type=str, default="0,8")
    ap.add_argument("--smoke", action="store_true", help="CI parity run")
    ap.add_argument("--json", type=str, default=None, help="write rows to PATH")
    args = ap.parse_args()

    if args.smoke:
        args.keys = min(args.keys, 1 << 14)
        args.queries = min(args.queries, 1 << 12)
        args.depths = "0,4"

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.schema import TableSchema, pack_u64
    from repro.core.table import DistributedHashTable

    depths = [int(x) for x in args.depths.split(",")]
    deepest = max(depths)
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = (args.keys // d) * d
    nq = args.queries
    rng = np.random.default_rng(11)
    distinct = max(1, n // args.dup)

    def make_keys(key_dtype, size):
        raw = rng.integers(0, distinct, size=size).astype(np.uint64)
        if key_dtype == "uint64":
            # full 64-bit spread so the 2-lane compare path is real work
            return pack_u64(raw | (raw << np.uint64(32)))
        return jnp.asarray(raw.astype(np.uint32))

    rows = []
    for key_dtype in ("uint32", "uint64"):
        sch = TableSchema(key_dtype, 1)
        keys = make_keys(key_dtype, n)
        values = jnp.arange(n, dtype=jnp.int32)
        # hit/miss mix: half the queries re-draw stored content, half miss
        q_hit = make_keys(key_dtype, nq // 2)
        miss = rng.integers(distinct, 2 * distinct, size=nq - nq // 2).astype(np.uint64)
        q_miss = (
            pack_u64(miss | (miss << np.uint64(32)))
            if key_dtype == "uint64"
            else jnp.asarray(miss.astype(np.uint32))
        )
        queries = jnp.concatenate([q_hit, q_miss], axis=0)
        ins_batches = [make_keys(key_dtype, max(64, n // 256)) for _ in range(deepest)]
        dels = keys[:64]

        results = {}
        for fp in (False, True):
            table = DistributedHashTable(
                mesh,
                ("d",),
                hash_range=n,
                capacity_slack=2.0,
                schema=sch,
                max_deltas=max(deepest, 1),
                fingerprint=fp,
            )
            state = table.init(keys, values=values)
            state = state.delete(dels)
            by_depth = {0: state}
            for i, ins in enumerate(ins_batches):
                state = state.insert(ins)
                by_depth[i + 1] = state

            for depth in depths:
                st = by_depth[depth]
                plan = table.plan_retrieve(st, queries)
                res = plan(st, queries)
                assert int(res.num_dropped) == 0, "benchmark capacity sizing bug"
                results[(fp, depth)] = res
                sec_q = time_fn(table.query, st, queries, iters=3)
                sec_r = time_fn(plan, st, queries, iters=3)
                lanes = sch.key_lanes
                row = {
                    "key_dtype": key_dtype,
                    "fingerprint": fp,
                    "depth": depth,
                    "keys": n,
                    "queries": nq,
                    # bytes compared per probe step: the fingerprint layout
                    # bisects a 1-lane uint32 array; the full-key layout
                    # compares every key lane.
                    "probe_lane_bytes": 4 if fp else 4 * lanes,
                    "query_keys_per_sec": nq / sec_q,
                    "retrieve_keys_per_sec": nq / sec_r,
                    "query_sec": sec_q,
                    "retrieve_sec": sec_r,
                }
                rows.append(row)
                emit(
                    "probe",
                    sec_q,
                    key_dtype=key_dtype,
                    fingerprint=fp,
                    depth=depth,
                    query_keys_per_sec=f"{nq / sec_q:.3e}",
                    retrieve_keys_per_sec=f"{nq / sec_r:.3e}",
                )

        # Parity gate: same workload through both probe layouts must agree
        # byte-for-byte (stable sort makes even duplicate-run payload order
        # identical).  Always checked; --smoke exists to run it cheaply.
        for depth in depths:
            a, b = results[(False, depth)], results[(True, depth)]
            for field in ("offsets", "counts", "values", "num_dropped"):
                av, bv = np.asarray(getattr(a, field)), np.asarray(getattr(b, field))
                assert np.array_equal(av, bv), (
                    f"fingerprint path diverged: {key_dtype} depth={depth} {field}"
                )
        print(f"parity: {key_dtype} fingerprint path byte-identical at depths {depths}")

    for key_dtype in ("uint32", "uint64"):
        sub = {
            (r["fingerprint"], r["depth"]): r
            for r in rows
            if r["key_dtype"] == key_dtype
        }
        for depth in depths:
            ratio = sub[(False, depth)]["query_sec"] / sub[(True, depth)]["query_sec"]
            print(
                f"{key_dtype} depth={depth}: fingerprint query speedup {ratio:.2f}x "
                f"(probe lane {sub[(True, depth)]['probe_lane_bytes']}B vs "
                f"{sub[(False, depth)]['probe_lane_bytes']}B per compare)"
            )

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {
                    "bench": "probe",
                    "devices": d,
                    "keys": n,
                    "queries": nq,
                    "dup": args.dup,
                    "note": (
                        "CPU interpret-mode numbers: fixed-trip bisection is "
                        "ALU-bound here, so fingerprint vs full-key lands at "
                        "parity; probe_lane_bytes records the per-compare "
                        "bytes-moved reduction the lane buys on the "
                        "memory-bound TPU target."
                    ),
                    "rows": rows,
                },
                f,
                indent=2,
            )
            f.write("\n")
        print(f"wrote {args.json}")

    if args.smoke:
        print("smoke: fingerprint/full-key parity asserted on mixed workload")


if __name__ == "__main__":
    main()
