"""Retrieval subsystem benchmark — count vs materialize, duplicates sweep.

Compares the counting query (``query``) with the two-pass retrieval
pipeline (``retrieve``: count → prefix-sum → gather) and the materialized
join (``inner_join``) as the average key multiplicity grows.  The delta
between the query and retrieve columns is the price of actually producing
the values — the functionality gap WarpSpeed (2509.16407) highlights for
GPU hash tables, closed here for the TPU table.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 18)
    ap.add_argument("--max-dup-log2", type=int, default=6)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(1)

    for dup_log2 in range(0, args.max_dup_log2 + 1, 2):
        dup = 1 << dup_log2
        keys = jnp.asarray(rng.integers(0, max(1, n // dup), size=n, dtype=np.uint32))
        table = DistributedHashTable(
            mesh, ("d",), hash_range=n, capacity_slack=2.0
        )
        state = table.build(keys)
        # every key is its own query: expected fanout == avg multiplicity
        out_cap = 8 * ((4 * dup * (n // d) + 64) // 8)

        def run_retrieve(state, q):
            return table.retrieve(state, q, out_capacity=out_cap, seg_capacity=out_cap)

        def run_join(state, q):
            return table.inner_join(state, q, out_capacity=out_cap, seg_capacity=out_cap)

        res = run_retrieve(state, keys)
        assert int(res.num_dropped) == 0, "benchmark capacity sizing bug"
        sec_q = time_fn(table.query, state, keys)
        sec_r = time_fn(run_retrieve, state, keys)
        sec_j = time_fn(run_join, state, keys)
        results = int(np.asarray(res.counts).sum())
        emit(
            "retrieve",
            sec_r,
            avg_occurrence=dup,
            results=results,
            query_keys_per_sec=f"{n / sec_q:.3e}",
            retrieve_keys_per_sec=f"{n / sec_r:.3e}",
            retrieve_results_per_sec=f"{results / sec_r:.3e}",
            join_pairs_per_sec=f"{results / sec_j:.3e}",
        )


if __name__ == "__main__":
    main()
