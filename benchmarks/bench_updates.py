"""Mutation benchmark — insert/delete/compact throughput vs delta depth.

Measures the versioned plan/execute API (PR 3): functional ``insert``
(delta-graph build), ``delete`` (tombstone append), planned ``retrieve``
execution as the delta ring deepens (each extra delta adds one routed
round per query batch), and ``compact`` (fold deltas + tombstones into a
fresh base).  The query-latency-vs-depth column is the read amplification
an LSM-style design pays before compaction.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 17)
    ap.add_argument("--insert-batch", type=int, default=1 << 12)
    ap.add_argument("--max-depth", type=int, default=8)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n, batch = args.keys, args.insert_batch
    rng = np.random.default_rng(2)

    table = DistributedHashTable(
        mesh, ("d",), hash_range=n, capacity_slack=2.0, max_deltas=args.max_depth
    )
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    state = table.init(keys)
    queries = jnp.asarray(rng.integers(0, n, size=n // 4, dtype=np.uint32))

    sec_build = time_fn(table.init, keys, iters=3)
    emit("update_build", sec_build, keys=n, keys_per_sec=f"{n / sec_build:.3e}")

    depth = 0
    while depth < args.max_depth:
        ins = jnp.asarray(rng.integers(0, n, size=batch, dtype=np.uint32))
        sec_ins = time_fn(state.insert, ins, iters=3)
        state = state.insert(ins)
        depth = state.epoch

        dels = jnp.asarray(rng.integers(0, n, size=64, dtype=np.uint32))
        sec_del = time_fn(state.delete, dels, iters=3)
        state = state.delete(dels)

        plan = table.plan_retrieve(state, queries)
        res = plan(state, queries)
        assert int(res.num_dropped) == 0, "benchmark capacity sizing bug"
        sec_q = time_fn(table.query, state, queries)
        sec_r = time_fn(plan, state, queries)
        emit(
            "update_depth",
            sec_r,
            depth=depth,
            insert_keys_per_sec=f"{batch / sec_ins:.3e}",
            delete_keys_per_sec=f"{64 / sec_del:.3e}",
            query_keys_per_sec=f"{queries.shape[0] / sec_q:.3e}",
            retrieve_keys_per_sec=f"{queries.shape[0] / sec_r:.3e}",
        )

        if depth in (1, args.max_depth // 2, args.max_depth):
            sec_c = time_fn(state.compact, iters=2)
            live = n + depth * batch
            emit(
                "update_compact",
                sec_c,
                depth=depth,
                live_keys=live,
                keys_per_sec=f"{live / sec_c:.3e}",
            )


if __name__ == "__main__":
    main()
