"""Paper Fig. 4 — duplicate-keys sweep: throughput vs avg key occurrence.

Fixed key count; the hash range shrinks 2^0..2^6 so the average
multiplicity doubles each step (paper: build flat, query decays once
lists exceed ~8 — our sorted-bucket query keeps the decay logarithmic,
the beyond-paper variant is reported alongside the faithful probe).
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 19)
    ap.add_argument("--max-dup-log2", type=int, default=6)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(1)

    for dup_log2 in range(args.max_dup_log2 + 1):
        dup = 1 << dup_log2
        # sample keys from a range n/dup wide → avg multiplicity ≈ dup
        keys = jnp.asarray(rng.integers(0, max(1, n // dup), size=n, dtype=np.uint32))
        hr = n  # C=1 table size, as the paper fixes it
        table = DistributedHashTable(
            mesh, ("d",), hash_range=hr, capacity_slack=1.5
        )
        sec_b = time_fn(table.build, keys)
        state = table.build(keys)
        sec_q = time_fn(table.query, state, keys)
        table_p = DistributedHashTable(
            mesh, ("d",), hash_range=hr, capacity_slack=1.5,
            paper_faithful_probe=True, max_probe=int(dup * 8 + 16),
        )
        state_p = table_p.build(keys)
        sec_qp = time_fn(table_p.query, state_p, keys)
        emit(
            "duplicates",
            sec_b,
            avg_occurrence=dup,
            build_keys_per_sec=f"{n / sec_b:.3e}",
            query_sorted_keys_per_sec=f"{n / sec_q:.3e}",
            query_probe_keys_per_sec=f"{n / sec_qp:.3e}",
        )


if __name__ == "__main__":
    main()
