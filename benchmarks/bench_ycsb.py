"""YCSB benchmark — the KV-cache subsystem under A–F mixed workloads.

The cloud-serving measurement for the upsert/TTL serving stack: each
workload letter (see :mod:`repro.cache.workload`) streams zipfian-skewed
reads and insert-or-replace writes through the AOT-warmed
:class:`TableServer` behind an :class:`AsyncFrontend`, closed-loop (ops
are offered as fast as the front end admits them — the throughput mode of
the YCSB client; latency percentiles are completion minus submission, so
queueing counts against the server).

Mapping onto the serving stack:

* **read**   — count-probe requests of ``--req-keys`` keys through
  ``submit_query`` (the fused 2-all-to-all read path; value
  materialization is benched separately in ``bench_retrieve``).
* **update / insert / rmw-write** — coalesced into ``write_bucket``-sized
  buffers and applied via ``submit_upsert`` (delete-prior + bucket-padded
  delta build, keep-last dedup at admission).  RMW issues the read half
  first, same keys.
* **scan** — one request per scan op: a contiguous multiget of
  ``--scan-len`` insertion-order keys (the hashed-store reading of
  YCSB-E's short ranges).

Write submissions are pre-planned, so the exact number of incremental
folds the compaction policy will run is known up front and the AOT warmup
covers every fold-grown base geometry the run can reach
(``fold_horizon``).  ``--smoke`` (CI) then *asserts* the serving
invariants: zero failed/lost requests, zero dropped rows (delta builds
and tombstone buffer), zero skew fallbacks, and zero live traces — every
read batch hits the warmed executor grid and the jit dispatch cache stays
flat across all six letters.

Output: one row per letter (throughput, read p50/p99, op counts) into
``BENCH_ycsb.json`` and ``BENCH,`` CSV lines for the orchestrator.
"""
from __future__ import annotations

import argparse
import threading
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 13, help="loaded population")
    ap.add_argument("--ops", type=int, default=4000, help="ops per workload letter")
    ap.add_argument("--theta", type=float, default=0.99, help="zipfian skew")
    ap.add_argument("--batch", type=int, default=128, help="generator op-batch size")
    ap.add_argument("--scan-len", type=int, default=16)
    ap.add_argument("--req-keys", type=int, default=8, help="keys per read request")
    ap.add_argument("--workloads", type=str, default="A,B,C,D,E,F")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--smoke", action="store_true", help="CI invariant-assertion run")
    ap.add_argument("--json", type=str, default="BENCH_ycsb.json")
    args = ap.parse_args()

    if args.smoke:
        args.keys = min(args.keys, 1 << 10)
        args.ops = min(args.ops, 600)
        args.batch = min(args.batch, 64)
        args.scan_len = min(args.scan_len, 8)

    import jax
    import numpy as np

    from benchmarks.common import assert_clean_run, emit, write_bench_json
    from repro.cache.workload import WORKLOADS, YCSBWorkload, key_of
    from repro.core import plans
    from repro.core.table import DistributedHashTable
    from repro.serve_table import (
        AsyncFrontend,
        CompactionPolicy,
        MicroBatcher,
        TableServer,
    )

    d = len(jax.devices())
    n = args.keys
    letters = [w.strip().upper() for w in args.workloads.split(",") if w.strip()]

    # Write geometry: big buckets keep the fold count (one per coalesced
    # upsert submission at steady state) small enough to pre-warm every
    # fold-grown base the run reaches; one read flush bucket keeps the
    # executor grid linear in the fold horizon.
    wb = 16 * d if args.smoke else 32 * d
    wb = max(8, 1 << (wb - 1).bit_length())
    flush_keys = wb
    if args.scan_len > flush_keys:
        raise SystemExit("--scan-len must fit one flush (raise --keys tier)")

    # ---- pre-plan every letter's op script -----------------------------------
    # ('q', keys) read/scan requests; ('w', keys, values) coalesced upsert
    # submissions of at most wb keys.  Pre-planning pins the exact write-
    # submission count, which pins the fold count, which sizes the warmup.
    scripts = {}
    total_write_submits = 0
    for letter in letters:
        w = YCSBWorkload(
            WORKLOADS[letter],
            n,
            theta=args.theta,
            batch=args.batch,
            scan_len=args.scan_len,
            seed=args.seed,
        )
        script = []
        counts = {k: 0 for k in ("read", "update", "insert", "scan", "rmw")}
        buf_k, buf_v, buf_n = [], [], 0

        def flush_writes():
            nonlocal buf_k, buf_v, buf_n
            if buf_n:
                script.append(
                    ("w", np.concatenate(buf_k), np.concatenate(buf_v))
                )
                buf_k, buf_v, buf_n = [], [], 0

        for kind, keys, vals in w.batches(args.ops):
            if kind == "scan":
                counts["scan"] += keys.shape[0] // args.scan_len
                for i in range(0, keys.shape[0], args.scan_len):
                    script.append(("q", keys[i : i + args.scan_len]))
                continue
            if kind == "read" or kind == "rmw":
                counts[kind] += keys.shape[0]
                for i in range(0, keys.shape[0], args.req_keys):
                    script.append(("q", keys[i : i + args.req_keys]))
                if kind == "read":
                    continue
            else:
                counts[kind] += keys.shape[0]
            # update / insert / rmw write half: coalesce up to wb keys
            off = 0
            while off < keys.shape[0]:
                take = min(wb - buf_n, keys.shape[0] - off)
                buf_k.append(keys[off : off + take])
                buf_v.append(vals[off : off + take])
                buf_n += take
                off += take
                if buf_n == wb:
                    flush_writes()
        flush_writes()
        scripts[letter] = (script, counts)
        total_write_submits += sum(1 for op in script if op[0] == "w")

    # Exact fold forecast: the policy folds one layer per upsert submission
    # once the ring holds max_delta_depth deltas.
    max_depth = 2
    depth = folds = 0
    for _ in range(total_write_submits):
        if depth >= max_depth:
            folds += 1
            depth -= 1
        depth += 1
    fold_horizon = folds + 2  # slack for count drift

    # ---- table + server + AOT warmup ----------------------------------------
    table = DistributedHashTable(
        jax.make_mesh((d,), ("d",)),
        ("d",),
        hash_range=max(n, 1024),
        capacity_slack=2.0,
        max_deltas=4,
        tombstone_capacity=max(256, 4 * wb),
    )
    policy = CompactionPolicy(
        max_delta_depth=max_depth, fold_k=1, tombstone_load=0.9
    )
    server = TableServer(
        table,
        key_of(np.arange(n)),
        np.arange(n, dtype=np.int32),
        policy=policy,
        batcher=MicroBatcher(table, min_bucket=wb),
        write_bucket=wb,
    )
    warm_buckets = tuple(
        wb << i for i in range((flush_keys // wb).bit_length())
    )
    warm = server.warm(
        buckets=warm_buckets, depths=(0, 1, 2), fold_horizon=fold_horizon
    )
    emit(
        "ycsb_warmup",
        warm.compile_seconds,
        entries=warm.entries,
        buckets=",".join(str(b) for b in warm_buckets),
        fold_horizon=fold_horizon,
        write_submits=total_write_submits,
    )
    cache_size = getattr(plans.exec_query, "_cache_size", None)
    cache0 = cache_size() if cache_size else None

    # ---- run phase -----------------------------------------------------------
    rows = [
        {
            "part": "warmup",
            "entries": warm.entries,
            "compile_seconds": warm.compile_seconds,
            "buckets": list(warm_buckets),
            "fold_horizon": fold_horizon,
            "write_submits_planned": total_write_submits,
        }
    ]
    for letter in letters:
        script, counts = scripts[letter]
        lat: list = []
        failures: list = []
        done_lock = threading.Lock()
        submitted = 0

        with AsyncFrontend(
            server, linger=0.002, flush_keys=flush_keys, write_backlog=32
        ) as fe:
            t0 = time.perf_counter()
            for op in script:
                if op[0] == "w":
                    fe.submit_upsert(op[1], op[2], timeout=60.0)
                    continue
                t_sub = time.perf_counter()

                def _done(fut, t=t_sub):
                    dt = time.perf_counter() - t
                    with done_lock:
                        if fut.exception() is None:
                            lat.append(dt)
                        else:
                            failures.append(fut.exception())

                fe.submit_query(op[1], timeout=60.0).add_done_callback(_done)
                submitted += 1
            deadline = time.perf_counter() + 120.0
            while True:
                with done_lock:
                    if len(lat) + len(failures) >= submitted:
                        break
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"workload {letter}: "
                        f"{submitted - len(lat) - len(failures)} read "
                        "responses never resolved"
                    )
                time.sleep(0.002)
            server.drain(timeout=120.0)
            wall = time.perf_counter() - t0

        fe.metrics()  # refresh trace_live / queue-depth gauges post-drain
        snap = server.metrics()  # ONE atomic registry sample per letter
        wstats = server.stats()
        row = {
            "part": "workload",
            "workload": letter,
            "ops": args.ops,
            "op_counts": counts,
            "read_requests": submitted,
            "throughput_ops_s": args.ops / wall,
            "read_p50_ms": float(np.percentile(lat, 50) * 1e3) if lat else None,
            "read_p99_ms": float(np.percentile(lat, 99) * 1e3) if lat else None,
            "wall_seconds": wall,
            "folds_total": wstats.folds,
            "full_compacts_total": wstats.full_compacts,
            "aot_misses_total": wstats.warmup.aot_misses,
            "dropped_rows": wstats.shadow.num_dropped,
        }
        rows.append(row)
        emit(
            "ycsb",
            wall,
            workload=letter,
            ops=args.ops,
            throughput_ops_s=f"{row['throughput_ops_s']:.1f}",
            read_p50_ms=(
                f"{row['read_p50_ms']:.3f}" if lat else "n/a"
            ),
            read_p99_ms=(
                f"{row['read_p99_ms']:.3f}" if lat else "n/a"
            ),
            aot_misses=row["aot_misses_total"],
        )

        if args.smoke:
            assert not failures, (
                f"workload {letter}: {len(failures)} reads failed: "
                f"{failures[:3]}"
            )
            assert len(lat) == submitted, f"workload {letter}: lost responses"
            # Shared smoke gate (zero AOT misses, zero dropped rows, zero
            # skew fallbacks, zero live traces, flat jit cache) off ONE
            # registry snapshot; only the letter-specific fold-forecast
            # check stays inline.
            assert_clean_run(
                snap,
                baseline_cache_size=cache0,
                context=f"workload {letter}",
            )
            assert wstats.full_compacts == 0, (
                f"workload {letter}: {wstats.full_compacts} full compacts — "
                "the fold forecast missed (geometry left the warmed grid)"
            )

    if args.smoke:
        wstats = server.stats()
        print(
            f"ycsb smoke: {len(letters)} workloads x {args.ops} ops, "
            f"{wstats.folds} folds inside a horizon of {fold_horizon}, "
            f"0 dropped rows, 0 live traces "
            f"({wstats.warmup.aot_hits} AOT read hits)"
        )

    if args.json:
        write_bench_json(
            args.json,
            "ycsb",
            rows,
            snapshot=server.metrics(),
            devices=d,
            keys=n,
            ops_per_workload=args.ops,
            theta=args.theta,
            write_bucket=wb,
            flush_keys=flush_keys,
        )


if __name__ == "__main__":
    main()
