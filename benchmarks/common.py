"""Shared benchmark helpers: wall timing + CSV emit.

CPU numbers are *indicative* (TPU is the target); the harness per paper
table is the deliverable — the same scripts run unmodified on a TPU pod.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median seconds per call (after warmup, fully blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, **derived) -> None:
    """One CSV row: name,seconds,k=v,..."""
    kv = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"BENCH,{name},{seconds:.6f},{kv}")
