"""Shared benchmark helpers: wall timing, CSV emit, stamped JSON artifacts.

CPU numbers are *indicative* (TPU is the target); the harness per paper
table is the deliverable — the same scripts run unmodified on a TPU pod.

Every ``BENCH_*.json`` artifact goes through :func:`write_bench_json`, so
each one carries the same envelope: a schema version, host metadata
(device count, backend, CPU count), and — when the script hands one
over — a full :class:`~repro.obs.registry.RegistrySnapshot` of the
serving stack's metrics at the end of the run.  Comparing two artifacts
therefore never requires guessing what machine or code shape produced
them.

:func:`assert_clean_run` is the shared CI gate: the zero-drop / zero-miss
invariants every smoke benchmark used to restate inline, asserted off one
registry snapshot.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Optional

import jax
import numpy as np

#: Bump when the artifact envelope changes shape.  v1 was the bare
#: ``{"bench": ..., "rows": [...]}`` dict; v2 adds ``schema_version``,
#: ``host`` and the optional ``metrics`` registry snapshot.
BENCH_SCHEMA_VERSION = 2


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median seconds per call (after warmup, fully blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, **derived) -> None:
    """One CSV row: name,seconds,k=v,..."""
    kv = ",".join(f"{k}={v}" for k, v in derived.items())
    print(f"BENCH,{name},{seconds:.6f},{kv}")


def host_metadata() -> dict:
    """Where this artifact was measured: backend, device count, CPU count."""
    return {
        "backend": jax.default_backend(),
        "device_count": len(jax.devices()),
        "cpu_count": os.cpu_count(),
        "jax_version": jax.__version__,
    }


def write_bench_json(
    path: str,
    bench: str,
    rows: list,
    *,
    snapshot=None,
    registry=None,
    **extra,
) -> dict:
    """Write one stamped ``BENCH_*.json`` artifact; returns the payload.

    ``snapshot`` (a :class:`~repro.obs.registry.RegistrySnapshot`) or
    ``registry`` (sampled here) lands under ``"metrics"`` — the whole
    serving stack's counters/gauges/histograms at end of run, in the
    nested-dict form of ``RegistrySnapshot.as_dict()``.  ``extra`` keys
    (devices, key counts, knobs) merge into the envelope top level.
    """
    if snapshot is None and registry is not None:
        snapshot = registry.snapshot()
    payload = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "bench": bench,
        "host": host_metadata(),
        **extra,
        "rows": rows,
    }
    if snapshot is not None:
        payload["metrics"] = snapshot.as_dict()
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return payload


def assert_clean_run(
    snap,
    *,
    baseline_cache_size: Optional[int] = None,
    context: str = "",
) -> None:
    """The shared smoke-gate invariants, off ONE registry snapshot.

    ``snap`` must come from ``server.metrics()`` (refreshed gauges) taken
    after the run — and after ``frontend.metrics()`` when a front end was
    involved, so ``trace_live``/``frontend_failed_total`` are populated.
    Asserts, with zero tolerance:

    * no read batch fell off the warmed executor grid (``aot_misses_total``);
    * no rows lost anywhere (``serve_dropped_rows``,
      ``serve_tombstone_dropped``) and no skew-guard fallbacks;
    * no failed front-end requests and no trace still open
      (``frontend_failed_total``, ``trace_live``);
    * with ``baseline_cache_size``: the jit dispatch cache is exactly as
      big as before the run — a growth means a live trace slipped past
      AOT warmup.
    """
    where = f"{context}: " if context else ""
    aot_misses = int(snap.value("aot_misses_total"))
    assert aot_misses == 0, (
        f"{where}{aot_misses} read batches fell off the warmed executor "
        "grid — live tracing happened"
    )
    dropped = int(snap.value("serve_dropped_rows"))
    assert dropped == 0, (
        f"{where}{dropped} rows dropped (delta build or tombstone overflow)"
    )
    ts_dropped = int(snap.value("serve_tombstone_dropped"))
    assert ts_dropped == 0, f"{where}tombstone buffer overflowed ({ts_dropped})"
    skew = int(snap.value("serve_skew_fallbacks"))
    assert skew == 0, (
        f"{where}{skew} inserts routed incoherent by the skew guard"
    )
    failed = int(snap.value("frontend_failed_total"))
    assert failed == 0, f"{where}{failed} front-end requests failed"
    live = int(snap.value("trace_live"))
    assert live == 0, (
        f"{where}{live} traces still open after drain — a request was "
        "admitted but never resolved"
    )
    if baseline_cache_size is not None:
        cache = int(snap.value("jit_dispatch_cache_size"))
        assert cache == baseline_cache_size, (
            f"{where}jit dispatch cache grew {baseline_cache_size} -> "
            f"{cache}: a live trace slipped past AOT warmup"
        )
