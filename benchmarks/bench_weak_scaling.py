"""Paper Fig. 3 — weak scaling: fixed keys/device, growing device count.

Run by ``benchmarks.run`` in a subprocess per device count (the device
count is locked at jax init).  Reports build and query throughput
(keys/s) for random and sequential keys, as in the paper.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys-per-device", type=int, default=1 << 18)
    ap.add_argument("--devices", type=int, default=0, help="0 = use all present")
    args = ap.parse_args()
    if args.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    n = args.keys_per_device * d
    mesh = jax.make_mesh((d,), ("d",))
    table = DistributedHashTable(mesh, ("d",), hash_range=n)
    rng = np.random.default_rng(0)

    for dist in ("random", "sequential"):
        if dist == "random":
            keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
        else:
            keys = jnp.arange(n, dtype=jnp.uint32)
        sec = time_fn(table.build, keys)
        emit(
            f"weak_scaling_build_{dist}",
            sec,
            devices=d,
            keys=n,
            keys_per_sec=f"{n / sec:.3e}",
        )
        state = table.build(keys)
        sec = time_fn(table.query, state, keys)
        emit(
            f"weak_scaling_query_{dist}",
            sec,
            devices=d,
            keys=n,
            keys_per_sec=f"{n / sec:.3e}",
        )


if __name__ == "__main__":
    main()
