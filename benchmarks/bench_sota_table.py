"""Paper §5 SOTA comparison table.

Prints the paper-reported throughputs next to this implementation's
measured numbers (CPU here — indicative only; the same harness reports
TPU keys/s when run on real hardware).
"""
import argparse

PAPER = [
    ("Folklore CPU [Maier et al.]", "multicore CPU", 0.3e9),
    ("Balkesen et al.", "multicore CPU", 0.45e9),
    ("Cray XMT [Goodman et al.]", "massively-threaded", 0.25e9),
    ("Barthels et al. 512 cores", "distributed MPI", 8e9),
    ("Barthels et al. 1024 cores", "distributed MPI", 10e9),
    ("Single-GPU HashGraph [Green]", "V100", 2.3e9),
    ("Multi-GPU HashGraph (paper, DGX-2 16xV100)", "NVSwitch", 8e9),
    ("Multi-GPU HashGraph (paper, AC922 6xV100)", "NVLink", 5e9),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 19)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(4)
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    table = DistributedHashTable(mesh, ("d",), hash_range=n)
    sec = time_fn(table.build, keys)
    ours = n / sec

    print(f"{'system':52s} {'class':22s} {'build keys/s':>14s}")
    for name, klass, thr in PAPER:
        print(f"{name:52s} {klass:22s} {thr:14.2e}")
    print(f"{'THIS IMPL (CPU, ' + str(d) + ' fake devices)':52s} {'JAX/TPU-target':22s} {ours:14.2e}")
    emit("sota_build", sec, keys=n, keys_per_sec=f"{ours:.3e}", devices=d)


if __name__ == "__main__":
    main()
