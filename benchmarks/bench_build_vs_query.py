"""Paper §5.3 — build vs query cost.

The paper's query = build a second HashGraph from the query set + list
intersections (~90% build / ~10% intersect).  We time: build, the
query-side second build, the full count query (sorted + paper-faithful
probe), and the join.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 19)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n = args.keys
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))

    table = DistributedHashTable(mesh, ("d",), hash_range=n)
    sec_build = time_fn(table.build, keys)
    state = table.build(keys)
    sec_query = time_fn(table.query, state, queries)
    sec_join = time_fn(table.join_size, state, queries)

    table_p = DistributedHashTable(
        mesh, ("d",), hash_range=n, paper_faithful_probe=True, max_probe=32
    )
    state_p = table_p.build(keys)
    sec_query_probe = time_fn(table_p.query, state_p, queries)

    emit("build", sec_build, keys=n, keys_per_sec=f"{n/sec_build:.3e}")
    emit("query_sorted", sec_query, keys=n, keys_per_sec=f"{n/sec_query:.3e}",
         query_over_build=f"{sec_query/sec_build:.2f}")
    emit("query_probe_faithful", sec_query_probe, keys=n,
         keys_per_sec=f"{n/sec_query_probe:.3e}")
    emit("join_size", sec_join, keys=n, keys_per_sec=f"{n/sec_join:.3e}")


if __name__ == "__main__":
    main()
