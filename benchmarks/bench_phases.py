"""Paper Fig. 5 — normalized execution time of the four build phases.

Cumulative jitted prefixes (phase1, phases1-2, phases1-3, full build);
per-phase time is the successive difference — the standard way to carve a
fused SPMD program without instrumenting inside jit.
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 19)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.utils.compat import shard_map
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import emit, time_fn
    from repro.core import exchange, hashing, multi_hashgraph, partition
    from repro.core.hashgraph import EMPTY_KEY
    from repro.core import hashgraph

    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    ax = ("d",)
    n = args.keys
    hr = n
    rng = np.random.default_rng(2)
    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    bins_g = partition.choose_num_bins(hr, d)
    capacity = multi_hashgraph.default_capacity(n // d, d, 1.25)
    local_cap = int(-(-hr // d) * 1.5)

    def phase1(k):
        h = hashing.hash_to_buckets(k, hr)
        hist = partition.local_bin_histogram(h, bins_g, hr)
        ghist = jax.lax.psum(hist, ax)
        return partition.balanced_hash_splits(ghist, d, hr)

    def phase12(k):
        splits = phase1(k)
        h = hashing.hash_to_buckets(k, hr)
        dest = partition.destination_of(h, splits)
        packed, _ = exchange.pack_by_destination(
            (k,), dest, d, capacity, fills=(jnp.uint32(EMPTY_KEY),)
        )
        return packed[0]

    def phase123(k):
        buf = phase12(k)
        b = buf.reshape(d, capacity)
        return exchange.all_to_all_hierarchical(b, ax).reshape(-1)

    def phase1234(k):
        rk = phase123(k)
        splits = phase1(k)
        rank = exchange.my_rank(ax)
        lo = splits[rank]
        buckets = multi_hashgraph._local_buckets(rk, lo, hr, local_cap, hashing.DEFAULT_SEED)
        hg = hashgraph.build_from_buckets(rk, buckets, local_cap)
        return hg.offsets

    def sm(f, out_spec):
        return jax.jit(
            shard_map(
                f, mesh=mesh, in_specs=(P(ax),), out_specs=out_spec, check_vma=False
            )
        )

    fns = {
        "partitioning": sm(phase1, P()),
        "preprocess": sm(phase12, P(ax)),
        "all_to_all": sm(phase123, P(ax)),
        "table_construction": sm(phase1234, P(ax)),
    }
    prev = 0.0
    total = None
    for name, fn in fns.items():
        sec = time_fn(fn, keys)
        emit(f"phase_cumulative_{name}", sec, keys=n, devices=d)
        emit(f"phase_delta_{name}", max(sec - prev, 0.0), keys=n, devices=d)
        prev = sec
        total = sec
    emit("phase_total_build", total, keys=n, devices=d,
         keys_per_sec=f"{n / total:.3e}")


if __name__ == "__main__":
    main()
