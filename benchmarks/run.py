"""Benchmark orchestrator — one bench per paper table/figure.

Each bench runs in its own subprocess so the fake-device count can differ
(jax locks the device count at first init).  Output lines starting with
``BENCH,`` form the machine-readable record; everything is teed by the
caller into bench_output.txt.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

BENCHES = [
    # (module, args, fake_devices) — paper Fig. 3 weak scaling over device counts
    ("benchmarks.bench_weak_scaling", ["--keys-per-device", "131072"], 1),
    ("benchmarks.bench_weak_scaling", ["--keys-per-device", "131072"], 2),
    ("benchmarks.bench_weak_scaling", ["--keys-per-device", "131072"], 4),
    ("benchmarks.bench_weak_scaling", ["--keys-per-device", "131072"], 8),
    # Fig. 4 duplicates sweep
    ("benchmarks.bench_duplicates", ["--keys", "262144"], 8),
    # Fig. 5 phase breakdown
    ("benchmarks.bench_phases", ["--keys", "262144"], 8),
    # §5.3 build vs query
    ("benchmarks.bench_build_vs_query", ["--keys", "262144"], 8),
    # retrieval subsystem: count vs materialize (WarpSpeed-style value API)
    ("benchmarks.bench_retrieve", ["--keys", "131072"], 8),
    # schema widths: uint32 vs uint64 keys, 1 vs 4 value columns
    ("benchmarks.bench_widths", ["--keys", "131072"], 8),
    # versioned state: insert/delete/compact throughput vs delta depth
    ("benchmarks.bench_updates", ["--keys", "131072"], 8),
    # single-route layered execution: fused vs legacy routing vs delta depth
    ("benchmarks.bench_layers", ["--keys", "131072"], 8),
    # probe path: fingerprint lane vs full-key bisection, u32x1/u64x2,
    # depth 0 and 8 (parity-asserted; bytes-moved scorecard)
    ("benchmarks.bench_probe", ["--keys", "131072"], 8),
    # serving engine: request-stream latency/throughput vs batching window,
    # fold-vs-full-compact pause time
    ("benchmarks.bench_serve", ["--keys", "32768"], 8),
    # async front end: open-loop Poisson arrivals through the AOT-warmed
    # server — p50/p99/p999 + goodput per offered rate
    ("benchmarks.bench_serve", ["--keys", "32768", "--open-loop"], 8),
    # KV-cache subsystem: YCSB A–F mixed workloads through the AOT-warmed
    # upsert/TTL serving stack — throughput + read p50/p99 per letter
    ("benchmarks.bench_ycsb", ["--keys", "8192"], 8),
    # §5 SOTA comparison
    ("benchmarks.bench_sota_table", ["--keys", "262144"], 8),
    # framework extra: LM step cost
    ("benchmarks.bench_train_smoke", [], 1),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    args = ap.parse_args()

    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    failures = 0
    for module, margs, devices in BENCHES:
        if args.fast:
            margs = [a if not a.isdigit() else str(max(1024, int(a) // 8)) for a in margs]
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep + env.get("PYTHONPATH", "")
        print(f"=== {module} devices={devices} {' '.join(margs)}", flush=True)
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", module, *margs],
            env=env,
            cwd=repo,
            capture_output=True,
            text=True,
        )
        sys.stdout.write(proc.stdout)
        if proc.returncode != 0:
            failures += 1
            sys.stdout.write(proc.stderr[-3000:])
            print(f"=== FAILED {module} rc={proc.returncode}")
        else:
            print(f"=== done in {time.time()-t0:.1f}s", flush=True)
    print(f"benchmarks complete: {len(BENCHES) - failures}/{len(BENCHES)} ok")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
