"""Layered-read benchmark — throughput vs delta-ring depth, fused vs legacy.

The single-route issue's acceptance metric: with partition-coherent deltas
and fused routing, query/retrieve cost must be ~flat in the delta depth L
(one dispatch + one return per op), where the legacy per-layer path pays
one routing round per layer (~L× the collectives, ~L× the latency).

For each depth L in ``--depths`` the same base + insert history is read
through both routings (``fused_routing=None`` vs ``False`` on otherwise
identical tables) with plan-executed retrieve (explicit caps, no planning
sync in the timed region).

``--smoke`` shrinks sizes/depths to a CI-budget run (~30s) and **asserts**
the fused path's collective count is depth-independent (a deterministic
jaxpr check — wall-clock on shared CI runners is too noisy to gate on), so
a routing-round regression fails the step loudly.  ``--json PATH`` records
the rows machine-readably (the committed ``BENCH_layers.json`` baseline).
"""
import argparse
import json


def _count_all_to_all(closed_jaxpr) -> int:
    """Occurrences of the all_to_all primitive anywhere in a nested jaxpr."""
    import jax.core as jcore

    def subs(v):
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                yield from subs(x)

    def walk(jaxpr):
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "all_to_all":
                n += 1
            for v in eqn.params.values():
                for sub in subs(v):
                    n += walk(sub)
        return n

    return walk(closed_jaxpr.jaxpr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=1 << 17)
    ap.add_argument("--queries", type=int, default=1 << 15)
    ap.add_argument("--insert-batch", type=int, default=1 << 12)
    ap.add_argument("--depths", type=str, default="0,1,2,4,8")
    ap.add_argument("--smoke", action="store_true", help="~30s CI smoke run")
    ap.add_argument("--json", type=str, default=None, help="write rows to PATH")
    args = ap.parse_args()

    if args.smoke:
        args.keys = min(args.keys, 1 << 14)
        args.queries = min(args.queries, 1 << 12)
        args.insert_batch = min(args.insert_batch, 1 << 9)
        args.depths = "0,2,4"

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import emit, time_fn
    from repro.core.table import DistributedHashTable

    depths = [int(x) for x in args.depths.split(",")]
    d = len(jax.devices())
    mesh = jax.make_mesh((d,), ("d",))
    n, nq, batch = args.keys, args.queries, args.insert_batch
    rng = np.random.default_rng(7)

    keys = jnp.asarray(rng.integers(0, n, size=n, dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, n, size=nq, dtype=np.uint32))
    ins_batches = [
        jnp.asarray(rng.integers(0, n, size=batch, dtype=np.uint32))
        for _ in range(max(depths))
    ]
    dels = jnp.asarray(rng.integers(0, n, size=64, dtype=np.uint32))

    rows = []
    states_by_mode = {}
    for mode, fused_routing in [("fused", None), ("legacy", False)]:
        table = DistributedHashTable(
            mesh,
            ("d",),
            hash_range=n,
            capacity_slack=2.0,
            max_deltas=max(max(depths), 1),
            fused_routing=fused_routing,
        )
        state = table.init(keys)
        state = state.delete(dels)  # tombstone masking on the timed path
        by_depth = {0: state}
        for i, ins in enumerate(ins_batches):
            state = state.insert(ins)
            by_depth[i + 1] = state
        states_by_mode[mode] = (table, by_depth)

        for depth in depths:
            st = by_depth[depth]
            plan = table.plan_retrieve(st, queries)
            res = plan(st, queries)
            assert int(res.num_dropped) == 0, "benchmark capacity sizing bug"
            sec_q = time_fn(table.query, st, queries, iters=3)
            sec_r = time_fn(plan, st, queries, iters=3)
            row = {
                "mode": mode,
                "depth": depth,
                "layers": depth + 1,
                "query_keys_per_sec": nq / sec_q,
                "retrieve_keys_per_sec": nq / sec_r,
                "query_sec": sec_q,
                "retrieve_sec": sec_r,
            }
            rows.append(row)
            emit(
                "layers",
                sec_r,
                mode=mode,
                depth=depth,
                query_keys_per_sec=f"{nq / sec_q:.3e}",
                retrieve_keys_per_sec=f"{nq / sec_r:.3e}",
            )

    if args.json:
        with open(args.json, "w") as f:
            json.dump(
                {
                    "bench": "layers",
                    "devices": d,
                    "keys": n,
                    "queries": nq,
                    "insert_batch": batch,
                    "rows": rows,
                },
                f,
                indent=2,
            )
        print(f"wrote {args.json}")

    deepest = max(depths)
    if deepest > 0:
        by = {(r["mode"], r["depth"]): r for r in rows}
        fused_ratio = (
            by[("fused", deepest)]["retrieve_sec"]
            / by[("fused", 0)]["retrieve_sec"]
        )
        legacy_ratio = (
            by[("legacy", deepest)]["retrieve_sec"]
            / by[("legacy", 0)]["retrieve_sec"]
        )
        print(
            f"retrieve slowdown at depth {deepest}: fused {fused_ratio:.2f}x, "
            f"legacy {legacy_ratio:.2f}x"
        )

    # Smoke guard (deterministic, unlike CI wall-clock): the fused path's
    # collective count must not grow with the delta depth.
    if args.smoke and deepest > 0:
        from repro.core import plans

        table, by_depth = states_by_mode["fused"]
        a2a = {}
        for depth in (0, deepest):
            jx = jax.make_jaxpr(
                lambda s, q: plans.exec_retrieve(
                    table, s, q, out_capacity=1024, seg_capacity=1024
                )
            )(by_depth[depth], queries)
            a2a[depth] = _count_all_to_all(jx)
        assert a2a[deepest] == a2a[0], (
            f"fused routing regressed: depth-{deepest} retrieve traces "
            f"{a2a[deepest]} all_to_alls vs {a2a[0]} at depth 0"
        )
        print(
            f"smoke: fused retrieve all_to_all count depth-independent "
            f"({a2a[0]} at depth 0 and depth {deepest})"
        )


if __name__ == "__main__":
    main()
