"""Oracle tests for the retrieval subsystem (retrieve / inner_join).

Every test checks against a numpy dict-of-lists oracle: the values stored
under each key, compared per query up to within-key ordering.  Covers
duplicate-heavy and adversarial-collision key distributions, single device
and the 8-way forced-host mesh (see conftest), and the static-capacity
overflow contract (reported, never silent).
"""
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashgraph
from repro.core.multi_hashgraph import ShardJoin, ShardRetrieval
from repro.core.table import (
    DistributedHashTable,
    _join_to_pairs_loop,
    _retrieval_to_lists_loop,
    join_to_pairs,
    retrieval_to_lists,
)


def _oracle(keys, values):
    d = defaultdict(list)
    for k, v in zip(keys.tolist(), values.tolist()):
        d[k].append(v)
    return d


def _dup_heavy(rng, n_base, max_mult, key_range):
    """Duplicate-heavy multiset: each base key repeated 1..max_mult times."""
    base = rng.choice(
        np.arange(key_range, dtype=np.uint32), size=n_base, replace=False
    )
    mult = rng.integers(1, max_mult + 1, size=n_base)
    keys = np.repeat(base, mult)
    rng.shuffle(keys)
    return base, keys


def _assert_retrieval_matches(per_query, queries, oracle):
    for i, k in enumerate(queries):
        got = sorted(np.asarray(per_query[i]).tolist())
        want = sorted(oracle[int(k)])
        assert got == want, f"query {i} (key {int(k)}): {got} != {want}"


# ---------------------------------------------------------------------------
# single-device HashGraph
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("table_size,max_mult", [(1 << 12, 16), (1 << 12, 256)])
def test_retrieve_single_device_duplicates(table_size, max_mult):
    rng = np.random.default_rng(table_size + max_mult)
    base, keys = _dup_heavy(rng, 512, max_mult, 1 << 20)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(
        jnp.asarray(keys), table_size=table_size, values=jnp.asarray(values)
    )
    oracle = _oracle(keys, values)
    queries = np.concatenate(
        [base, rng.integers(0, 1 << 20, size=256, dtype=np.uint32)]
    )
    rng.shuffle(queries)
    total = sum(len(oracle[int(k)]) for k in queries)
    offsets, vals, dropped = hashgraph.retrieve(
        hg, jnp.asarray(queries), capacity=total + 64
    )
    assert int(dropped) == 0
    offsets, vals = np.asarray(offsets), np.asarray(vals)
    per_query = [vals[offsets[i] : offsets[i + 1]] for i in range(len(queries))]
    _assert_retrieval_matches(per_query, queries, oracle)


def test_retrieve_single_device_adversarial_collisions():
    """Every key lands in the same bucket (table_size=1): pure collision chain."""
    rng = np.random.default_rng(7)
    base, keys = _dup_heavy(rng, 64, 32, 1 << 16)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(jnp.asarray(keys), table_size=1, values=jnp.asarray(values))
    oracle = _oracle(keys, values)
    queries = np.concatenate([base, base, rng.integers(0, 1 << 16, size=64, dtype=np.uint32)])
    total = sum(len(oracle[int(k)]) for k in queries)
    offsets, vals, dropped = hashgraph.retrieve(
        hg, jnp.asarray(queries), capacity=total + 8
    )
    assert int(dropped) == 0
    offsets, vals = np.asarray(offsets), np.asarray(vals)
    per_query = [vals[offsets[i] : offsets[i + 1]] for i in range(len(queries))]
    _assert_retrieval_matches(per_query, queries, oracle)


def test_inner_join_single_device_matches_oracle():
    rng = np.random.default_rng(11)
    base, keys = _dup_heavy(rng, 256, 24, 1 << 18)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(jnp.asarray(keys), table_size=512, values=jnp.asarray(values))
    oracle = _oracle(keys, values)
    queries = np.concatenate([base[:200], rng.integers(0, 1 << 18, size=56, dtype=np.uint32)])
    total = sum(len(oracle[int(k)]) for k in queries)
    qidx, vals, num_results, dropped = hashgraph.inner_join(
        hg, jnp.asarray(queries), capacity=total + 16
    )
    assert int(dropped) == 0 and int(num_results) == total
    got = sorted(
        (int(a), int(b))
        for a, b in zip(np.asarray(qidx)[:total], np.asarray(vals)[:total])
    )
    want = sorted(
        (i, v) for i, k in enumerate(queries) for v in oracle[int(k)]
    )
    assert got == want


def test_retrieve_overflow_reported_not_silent():
    rng = np.random.default_rng(13)
    _, keys = _dup_heavy(rng, 128, 8, 1 << 16)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(jnp.asarray(keys), table_size=64, values=jnp.asarray(values))
    queries = jnp.asarray(keys[:256])
    full_counts = np.asarray(hashgraph.query_count_sorted(hg, queries))
    total = int(full_counts.sum())
    cap = max(8, total // 3)
    offsets, vals, dropped = hashgraph.retrieve(hg, queries, capacity=cap)
    assert int(dropped) == total - cap  # exact, not just flagged
    assert int(np.asarray(offsets).max()) <= cap  # CSR stays in bounds
    # the values that *are* emitted are a prefix of the full result stream
    off_full, vals_full, _ = hashgraph.retrieve(hg, queries, capacity=total)
    np.testing.assert_array_equal(
        np.asarray(vals)[:cap], np.asarray(vals_full)[:cap]
    )


# ---------------------------------------------------------------------------
# distributed (8-way forced-host mesh via conftest)
# ---------------------------------------------------------------------------


def _distributed_case(table, rng, n_base, max_mult, key_range, nq):
    base, keys = _dup_heavy(rng, n_base, max_mult, key_range)
    pad = (-len(keys)) % table.num_devices
    if pad:
        keys = np.concatenate([keys, rng.choice(base, size=pad)])
    values = np.arange(len(keys), dtype=np.int32)
    state = table.build(jnp.asarray(keys), values=jnp.asarray(values))
    assert int(state.num_dropped) == 0
    oracle = _oracle(keys, values)
    queries = np.concatenate(
        [
            rng.choice(base, size=nq // 2),
            rng.integers(0, key_range, size=nq - nq // 2).astype(np.uint32),
        ]
    )
    rng.shuffle(queries)
    return state, oracle, queries


def _per_shard_capacity(oracle, queries, num_shards):
    n_local = len(queries) // num_shards
    per_shard = [
        sum(len(oracle[int(k)]) for k in queries[s * n_local : (s + 1) * n_local])
        for s in range(num_shards)
    ]
    return max(8, ((max(per_shard) + 64 + 7) // 8) * 8)


def test_retrieve_mesh8_matches_oracle(mesh8):
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 13)
    rng = np.random.default_rng(17)
    state, oracle, queries = _distributed_case(table, rng, 512, 64, 1 << 20, 2048)
    cap = _per_shard_capacity(oracle, queries, 8)
    res = table.retrieve(
        state, jnp.asarray(queries), out_capacity=cap, seg_capacity=cap
    )
    assert int(res.num_dropped) == 0
    _assert_retrieval_matches(retrieval_to_lists(res), queries, oracle)
    # counts agree with the counting query path
    np.testing.assert_array_equal(
        np.asarray(res.counts), np.asarray(table.query(state, jnp.asarray(queries)))
    )


def test_retrieve_mesh8_adversarial_collisions(mesh8):
    """Tiny hash range: every key collides into a handful of buckets and the
    balanced split degenerates — retrieval must still be exact."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=4, capacity_slack=16.0, range_slack=4.0
    )
    rng = np.random.default_rng(19)
    state, oracle, queries = _distributed_case(table, rng, 64, 16, 1 << 12, 512)
    cap = _per_shard_capacity(oracle, queries, 8)
    res = table.retrieve(
        state, jnp.asarray(queries), out_capacity=cap, seg_capacity=cap
    )
    assert int(res.num_dropped) == 0
    _assert_retrieval_matches(retrieval_to_lists(res), queries, oracle)


def test_inner_join_mesh8_matches_oracle(mesh8):
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(23)
    state, oracle, queries = _distributed_case(table, rng, 256, 32, 1 << 18, 1024)
    cap = _per_shard_capacity(oracle, queries, 8)
    join = table.inner_join(
        state, jnp.asarray(queries), out_capacity=cap, seg_capacity=cap
    )
    assert int(join.num_dropped) == 0
    got = sorted(map(tuple, join_to_pairs(join).tolist()))
    want = sorted((i, v) for i, k in enumerate(queries) for v in oracle[int(k)])
    assert got == want


def test_retrieve_mesh8_overflow_reported(mesh8):
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, capacity_slack=2.0
    )
    rng = np.random.default_rng(29)
    state, oracle, queries = _distributed_case(table, rng, 256, 32, 1 << 18, 1024)
    res = table.retrieve(state, jnp.asarray(queries), out_capacity=8, seg_capacity=8)
    assert int(res.num_dropped) > 0


def test_retrieve_mesh1_degenerate(mesh1):
    """Distributed path on a single-device mesh == single-device semantics."""
    table = DistributedHashTable(mesh1, ("d",), hash_range=1 << 10)
    rng = np.random.default_rng(31)
    state, oracle, queries = _distributed_case(table, rng, 128, 16, 1 << 16, 256)
    cap = _per_shard_capacity(oracle, queries, 1)
    res = table.retrieve(
        state, jnp.asarray(queries), out_capacity=cap, seg_capacity=cap
    )
    assert int(res.num_dropped) == 0
    _assert_retrieval_matches(retrieval_to_lists(res), queries, oracle)


# ---------------------------------------------------------------------------
# acceptance scale: >= 1M keys, multiplicities up to 1024
# ---------------------------------------------------------------------------


def _million_key_multiset(rng):
    """>=1M keys: 4096 distinct keys with multiplicities 1..1024 (E ~ 2.1M)."""
    base = rng.choice(np.arange(1 << 24, dtype=np.uint32), size=4096, replace=False)
    mult = rng.integers(1, 1025, size=4096)
    keys = np.repeat(base, mult)
    rng.shuffle(keys)
    return base, keys


@pytest.mark.slow
def test_retrieve_1m_keys_single_device():
    rng = np.random.default_rng(101)
    base, keys = _million_key_multiset(rng)
    assert len(keys) >= 1 << 20
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(
        jnp.asarray(keys), table_size=1 << 18, values=jnp.asarray(values)
    )
    oracle = _oracle(keys, values)
    # probe a sample of hits + misses; verify each against the oracle exactly
    queries = np.concatenate(
        [
            rng.choice(base, size=512),
            rng.integers(1 << 24, 1 << 25, size=512).astype(np.uint32),
        ]
    )
    total = sum(len(oracle[int(k)]) for k in queries)
    offsets, vals, dropped = hashgraph.retrieve(
        hg, jnp.asarray(queries), capacity=((total + 63) // 8) * 8
    )
    assert int(dropped) == 0
    offsets, vals = np.asarray(offsets), np.asarray(vals)
    per_query = [vals[offsets[i] : offsets[i + 1]] for i in range(len(queries))]
    _assert_retrieval_matches(per_query, queries, oracle)


@pytest.mark.slow
def test_retrieve_1m_keys_mesh8(mesh8):
    rng = np.random.default_rng(103)
    base, keys = _million_key_multiset(rng)
    pad = (-len(keys)) % 8
    if pad:
        keys = np.concatenate([keys, rng.choice(base, size=pad)])
    values = np.arange(len(keys), dtype=np.int32)
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 18, capacity_slack=2.0
    )
    state = table.build(jnp.asarray(keys), values=jnp.asarray(values))
    assert int(state.num_dropped) == 0
    oracle = _oracle(keys, values)
    queries = np.concatenate(
        [
            rng.choice(base, size=512),
            rng.integers(1 << 24, 1 << 25, size=512).astype(np.uint32),
        ]
    )
    rng.shuffle(queries)
    cap = _per_shard_capacity(oracle, queries, 8)
    res = table.retrieve(
        state, jnp.asarray(queries), out_capacity=cap, seg_capacity=cap
    )
    assert int(res.num_dropped) == 0
    _assert_retrieval_matches(retrieval_to_lists(res), queries, oracle)
    np.testing.assert_array_equal(
        np.asarray(res.counts), np.asarray(table.query(state, jnp.asarray(queries)))
    )


# ---------------------------------------------------------------------------
# vectorized host-side views: parity against the original per-query loops
# ---------------------------------------------------------------------------


def _random_shard_retrieval(rng, d, n_local, out_cap, cols=None, clamp=False):
    """Synthesize a structurally-valid ShardRetrieval (global-view arrays)."""
    offsets, counts, values = [], [], []
    for _ in range(d):
        c = rng.integers(0, 4, size=n_local).astype(np.int32)
        off = np.concatenate([[0], np.cumsum(c)]).astype(np.int32)
        if clamp:
            off = np.minimum(off, out_cap)
        vshape = (out_cap,) if cols is None else (out_cap, cols)
        v = rng.integers(0, 1000, size=vshape).astype(np.int32)
        offsets.append(off)
        counts.append(c)
        values.append(v)
    return ShardRetrieval(
        offsets=jnp.asarray(np.concatenate(offsets)),
        values=jnp.asarray(np.concatenate(values, axis=0)),
        counts=jnp.asarray(np.concatenate(counts)),
        num_dropped=jnp.int32(0),
    )


@pytest.mark.parametrize("cols", [None, 3])
@pytest.mark.parametrize("clamp", [False, True])
def test_retrieval_to_lists_vectorized_parity(cols, clamp):
    rng = np.random.default_rng(5 + (cols or 0) + clamp)
    res = _random_shard_retrieval(rng, d=4, n_local=13, out_cap=32, cols=cols, clamp=clamp)
    got = retrieval_to_lists(res)
    want = _retrieval_to_lists_loop(res)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("cols", [None, 2])
@pytest.mark.parametrize("empty", [False, True])
def test_join_to_pairs_vectorized_parity(cols, empty):
    rng = np.random.default_rng(9 + (cols or 0) + empty)
    d, out_cap = 4, 24
    nres = (
        np.zeros(d, np.int32)
        if empty
        else rng.integers(0, out_cap + 1, size=d).astype(np.int32)
    )
    vshape = (d * out_cap,) if cols is None else (d * out_cap, cols)
    res = ShardJoin(
        query_idx=jnp.asarray(rng.integers(0, 100, size=d * out_cap).astype(np.int32)),
        values=jnp.asarray(rng.integers(0, 1000, size=vshape).astype(np.int32)),
        num_results=jnp.asarray(nres),
        num_dropped=jnp.int32(0),
    )
    got = join_to_pairs(res)
    want = _join_to_pairs_loop(res)
    np.testing.assert_array_equal(got, want)
    assert got.dtype == want.dtype == np.int32
