"""Data substrate tests: synthetic determinism, packing, dedup, loader."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data import (
    LoaderState,
    ShardedLoader,
    SyntheticCorpus,
    dedup_mask,
    pack_documents,
    sequence_fingerprints,
)
from repro.data.packing import packing_efficiency


def test_synthetic_batches_are_pure_functions_of_step():
    c = SyntheticCorpus(vocab_size=1000, seq_len=32, seed=5, dup_rate=0.2)
    a = np.asarray(c.batch(7, 16))
    b = np.asarray(c.batch(7, 16))
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, np.asarray(c.batch(8, 16)))
    assert a.shape == (16, 33)
    assert a.min() >= 0 and a.max() < 1000


def test_synthetic_dup_rate_injects_duplicates():
    c = SyntheticCorpus(vocab_size=10_000, seq_len=64, seed=1, dup_rate=0.5)
    toks = np.asarray(c.batch(0, 64))
    fp = np.asarray(sequence_fingerprints(jnp.asarray(toks[:, :-1])))
    assert len(np.unique(fp)) < 64  # some rows cloned


@settings(max_examples=25, deadline=None)
@given(
    lengths=st.lists(st.integers(0, 40), min_size=1, max_size=30),
    seq_len=st.integers(8, 64),
)
def test_packing_preserves_tokens(lengths, seq_len):
    rng = np.random.default_rng(0)
    max_len = max(max(lengths), 1)
    docs = rng.integers(1, 100, size=(len(lengths), max_len)).astype(np.int32)
    lens = np.array(lengths, np.int32)
    rows, segs = pack_documents(docs, lens, seq_len)
    # every non-padding token appears exactly once, in order per doc
    out_tokens = rows[segs > 0]
    expect = np.concatenate(
        [docs[i, : min(l, seq_len)] for i, l in enumerate(lengths) if l > 0]
    ) if any(l > 0 for l in lengths) else np.array([], np.int32)
    np.testing.assert_array_equal(out_tokens, expect)
    # segment ids are per-row contiguous starting at 1
    for r in range(rows.shape[0]):
        seg = segs[r][segs[r] > 0]
        if len(seg):
            uniq = np.unique(seg)
            np.testing.assert_array_equal(uniq, np.arange(1, len(uniq) + 1))
    if rows.size:
        assert 0.0 < packing_efficiency(segs) <= 1.0


def test_dedup_mask_keeps_first_occurrence_only():
    base = np.arange(10_000, 10_000 + 8 * 16, dtype=np.int32).reshape(8, 16)
    toks = np.concatenate([base, base[:3]])  # rows 8,9,10 duplicate 0,1,2
    keep = np.asarray(dedup_mask(jnp.asarray(toks)))
    np.testing.assert_array_equal(keep[:8], True)
    np.testing.assert_array_equal(keep[8:], False)


@settings(max_examples=20, deadline=None)
@given(perm=st.permutations(list(range(6))))
def test_dedup_mask_first_occurrence_under_permutation(perm):
    rows = np.array(
        [[1, 2, 3], [4, 5, 6], [1, 2, 3], [7, 8, 9], [4, 5, 6], [1, 2, 3]],
        np.int32,
    )[list(perm)]
    keep = np.asarray(dedup_mask(jnp.asarray(rows)))
    seen = set()
    expect = []
    for r in rows:
        t = tuple(r.tolist())
        expect.append(t not in seen)
        seen.add(t)
    np.testing.assert_array_equal(keep, np.array(expect))


def test_loader_resume_is_exact():
    c = SyntheticCorpus(vocab_size=500, seq_len=16, seed=2)
    l1 = ShardedLoader(c, batch_size=4)
    batches = [np.asarray(l1.next_batch()["tokens"]) for _ in range(5)]
    l2 = ShardedLoader(c, batch_size=4)
    l2.skip_to(3)
    np.testing.assert_array_equal(np.asarray(l2.next_batch()["tokens"]), batches[3])
    np.testing.assert_array_equal(np.asarray(l2.next_batch()["tokens"]), batches[4])


def test_loader_dedup_replaces_duplicates_keeps_shape():
    c = SyntheticCorpus(vocab_size=50_000, seq_len=32, seed=3, dup_rate=0.5)
    l = ShardedLoader(c, batch_size=32, dedup="local")
    toks = np.asarray(l.next_batch()["tokens"])
    assert toks.shape == (32, 33)
    fp = np.asarray(sequence_fingerprints(jnp.asarray(toks[:, :-1])))
    assert len(np.unique(fp)) == 32  # all rows unique post-dedup


def test_loader_state_roundtrip():
    s = LoaderState(step=42)
    assert LoaderState.restore(s.checkpoint_payload()).step == 42
