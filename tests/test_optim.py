"""Optimizer substrate tests: AdamW, schedules, clip, int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    dequantize_int8,
    error_feedback_compress,
    quantize_int8,
    warmup_cosine,
    warmup_linear,
)


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(weight_decay=0.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros((3, 1))}
    state = adamw_init(params, cfg)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum((q["w"][:, 0] - target) ** 2))(p)
        return adamw_update(p, g, s, jnp.float32(0.05), cfg)

    for _ in range(300):
        params, state = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"][:, 0]), np.asarray(target), atol=1e-2)
    assert int(state["step"]) == 300


def test_adamw_bf16_moments_track_f32():
    cfg32 = AdamWConfig(moment_dtype="float32", weight_decay=0.0)
    cfg16 = AdamWConfig(moment_dtype="bfloat16", weight_decay=0.0)
    params = {"w": jnp.ones((8, 8))}
    g = {"w": jnp.full((8, 8), 0.1)}
    s32, s16 = adamw_init(params, cfg32), adamw_init(params, cfg16)
    p32, p16 = params, params
    for _ in range(10):
        p32, s32 = adamw_update(p32, g, s32, jnp.float32(0.01), cfg32)
        p16, s16 = adamw_update(p16, g, s16, jnp.float32(0.01), cfg16)
    np.testing.assert_allclose(
        np.asarray(p32["w"]), np.asarray(p16["w"]), rtol=0.03, atol=3e-3
    )
    assert s16["m"]["w"].dtype == jnp.bfloat16


def test_weight_decay_applies_to_matrices_not_vectors():
    cfg = AdamWConfig(weight_decay=0.5)
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    zero_g = jax.tree.map(jnp.zeros_like, params)
    state = adamw_init(params, cfg)
    p2, _ = adamw_update(params, zero_g, state, jnp.float32(0.1), cfg)
    assert float(p2["w"][0, 0]) < 1.0  # decayed
    assert float(p2["b"][0]) == 1.0  # vectors exempt


def test_schedules():
    lr = warmup_cosine(jnp.int32(0), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr) == 0.0
    lr = warmup_cosine(jnp.int32(10), peak_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr) == pytest.approx(1.0)
    lr_end = warmup_cosine(
        jnp.int32(100), peak_lr=1.0, warmup_steps=10, total_steps=100, floor=0.1
    )
    assert float(lr_end) == pytest.approx(0.1, abs=1e-6)
    lin = warmup_linear(jnp.int32(55), peak_lr=2.0, warmup_steps=10, total_steps=100)
    assert 0.0 < float(lin) <= 2.0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    from repro.utils import tree_global_norm

    assert float(norm) == pytest.approx(np.sqrt(10 * 9 + 10 * 16), rel=1e-6)
    assert float(tree_global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the cap → untouched
    same, _ = clip_by_global_norm(g, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(g["a"]))


@settings(max_examples=30, deadline=None)
@given(
    vals=st.lists(
        st.floats(-100, 100, allow_nan=False, width=32), min_size=1, max_size=64
    )
)
def test_quantize_roundtrip_error_bound(vals):
    x = jnp.asarray(np.array(vals, np.float32))
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    amax = float(jnp.max(jnp.abs(x)))
    # symmetric int8: error ≤ scale/2 = amax/254 per element
    assert float(jnp.max(jnp.abs(deq - x))) <= amax / 254 + 1e-7
    assert q.dtype == jnp.int8


def test_error_feedback_is_lossless_in_aggregate():
    """Σ_t transmitted_t = Σ_t g_t - e_T: the residual never exceeds one
    quantization step, so EF-SGD sees an unbiased gradient stream."""
    rng = np.random.default_rng(0)
    g_stream = [jnp.asarray(rng.standard_normal(32), jnp.float32) for _ in range(50)]
    err = {"w": jnp.zeros(32)}
    sent_total = np.zeros(32)
    for g in g_stream:
        sent, err = error_feedback_compress({"w": g}, err)
        sent_total += np.asarray(sent["w"])
    g_total = np.sum([np.asarray(g) for g in g_stream], axis=0)
    resid = np.abs(g_total - sent_total)
    # residual equals the final error buffer — bounded by one quant step
    np.testing.assert_allclose(resid, np.abs(np.asarray(err["w"])), atol=1e-5)
    assert resid.max() < 0.05


def test_compressed_step_close_to_exact_step():
    cfg = AdamWConfig(weight_decay=0.0)
    params = {"w": jnp.ones((16,))}
    g = {"w": jnp.asarray(np.random.default_rng(1).standard_normal(16), jnp.float32)}
    state = adamw_init(params, cfg)
    p_exact, _ = adamw_update(params, g, state, jnp.float32(0.01), cfg)
    sent, _ = error_feedback_compress(g, {"w": jnp.zeros(16)})
    p_comp, _ = adamw_update(params, sent, adamw_init(params, cfg), jnp.float32(0.01), cfg)
    np.testing.assert_allclose(
        np.asarray(p_exact["w"]), np.asarray(p_comp["w"]), atol=5e-3
    )
