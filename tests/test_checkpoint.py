"""Checkpoint manager semantics: atomicity, async, retention, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "opt": {"step": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    t = _tree()
    m.save(10, t, extra={"loader_step": 10})
    step, got, extra = m.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 10 and extra["loader_step"] == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_writer_and_wait(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=True)
    for s in (1, 2, 3):
        m.save(s, _tree(s))
    m.wait()
    assert m.all_steps() == [1, 2, 3]
    m.close()


def test_retention_keeps_newest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in range(5):
        m.save(s, _tree(s))
    assert m.all_steps() == [3, 4]


def test_atomic_no_tmp_dirs_after_save(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    m.save(1, _tree())
    assert not [p for p in os.listdir(tmp_path) if p.endswith(".tmp")]


def test_restore_latest_and_specific(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    m.save(1, _tree(1))
    m.save(5, _tree(5))
    like = jax.tree.map(jnp.zeros_like, _tree())
    assert m.restore(like)[0] == 5
    assert m.restore(like, step=1)[0] == 1


def test_tree_mismatch_raises(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    m.save(1, _tree())
    with pytest.raises(ValueError, match="mismatch"):
        m.restore({"different": jnp.zeros(3)})


def test_missing_checkpoint_raises(tmp_path):
    m = CheckpointManager(str(tmp_path), async_write=False)
    with pytest.raises(FileNotFoundError):
        m.restore({"x": jnp.zeros(1)})


def test_elastic_restore_resharding(tmp_path):
    """Arrays saved from one layout restore onto explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    m = CheckpointManager(str(tmp_path), async_write=False)
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    m.save(3, t)
    mesh = jax.make_mesh((1,), ("d",))
    sh = {"w": NamedSharding(mesh, P("d", None))}
    _, got, _ = m.restore(jax.tree.map(jnp.zeros_like, t), shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))
    assert got["w"].sharding == sh["w"]
