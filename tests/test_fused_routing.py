"""Single-route layered execution — collective-count regression + parity.

The tentpole invariant of the fused path: a partition-coherent delta stack
(every delta built on the base's frozen ``hash_splits``) executes query /
retrieve / plan in ONE exchange round regardless of delta depth — one
query-dispatch all-to-all plus one fused ragged return — where the
per-layer legacy path pays one round per layer.

* ``test_collective_count_regression`` counts ``all_to_all`` primitives in
  the traced executors: an L=4-layer retrieve must contain exactly one
  dispatch and one ragged return (2 collectives) on the fused path vs 2·L
  on the legacy path — so a routing-round regression fails loudly in CI.
* The parity grid runs identical mutation histories (including
  delete-then-reinsert epochs) through the fused path, the forced-legacy
  path on the same coherent state, and a mixed-split legacy stack
  (``coherent_deltas=False``, exercising the fallback), across
  uint32/uint64 keys × 1/2 value columns on mesh1 and mesh8.
"""
import jax
import jax.core as jcore
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plans
from repro.core.schema import TableSchema
from repro.core.table import (
    DistributedHashTable,
    join_to_pairs,
    retrieval_to_lists,
)
from test_table_state import Oracle, _keys_for, _value_rows, _values_for

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


# ---------------------------------------------------------------------------
# jaxpr collective counting
# ---------------------------------------------------------------------------


def _iter_jaxprs(v):
    if isinstance(v, jcore.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jcore.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _iter_jaxprs(x)


def count_primitive(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` anywhere in a (nested) jaxpr."""
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in _iter_jaxprs(v):
                n += count_primitive(sub, name)
    return n


def _four_layer_state(table, rng):
    """base + 3 deltas = an L=4 layer stack with tombstones."""
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    for _ in range(3):
        state = state.insert(
            jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32))
        )
    state = state.delete(jnp.asarray(keys[:16]))
    return state


def test_collective_count_regression(mesh8):
    """L=4 retrieve: ONE dispatch a2a + ONE ragged return, depth-independent.

    The legacy per-layer path pays 2 collectives per layer; the fused path
    must stay at 2 total (the acceptance bound of the single-route issue).
    Query likewise: 2 fused vs 2·L legacy.
    """
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.integers(0, 1 << 14, 128, dtype=np.uint32))

    fused_t = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    legacy_t = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, fused_routing=False
    )
    nlayers = 4
    for table, want_per_op in [(fused_t, 2), (legacy_t, 2 * nlayers)]:
        state = _four_layer_state(table, np.random.default_rng(5))
        assert len(state.layers) == nlayers

        jx = jax.make_jaxpr(
            lambda s, qq, t=table: plans.exec_retrieve(
                t, s, qq, out_capacity=2048, seg_capacity=2048
            )
        )(state, q)
        assert count_primitive(jx.jaxpr, "all_to_all") == want_per_op

        jq = jax.make_jaxpr(
            lambda s, qq, t=table: plans.exec_query(t, s, qq)
        )(state, q)
        assert count_primitive(jq.jaxpr, "all_to_all") == want_per_op

    # The planning counts round is also single-route on the fused path.
    state = _four_layer_state(fused_t, np.random.default_rng(5))
    jp = jax.make_jaxpr(lambda s, qq: plans.exec_plan_caps(fused_t, s, qq))(
        state, q
    )
    assert count_primitive(jp.jaxpr, "all_to_all") == 1  # dispatch only


def test_depth_independence_of_collective_count(mesh8):
    """Fused collective count is flat in L: identical at 1, 2, 4, 8 layers."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, max_deltas=8)
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.integers(0, 1 << 14, 128, dtype=np.uint32))
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 512, dtype=np.uint32)))
    counts = []
    for depth in range(8):
        if len(state.layers) in (1, 2, 4, 8):
            jx = jax.make_jaxpr(
                lambda s, qq: plans.exec_retrieve(
                    table, s, qq, out_capacity=2048, seg_capacity=2048
                )
            )(state, q)
            counts.append(count_primitive(jx.jaxpr, "all_to_all"))
        state = state.insert(
            jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32))
        )
    assert counts == [2, 2, 2, 2]


# ---------------------------------------------------------------------------
# fused vs legacy parity
# ---------------------------------------------------------------------------


def _mutation_history(table, schema, rng, d):
    """build → insert → delete → reinsert, mirrored into an oracle."""
    n = 256
    keys = _keys_for(schema, rng, n)
    vals = _values_for(schema, 0, n)
    oracle = Oracle()
    oracle.insert(keys, vals)
    state = table.init(table.schema.pack_keys(keys), values=jnp.asarray(vals))

    ins = _keys_for(schema, rng, 8 * d, lo=1 << 16, hi=1 << 17)
    ins_vals = _values_for(schema, 10_000, len(ins))
    state = state.insert(table.schema.pack_keys(ins), jnp.asarray(ins_vals))
    oracle.insert(ins, ins_vals)

    dels = np.concatenate([keys[:16], ins[: 2 * d]])
    state = state.delete(table.schema.pack_keys(dels))
    oracle.delete(dels)

    # delete-then-reinsert: later epochs stay visible through the tombstones
    re_keys = keys[:8]
    re_vals = _values_for(schema, 20_000, len(re_keys))
    state = state.insert(table.schema.pack_keys(re_keys), jnp.asarray(re_vals))
    oracle.insert(re_keys, re_vals)

    queries = np.concatenate([keys[:64], ins[: 2 * d], _keys_for(schema, rng, 2 * d)])
    return state, oracle, queries


def _observe(table, state, queries):
    q = table.schema.pack_keys(queries)
    counts = np.asarray(table.query(state, q)).tolist()
    res = table.retrieve(state, q, out_capacity=4096, seg_capacity=4096)
    assert int(res.num_dropped) == 0
    lists = [
        sorted(_value_rows(np.asarray(v)), key=repr)
        for v in retrieval_to_lists(res)
    ]
    join = table.inner_join(state, q, out_capacity=4096, seg_capacity=4096)
    pairs = sorted(map(tuple, join_to_pairs(join).tolist()))
    return counts, lists, pairs, int(table.join_size(state, q))


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_fused_vs_legacy_parity(schema, meshname, request):
    """Identical mutation history through three routings, one oracle.

    1. fused single-route on a coherent stack (the default),
    2. forced per-layer legacy on the SAME coherent state
       (``fused_routing=False``),
    3. a mixed-split legacy stack (``coherent_deltas=False``) exercising
       the automatic fallback.
    All three must agree with each other and the oracle.
    """
    mesh = request.getfixturevalue(meshname)
    d = 8 if meshname == "mesh8" else 1
    variants = {
        "fused": {},
        "forced-legacy": {"fused_routing": False},
        "mixed-splits": {"coherent_deltas": False},
    }
    observed = {}
    for label, kw in variants.items():
        table = DistributedHashTable(
            mesh, ("d",), hash_range=1 << 12, schema=schema, **kw
        )
        rng = np.random.default_rng(17 + d + schema.value_cols)
        state, oracle, queries = _mutation_history(table, schema, rng, d)
        assert state.coherent == (label != "mixed-splits")
        counts, lists, pairs, jsize = _observe(table, state, queries)
        want = [oracle.count(k) for k in queries]
        assert counts == want, label
        for i, k in enumerate(queries):
            assert lists[i] == oracle.values(k), f"{label}: query {i}"
        observed[label] = (counts, lists, pairs, jsize)
    assert observed["fused"] == observed["forced-legacy"]
    assert observed["fused"] == observed["mixed-splits"]


def test_mixed_split_stack_uses_per_layer_routing(mesh8):
    """The mixed-split fallback really is per-layer: 2·L collectives."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, coherent_deltas=False
    )
    rng = np.random.default_rng(23)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 512, dtype=np.uint32)))
    for _ in range(2):
        state = state.insert(
            jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32))
        )
    assert not state.coherent
    q = jnp.asarray(rng.integers(0, 1 << 14, 128, dtype=np.uint32))
    jx = jax.make_jaxpr(
        lambda s, qq: plans.exec_retrieve(
            table, s, qq, out_capacity=2048, seg_capacity=2048
        )
    )(state, q)
    assert count_primitive(jx.jaxpr, "all_to_all") == 2 * len(state.layers)


def test_fused_plan_caps_are_exact(mesh8):
    """Fused planning sizes the fused execution with zero drops and an
    exactly-sized output CSR, tombstones included."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(29)
    state = _four_layer_state(table, rng)
    queries = jnp.asarray(rng.integers(0, 1 << 14, 256, dtype=np.uint32))
    res = table.retrieve(state, queries)  # planned caps (fused counts round)
    assert int(res.num_dropped) == 0
    want = np.asarray(table.query(state, queries))
    np.testing.assert_array_equal(np.asarray(res.counts), want)
    # out_capacity is the lane-rounded exact per-device maximum
    seg, out = table.plan_caps(state, queries)
    assert res.values.shape[0] // 8 == max(8, -(-out // 8) * 8)


def test_per_layer_counts_parity_and_collective_budget(mesh8):
    """retrieve(per_layer_counts=True): fused == legacy breakdown, row sums
    equal the merged counts, and the fused path STILL costs exactly 2
    all-to-alls — the breakdown rides the bitcast return buffer, not a
    second round (the ROADMAP "fused return payload packing" item)."""
    rng = np.random.default_rng(41)
    q = jnp.asarray(rng.integers(0, 1 << 14, 128, dtype=np.uint32))
    fused_t = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    legacy_t = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, fused_routing=False
    )
    state_f = _four_layer_state(fused_t, np.random.default_rng(41))
    state_l = _four_layer_state(legacy_t, np.random.default_rng(41))

    res_f = fused_t.retrieve(
        state_f, q, out_capacity=4096, seg_capacity=4096, per_layer_counts=True
    )
    res_l = legacy_t.retrieve(
        state_l, q, out_capacity=4096, seg_capacity=4096, per_layer_counts=True
    )
    assert res_f.layer_counts.shape == (128, 4)
    np.testing.assert_array_equal(
        np.asarray(res_f.layer_counts), np.asarray(res_l.layer_counts)
    )
    np.testing.assert_array_equal(
        np.asarray(res_f.layer_counts).sum(axis=1), np.asarray(res_f.counts)
    )
    # tombstoned rows contribute zero to their layer's column
    deleted = np.asarray(
        fused_t.query(state_f, q)
    )  # merged counts already exclude them
    np.testing.assert_array_equal(np.asarray(res_f.counts), deleted)

    # the provenance is layer-exact: a key inserted only in delta 2 shows
    # its count in column 2 and nowhere else
    fresh = jnp.asarray(rng.integers(1 << 15, 1 << 16, 16, dtype=np.uint32))
    s2 = fused_t.init(jnp.asarray(rng.integers(0, 1 << 14, 512, dtype=np.uint32)))
    s2 = s2.insert(jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32)))
    s2 = s2.insert(fresh)
    r2 = fused_t.retrieve(
        s2, jnp.concatenate([fresh, fresh]), out_capacity=512,
        seg_capacity=512, per_layer_counts=True,
    )
    lc = np.asarray(r2.layer_counts)
    assert (lc[:, 2] >= 1).all() and (lc[:, :2].sum() == 0)

    # collective budget unchanged: 2 (dispatch + fused ragged return)
    jx = jax.make_jaxpr(
        lambda s, qq: plans.exec_retrieve(
            fused_t, s, qq, out_capacity=2048, seg_capacity=2048,
            per_layer_counts=True,
        )
    )(state_f, q)
    assert count_primitive(jx.jaxpr, "all_to_all") == 2


def test_coherent_delta_geometry_is_small(mesh8):
    """Coherent deltas stride the base's bucket map: a small insert must not
    pay the base's O(hash_range / D) offsets array."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 16)
    rng = np.random.default_rng(31)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 16, 4096, dtype=np.uint32)))
    state = state.insert(jnp.asarray(rng.integers(0, 1 << 16, 64, dtype=np.uint32)))
    delta = state.deltas[0]
    assert delta.bucket_stride > 1
    assert delta.local_range_cap * 8 < state.base.local_range_cap
    # global offsets array: D * (local_range_cap + 2) rows
    assert delta.local.offsets.shape[0] < state.base.local.offsets.shape[0] // 8
