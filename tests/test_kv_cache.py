"""KV-cache subsystem — upsert/TTL/eviction oracles, YCSB generator, hot keys.

The cache contract on top of the multiset core:

* **upsert** is read-your-writes and last-writer-wins: after
  ``upsert(state, keys, values)`` every key counts exactly 1 and
  retrieves exactly its newest value — across schema widths, mesh sizes,
  duplicate-heavy batches, and fold/compact boundaries.
* **TTL** expires *exactly* at the deadline epoch: a row put with
  ``ttl=T`` at clock ``t`` is visible through ``t+T-1`` and gone at
  ``t+T``, whichever side of a fold/compact the expiry is observed from.
* **Eviction reclaims capacity**: a steady upsert+expire stream through
  :class:`KVCache` holds both the live count and the allocated rows flat
  (the policy's expired-load escalation folds expired rows out of the
  base instead of growing it forever).
* Reads over TTL'd state stay on the **fused 2-all-to-all** plan (jaxpr
  asserted) — cache semantics never add collective rounds.
* **Hot-key replication** (``replicate_hot_keys``) spreads a zipfian
  hot key's rows across destination shards with zero dropped rows and
  exact merged counts at YCSB skew (theta = 0.99).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import KVCache, WORKLOADS, YCSBWorkload, ZipfianGenerator, key_of
from repro.core import maintenance, plans
from repro.core.maintenance import CompactionPolicy, fold_oldest
from repro.core.schema import TableSchema
from repro.core.table import DistributedHashTable, retrieval_to_lists
from test_fused_routing import count_primitive
from test_table_state import _keys_for, _value_rows, _values_for

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


def _table(mesh, d, schema=None, **kw):
    kw.setdefault("hash_range", 1 << 12)
    if schema is not None:
        kw["schema"] = schema
    return DistributedHashTable(mesh, ("d",), **kw)


def _values_of(table, state, queries):
    """Per-query value rows via retrieve (KV reads: at most one per key)."""
    q = table.schema.pack_keys(queries)
    res = table.retrieve(state, q, out_capacity=4096, seg_capacity=4096)
    assert int(res.num_dropped) == 0
    return [
        _value_rows(np.asarray(v)) for v in retrieval_to_lists(res)
    ]


# ---------------------------------------------------------------------------
# upsert: read-your-writes + last-writer-wins
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_upsert_read_your_writes_last_writer_wins(schema, meshname, request):
    mesh = request.getfixturevalue(meshname)
    d = 8 if meshname == "mesh8" else 1
    table = _table(mesh, d, schema)
    rng = np.random.default_rng(17 + d + schema.value_cols)

    base_keys = _keys_for(schema, rng, 64)
    base_keys = np.unique(base_keys)
    state = table.init(
        table.schema.pack_keys(base_keys),
        jnp.asarray(_values_for(schema, 0, len(base_keys))),
    )

    # Overwrite half the existing keys + introduce fresh ones, with
    # in-batch duplicates: the LAST occurrence must win.
    old = base_keys[: len(base_keys) // 2]
    fresh = _keys_for(schema, rng, 16, lo=1 << 17, hi=1 << 18)
    fresh = np.unique(fresh)
    up_keys = np.concatenate([old, fresh, old])  # old repeated: dup batch
    up_vals = _values_for(schema, 10_000, len(up_keys))
    state = table.upsert(state, table.schema.pack_keys(up_keys), jnp.asarray(up_vals))

    queries = np.concatenate([base_keys, fresh])
    counts = np.asarray(table.query(state, table.schema.pack_keys(queries)))
    np.testing.assert_array_equal(counts, np.ones(len(queries), np.int32))

    expect = {}
    for k, v in zip(base_keys.tolist(), _value_rows(_values_for(schema, 0, len(base_keys)))):
        expect[int(k)] = v
    for k, v in zip(up_keys.tolist(), _value_rows(up_vals)):
        expect[int(k)] = v  # later occurrence overwrites: keep-last
    got = _values_of(table, state, queries)
    for k, vals in zip(queries.tolist(), got):
        assert vals == [expect[int(k)]], f"key {k}"

    # Read-your-writes composes: a second upsert over the same keys wins
    # again, and the result survives a fold and a full compact unchanged.
    up2_vals = _values_for(schema, 50_000, len(queries))
    state = table.upsert(state, table.schema.pack_keys(queries), jnp.asarray(up2_vals))
    want2 = [[v] for v in _value_rows(up2_vals)]
    for st in (state, fold_oldest(state, 1), state.compact()):
        counts = np.asarray(table.query(st, table.schema.pack_keys(queries)))
        np.testing.assert_array_equal(counts, np.ones(len(queries), np.int32))
        assert _values_of(table, st, queries) == want2


# ---------------------------------------------------------------------------
# TTL: expiry exactly at the deadline epoch, across fold boundaries
# ---------------------------------------------------------------------------
def test_ttl_expires_exactly_at_boundary(mesh8):
    table = _table(mesh8, 8)
    keys = np.arange(1, 33, dtype=np.uint32)
    state = table.init(jnp.asarray(keys), jnp.asarray(np.arange(32, dtype=np.int32)))

    ttl_keys = keys[:8]
    state = table.upsert(
        state, jnp.asarray(ttl_keys), jnp.asarray(np.arange(8, dtype=np.int32)), ttl=5
    )
    q = jnp.asarray(keys)
    for now in (0, 4):  # visible strictly before the deadline
        counts = np.asarray(table.query(state.advance(now), q))
        np.testing.assert_array_equal(counts, np.ones(32, np.int32))
    for now in (5, 9):  # gone exactly at (and after) the deadline
        counts = np.asarray(table.query(state.advance(now), q))
        want = np.ones(32, np.int32)
        want[:8] = 0
        np.testing.assert_array_equal(counts, want)
    # the clock is data, not structure: advancing must not retrace
    jx = jax.make_jaxpr(lambda s, qq: plans.exec_query(table, s, qq))(state, q)
    assert count_primitive(jx.jaxpr, "all_to_all") == 2


def test_delete_upsert_expire_across_fold_boundary(mesh8):
    """delete -> upsert(ttl) -> fold_oldest straddling the tombstones."""
    table = _table(mesh8, 8)
    keys = np.arange(1, 65, dtype=np.uint32)
    state = table.init(jnp.asarray(keys), jnp.asarray(np.arange(64, dtype=np.int32)))

    victim = keys[:8]
    state = table.delete(state, jnp.asarray(victim))
    state = table.upsert(
        state,
        jnp.asarray(victim),
        jnp.asarray(np.arange(100, 108, dtype=np.int32)),
        ttl=3,
    )
    # pad the ring so a fold of 2 straddles the delete+upsert epochs
    filler = np.arange(1 << 10, (1 << 10) + 16, dtype=np.uint32)
    state = state.insert(jnp.asarray(filler), jnp.asarray(np.arange(16, dtype=np.int32)))

    q = jnp.asarray(victim)
    variants = {
        "unfolded": state,
        "fold1": fold_oldest(state, 1),
        "fold2": fold_oldest(state, 2),
        "compact": state.compact(),
    }
    for name, st in variants.items():
        alive = np.asarray(table.query(st.advance(2), q))
        np.testing.assert_array_equal(
            alive, np.ones(8, np.int32), err_msg=f"{name}: visible before expiry"
        )
        dead = np.asarray(table.query(st.advance(3), q))
        np.testing.assert_array_equal(
            dead, np.zeros(8, np.int32), err_msg=f"{name}: gone at the deadline"
        )
        vals = _values_of(table, st.advance(2), victim)
        assert vals == [[100 + i] for i in range(8)], name


# ---------------------------------------------------------------------------
# eviction: a steady upsert+expire stream holds capacity flat
# ---------------------------------------------------------------------------
def test_eviction_reclaims_capacity(mesh8):
    table = _table(mesh8, 8, max_deltas=4, tombstone_capacity=512)
    cache = KVCache(table, default_ttl=2)
    keys = np.arange(1, 65, dtype=np.uint32)

    allocs = []
    for t in range(12):
        cache.put(keys, np.full(64, t, np.int32))
        cache.tick()
        st = cache.stats()
        allocs.append(st.base_rows + st.delta_rows)
        # live rows never exceed the working set (every key has exactly
        # one unexpired version; expired versions are masked)
        assert cache.live_count() == 64

    assert cache.evictions >= 1, "expired-load trigger never escalated"
    # Allocation is flat, not monotone: the second half of the stream must
    # not grow past the high-water mark of the first half (eviction
    # actually returns capacity).
    assert max(allocs[6:]) <= max(allocs[:6]), allocs
    # values are the newest generation everywhere
    got = cache.get(keys)
    np.testing.assert_array_equal(got, np.full(64, 11, np.int32))
    # and a forced eviction on a fully-expired cache empties it
    cache.advance(cache.now + 2)
    assert cache.live_count() == 0
    cache.evict_expired()
    assert cache.stats().tombstone_count == 0
    assert cache.get(keys)[0] == -1


def test_kvcache_get_contains_delete(mesh8):
    table = _table(mesh8, 8, max_deltas=4, tombstone_capacity=256)
    cache = KVCache(table)
    keys = np.arange(10, 20, dtype=np.uint32)
    cache.put(keys, np.arange(10, dtype=np.int32) * 3)
    assert cache.contains(keys).all()
    np.testing.assert_array_equal(cache.get(keys), np.arange(10, dtype=np.int32) * 3)
    cache.delete(keys[:5])
    assert not cache.contains(keys[:5]).any()
    assert cache.contains(keys[5:]).all()
    np.testing.assert_array_equal(cache.get(keys[5:]), np.arange(5, 10, dtype=np.int32) * 3)
    # ragged (non-device-multiple) reads pad internally
    assert cache.get(keys[5:8]).shape == (3,)


# ---------------------------------------------------------------------------
# stats-driven folds: the cold prefix folds first
# ---------------------------------------------------------------------------
def test_stats_driven_fold_amount_cold_prefix(mesh8):
    table = _table(mesh8, 8, max_deltas=6, tombstone_capacity=512)
    keys = np.arange(1, 257, dtype=np.uint32)
    state = table.init(jnp.asarray(keys), jnp.asarray(np.arange(256, dtype=np.int32)))

    # two cold deltas (fully deleted), then one hot delta (all live)
    cold1 = np.arange(1 << 10, (1 << 10) + 32, dtype=np.uint32)
    cold2 = np.arange(1 << 11, (1 << 11) + 32, dtype=np.uint32)
    hot = np.arange(1 << 12, (1 << 12) + 32, dtype=np.uint32)
    for batch in (cold1, cold2, hot):
        state = state.insert(jnp.asarray(batch), jnp.asarray(np.arange(32, dtype=np.int32)))
    state = table.delete(state, jnp.asarray(np.concatenate([cold1, cold2])))

    layer_live = maintenance.collect_layer_live(state)
    assert len(layer_live) == 4  # base + 3 deltas
    assert layer_live[1][0] == 0 and layer_live[2][0] == 0  # cold deltas
    assert layer_live[3][0] == 32  # hot delta

    policy = CompactionPolicy(fold_k=None, cold_live_ratio=0.5)
    stats = state.stats()
    k = policy.fold_amount(stats, layer_live)
    assert k == 2  # exactly the cold prefix, stopping before the hot layer

    folded = fold_oldest(state, k)
    assert len(folded.deltas) == 1
    counts = np.asarray(table.query(folded, jnp.asarray(hot)))
    np.testing.assert_array_equal(counts, np.ones(32, np.int32))
    counts = np.asarray(table.query(folded, jnp.asarray(cold1)))
    np.testing.assert_array_equal(counts, np.zeros(32, np.int32))

    # static override still wins
    assert CompactionPolicy(fold_k=3).fold_amount(stats, layer_live) == 3


# ---------------------------------------------------------------------------
# hot-key replication at YCSB skew
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("theta", [0.99, 1.2])
def test_hot_key_replication_zipf(mesh8, theta):
    """theta >= 0.99 zipfian insert: zero drops, exact merged counts."""
    table = _table(mesh8, 8, capacity_slack=2.0, replicate_hot_keys=4)
    base = np.arange(1, 257, dtype=np.uint32)
    state = table.init(jnp.asarray(base), jnp.asarray(np.arange(256, dtype=np.int32)))

    zipf = ZipfianGenerator(64, theta=theta, seed=5)
    ranks = zipf.sample(512)
    # distinct key ids, disjoint from the base population
    batch = (ranks + 1).astype(np.uint32) * np.uint32(3) + np.uint32(1 << 14)
    state = state.insert(
        jnp.asarray(batch), jnp.asarray(np.arange(512, dtype=np.int32))
    )

    assert table.skew_fallbacks == 0, "replication should absorb the skew"
    assert table.hot_keys, "the zipf head never went hot"
    assert int(state.num_dropped) == 0

    uniq, want = np.unique(batch, return_counts=True)
    pad = (-len(uniq)) % 8  # queries ship device-aligned; EMPTY counts 0
    q = np.concatenate([uniq, np.full(pad, 0xFFFFFFFF, np.uint32)])
    counts = np.asarray(table.query(state, jnp.asarray(q)))[: len(uniq)]
    np.testing.assert_array_equal(counts, want.astype(np.int32))
    # non-hot base keys are unaffected (count once, not per-replica-round)
    others = base[200:232]
    counts = np.asarray(table.query(state, jnp.asarray(others)))
    np.testing.assert_array_equal(counts, np.ones(32, np.int32))


# ---------------------------------------------------------------------------
# YCSB workload generator
# ---------------------------------------------------------------------------
def test_key_of_is_injective_and_never_empty():
    k = key_of(np.arange(1 << 16))
    assert len(np.unique(k)) == 1 << 16
    assert not np.any(k == np.uint32(0xFFFFFFFF))


def test_zipfian_is_skewed_and_bounded():
    z = ZipfianGenerator(1000, theta=0.99, seed=0)
    s = z.sample(20_000)
    assert s.min() >= 0 and s.max() < 1000
    # zipf(0.99, 1000): the head rank draws ~13% of all samples
    head = np.mean(s == 0)
    assert 0.08 < head < 0.20, head
    # determinism under the same seed
    np.testing.assert_array_equal(
        ZipfianGenerator(1000, theta=0.99, seed=3).sample(64),
        ZipfianGenerator(1000, theta=0.99, seed=3).sample(64),
    )


@pytest.mark.parametrize("letter", list("ABCDEF"))
def test_workload_mix_and_shapes(letter):
    w = YCSBWorkload(WORKLOADS[letter], 512, batch=128, scan_len=4, seed=11)
    spec = WORKLOADS[letter]
    tot = {k: 0 for k in ("read", "update", "insert", "scan", "rmw")}
    for kind, keys, vals in w.batches(2000):
        n = keys.shape[0] // (w.scan_len if kind == "scan" else 1)
        tot[kind] += n
        if kind in ("update", "insert", "rmw"):
            assert vals is not None and vals.shape[0] == keys.shape[0]
        else:
            assert vals is None
        assert keys.dtype == np.uint32
    assert sum(tot.values()) == 2000
    for name, frac in (("read", spec.read), ("update", spec.update),
                       ("insert", spec.insert), ("scan", spec.scan),
                       ("rmw", spec.rmw)):
        assert abs(tot[name] / 2000 - frac) < 0.05, (letter, name, tot)
    # insert-bearing workloads advance the cursor; their keys are fresh
    if spec.insert:
        assert w.inserted == 512 + tot["insert"]


def test_workload_drives_kvcache_exactly(mesh8):
    """A zipfian A-mix applied through KVCache matches a dict oracle."""
    table = _table(mesh8, 8, max_deltas=4, tombstone_capacity=512)
    w = YCSBWorkload(WORKLOADS["A"], 128, batch=64, seed=2)
    cache = KVCache(table, w.load_keys(), w.load_values().astype(np.int32))
    oracle = dict(zip(w.load_keys().tolist(), w.load_values().tolist()))

    for kind, keys, vals in w.batches(512):
        if kind == "read":
            got = cache.get(keys)
            want = np.array([oracle.get(int(k), -1) for k in keys], np.int32)
            np.testing.assert_array_equal(got, want)
        else:  # update
            cache.put(keys, vals)
            for k, v in zip(keys.tolist(), vals.tolist()):
                oracle[int(k)] = v
    assert cache.live_count() == len(oracle)


# ---------------------------------------------------------------------------
# server integration: submit_upsert + advance
# ---------------------------------------------------------------------------
def test_server_upsert_and_clock(mesh8):
    from repro.serve_table import CompactionPolicy as SP
    from repro.serve_table import MicroBatcher, TableServer

    table = _table(mesh8, 8, max_deltas=4, tombstone_capacity=256)
    n = 128
    server = TableServer(
        table,
        np.arange(1, n + 1, dtype=np.uint32),
        np.arange(n, dtype=np.int32),
        policy=SP(max_delta_depth=2, fold_k=1, tombstone_load=0.9),
        batcher=MicroBatcher(table, min_bucket=16),
        write_bucket=16,
    )
    keys = np.arange(1, 17, dtype=np.uint32)
    # duplicate submissions dedup keep-last at admission
    server.submit_upsert(
        np.concatenate([keys, keys]),
        np.concatenate([np.zeros(16, np.int32), np.arange(16, dtype=np.int32) + 500]),
        ttl=4,
    )
    server.drain()
    counts, _ = server.query_many([keys])
    np.testing.assert_array_equal(counts[0], np.ones(16, np.int32))
    (vals,), _ = server.retrieve_many([keys])
    assert [int(v[0]) for v in vals] == [500 + i for i in range(16)]

    server.advance(3)
    counts, _ = server.query_many([keys])
    np.testing.assert_array_equal(counts[0], np.ones(16, np.int32))
    server.advance(4)  # the TTL deadline: rows age out of the snapshot
    counts, _ = server.query_many([keys])
    np.testing.assert_array_equal(counts[0], np.zeros(16, np.int32))
    # untouched keys still live
    rest = np.arange(17, 33, dtype=np.uint32)
    counts, _ = server.query_many([rest])
    np.testing.assert_array_equal(counts[0], np.ones(16, np.int32))
    assert server.stats().last_error is None
