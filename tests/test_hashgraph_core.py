"""Unit + property tests for the single-device HashGraph."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import hashgraph, hashing


def _np_counts(build_keys, query_keys):
    """Oracle: multiplicity of each query key in the build multiset."""
    from collections import Counter

    c = Counter(build_keys.tolist())
    return np.array([c[int(q)] for q in query_keys], dtype=np.int32)


def _murmur3_32_py(key: int, seed: int) -> int:
    """Independent pure-python port of the canonical MurmurHash3_x86_32
    (Appleby's reference C) for a single 4-byte little-endian block."""
    M = 0xFFFFFFFF

    def rotl(x, r):
        return ((x << r) | (x >> (32 - r))) & M

    k = key & M
    k = (k * 0xCC9E2D51) & M
    k = rotl(k, 15)
    k = (k * 0x1B873593) & M
    h = seed & M
    h ^= k
    h = rotl(h, 13)
    h = (h * 5 + 0xE6546B64) & M
    h ^= 4  # length in bytes
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & M
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & M
    h ^= h >> 16
    return h


@pytest.mark.parametrize("seed", [0, 0x9747B28C, 12345])
def test_murmur_matches_reference_port(seed):
    rng = np.random.default_rng(7)
    ks = np.concatenate(
        [
            np.array([0, 1, 2, 0xDEADBEEF, 0xFFFFFFFE], dtype=np.uint32),
            rng.integers(0, 2**32 - 1, size=64, dtype=np.uint32),
        ]
    )
    out = np.asarray(hashing.murmur3_u32(jnp.asarray(ks), seed=seed))
    golden = np.array([_murmur3_32_py(int(k), seed) for k in ks], dtype=np.uint32)
    np.testing.assert_array_equal(out, golden)


def test_fmix32_avalanche():
    # The finalizer must be a bijection (injective on a sample) and mix bits.
    x = jnp.arange(1 << 16, dtype=jnp.uint32)
    y = np.asarray(hashing.fmix32(x))
    assert len(np.unique(y)) == len(y)


def test_build_offsets_are_csr():
    keys = jnp.array([12, 3, 74, 6, 99, 3, 3], dtype=jnp.uint32)
    hg = hashgraph.build(keys, table_size=8)
    off = np.asarray(hg.offsets)
    assert off[0] == 0
    assert off[-1] == keys.shape[0]
    assert (np.diff(off) >= 0).all()
    # every key is stored exactly once
    assert sorted(np.asarray(hg.keys).tolist()) == sorted(np.asarray(keys).tolist())


def test_bucket_contents_match_hash():
    rng = np.random.default_rng(0)
    keys = jnp.asarray(rng.integers(0, 1 << 30, size=512, dtype=np.uint32))
    V = 128
    hg = hashgraph.build(keys, table_size=V)
    off = np.asarray(hg.offsets)
    ks = np.asarray(hg.keys)
    buckets = np.asarray(hashing.hash_to_buckets(keys, V))
    for v in range(V):
        stored = ks[off[v] : off[v + 1]]
        expected = np.asarray(keys)[buckets == v]
        assert sorted(stored.tolist()) == sorted(expected.tolist())


@pytest.mark.parametrize("dup_factor", [1, 4, 64])
def test_query_count_sorted_exact(dup_factor):
    rng = np.random.default_rng(1)
    base = rng.integers(0, 1 << 16, size=1024 // dup_factor, dtype=np.uint32)
    keys = jnp.asarray(np.repeat(base, dup_factor))
    queries = jnp.asarray(rng.integers(0, 1 << 16, size=333, dtype=np.uint32))
    hg = hashgraph.build(keys, table_size=512)
    counts = np.asarray(hashgraph.query_count_sorted(hg, queries))
    np.testing.assert_array_equal(counts, _np_counts(np.asarray(keys), np.asarray(queries)))


def test_query_count_probe_matches_sorted_small_buckets():
    rng = np.random.default_rng(2)
    keys = jnp.asarray(rng.integers(0, 1 << 28, size=2048, dtype=np.uint32))
    queries = keys[::3]
    hg = hashgraph.build(keys, table_size=4096)  # C=0.5, short buckets
    a = np.asarray(hashgraph.query_count_sorted(hg, queries))
    b = np.asarray(hashgraph.query_count_probe(hg, queries, max_probe=64))
    np.testing.assert_array_equal(a, b)


def test_lookup_first_returns_payload():
    keys = jnp.array([10, 20, 30], dtype=jnp.uint32)
    vals = jnp.array([100, 200, 300], dtype=jnp.int32)
    hg = hashgraph.build(keys, table_size=16, values=vals)
    out = np.asarray(hashgraph.lookup_first(hg, jnp.array([20, 99, 10], dtype=jnp.uint32)))
    assert out[0] == 200
    assert out[1] == -1
    assert out[2] == 100


def test_contains():
    keys = jnp.array([5, 7, 7, 9], dtype=jnp.uint32)
    hg = hashgraph.build(keys, table_size=8)
    got = np.asarray(hashgraph.contains(hg, jnp.array([5, 6, 7, 8, 9], dtype=jnp.uint32)))
    np.testing.assert_array_equal(got, [True, False, True, False, True])


def test_trash_bucket_excluded():
    # Padded (EMPTY) keys must never match queries.
    keys = jnp.array([1, 2, 3, hashgraph.EMPTY_KEY], dtype=jnp.uint32)
    V = 8
    buckets = hashing.hash_to_buckets(keys[:3], V)
    buckets = jnp.concatenate([buckets, jnp.array([V], jnp.int32)])
    hg = hashgraph.build_from_buckets(keys, buckets, V)
    assert int(hg.num_valid) == 3
    q = jnp.array([hashgraph.EMPTY_KEY], dtype=jnp.uint32)
    # EMPTY hashes into a real bucket but is stored only in the trash bucket.
    assert int(hashgraph.query_count_sorted(hg, q)[0]) == 0


@settings(max_examples=50, deadline=None)
@given(
    keys=st.lists(st.integers(0, 2**32 - 2), min_size=1, max_size=300),
    queries=st.lists(st.integers(0, 2**32 - 2), min_size=1, max_size=100),
    logv=st.integers(1, 12),
)
def test_property_multiset_semantics(keys, queries, logv):
    """HashGraph is a faithful multiset: counts match a Counter oracle."""
    kb = np.array(keys, dtype=np.uint32)
    qb = np.array(queries, dtype=np.uint32)
    hg = hashgraph.build(jnp.asarray(kb), table_size=1 << logv)
    counts = np.asarray(hashgraph.query_count_sorted(hg, jnp.asarray(qb)))
    np.testing.assert_array_equal(counts, _np_counts(kb, qb))


@settings(max_examples=30, deadline=None)
@given(
    keys=st.lists(st.integers(0, 2**32 - 2), min_size=1, max_size=200),
    logv=st.integers(1, 10),
)
def test_property_join_size_self(keys, logv):
    """|A ⋈ A| = sum of squared multiplicities."""
    kb = np.array(keys, dtype=np.uint32)
    hg = hashgraph.build(jnp.asarray(kb), table_size=1 << logv)
    counts = np.asarray(hashgraph.query_count_sorted(hg, jnp.asarray(kb)))
    from collections import Counter

    expected = sum(c * c for c in Counter(kb.tolist()).values())
    assert counts.sum() == expected


def test_build_under_jit():
    keys = jnp.arange(100, dtype=jnp.uint32)

    @jax.jit
    def f(k):
        hg = hashgraph.build(k, table_size=64)
        return hashgraph.query_count_sorted(hg, k)

    np.testing.assert_array_equal(np.asarray(f(keys)), np.ones(100, np.int32))
