"""Serving engine — snapshot consistency, micro-batching, executor caching.

The acceptance properties of the serving tentpole:

* **Snapshot consistency**: any interleaved writer/reader schedule
  observes, for each read, *exactly* the oracle contents of the seqno the
  read reports — never a torn or partially-applied write (reads bind to
  one published immutable state).
* **Executor reuse**: micro-batched requests of shifting ragged sizes
  land on pow2-bucketed static shapes, so the jitted plan executors are
  reused across requests — asserted both on the batcher's own plan cache
  counters and on ``jax.jit``'s compiled-cache size (no per-request
  retrace).
* **Background compaction off the read path**: a fold running on a worker
  thread never blocks reads; reads issued during the fold serve the
  pre-fold seqno and stay oracle-exact (the CI smoke in
  ``benchmarks/bench_serve.py`` additionally gates on this under load).
"""
from collections import Counter

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import plans
from repro.core.schema import TableSchema
from repro.core.table import DistributedHashTable
from repro.serve_table import (
    CompactionPolicy,
    MicroBatcher,
    SnapshotRegistry,
    TableServer,
)
from test_table_state import Oracle, _keys_for, _value_rows, _values_for

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


# ---------------------------------------------------------------------------
# SnapshotRegistry
# ---------------------------------------------------------------------------


def test_registry_publish_and_history(mesh8):
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 10)
    rng = np.random.default_rng(0)
    s0 = table.init(jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32)))
    reg = SnapshotRegistry(s0, history=3)
    assert reg.current().seqno == 0 and reg.current().state is s0
    s1 = s0.insert(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    snap = reg.publish(s1)
    assert snap.seqno == 1 and reg.current().state is s1
    # a reader holding the old snapshot still sees the old state object
    assert reg.recent(0) is not None and reg.recent(0).state is s0
    for i in range(4):
        reg.publish(s1)
    assert reg.recent(0) is None  # aged out of the ring
    assert reg.seqno == 5


# ---------------------------------------------------------------------------
# MicroBatcher
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schema", SCHEMAS)
def test_batcher_scatter_matches_oracle(mesh8, schema):
    """Ragged request batches through one fused execution == per-key oracle."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, schema=schema)
    rng = np.random.default_rng(1 + schema.value_cols)
    keys = _keys_for(schema, rng, 512)
    vals = _values_for(schema, 0, 512)
    oracle = Oracle()
    oracle.insert(keys, vals)
    state = table.init(table.schema.pack_keys(keys), values=jnp.asarray(vals))

    batcher = MicroBatcher(table, min_bucket=32)
    requests = [
        keys[:7],
        keys[100:101],
        _keys_for(schema, rng, 13),  # mostly misses
        keys[200:245],
    ]
    counts = batcher.query_many(state, requests)
    assert len(counts) == len(requests)
    for req, got in zip(requests, counts):
        want = np.array([oracle.count(k) for k in req], np.int32)
        np.testing.assert_array_equal(got, want)

    values = batcher.retrieve_many(state, requests)
    for req, got in zip(requests, values):
        assert len(got) == len(req)
        for k, rows in zip(req, got):
            assert sorted(_value_rows(np.asarray(rows)), key=repr) == oracle.values(k)

    # per-layer provenance through the batcher
    state2 = state.insert(
        table.schema.pack_keys(keys[:8]), jnp.asarray(_values_for(schema, 9000, 8))
    )
    out = batcher.retrieve_many(state2, [keys[:8]], per_layer_counts=True)
    (vals8, lc) = out[0]
    assert lc.shape == (8, 2)
    assert (lc.sum(axis=1) == np.array([len(vals8[i]) for i in range(8)])).all()
    assert (lc[:, 1] == 1).all()  # the reinserted copy lives in delta 1


def test_batcher_bucketing_reuses_executors(mesh8):
    """Shifting request sizes within a bucket: zero new traces after warmup.

    The acceptance criterion's executor-cache assertion: both the
    batcher's plan cache and the underlying ``jax.jit`` compiled cache
    stop growing once each pow2 bucket has been seen.
    """
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(5)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    batcher = MicroBatcher(table, min_bucket=64)

    assert batcher.bucket_size(1) == 64
    assert batcher.bucket_size(64) == 64
    assert batcher.bucket_size(65) == 128
    assert batcher.bucket_size(200) == 256

    # warmup: one batch in the 64-bucket, one in the 128-bucket
    batcher.query_many(state, [keys[:10], keys[20:40]])
    batcher.query_many(state, [keys[:50], keys[60:125]])
    batcher.retrieve_many(state, [keys[:10], keys[20:40]])
    batcher.retrieve_many(state, [keys[:50], keys[60:125]])
    warm = batcher.stats()
    has_cache_size = hasattr(plans.exec_query, "_cache_size")
    if has_cache_size:
        q_cache = plans.exec_query._cache_size()
        r_cache = plans.exec_retrieve._cache_size()

    # steady traffic: shifting ragged sizes, same buckets
    hits_before = warm.cache_hits
    for i in range(6):
        a, b = 5 + 3 * i, 30 + 2 * i
        batcher.query_many(state, [keys[:a], keys[a : a + b]])
        batcher.retrieve_many(state, [keys[:a], keys[a : a + b]])
    stats = batcher.stats()
    assert stats.cache_misses == warm.cache_misses  # no new plans
    assert stats.cache_hits == hits_before + 12  # every batch hit
    if has_cache_size:
        # the jitted executors really were reused: zero new compiled entries
        assert plans.exec_query._cache_size() == q_cache
        assert plans.exec_retrieve._cache_size() == r_cache
    assert stats.requests == warm.requests + 24
    assert 0.0 < stats.pad_fraction < 1.0


def test_batcher_overflow_doubles_and_recovers(mesh8):
    """Data drift past a bucket's cached caps re-plans instead of dropping."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(9)
    base = rng.choice(np.arange(1 << 14, dtype=np.uint32), size=64, replace=False)
    keys = np.concatenate([base, np.repeat(base[0], 64)])  # key 0: 65 copies
    state = table.init(jnp.asarray(keys))
    batcher = MicroBatcher(table, min_bucket=32)

    # warm the 32-bucket with low-multiplicity traffic
    out = batcher.retrieve_many(state, [base[1:9]])
    assert all(len(v) == 1 for v in out[0])
    # now a request hitting the hot key: outgrows the cached caps
    out = batcher.retrieve_many(state, [np.array([base[0]], np.uint32)])
    assert len(out[0][0]) == 65
    assert batcher.stats().overflow_retries >= 1
    cnt = Counter(keys.tolist())
    got = batcher.query_many(state, [base[:16]])[0]
    np.testing.assert_array_equal(got, [cnt[int(k)] for k in base[:16]])


# ---------------------------------------------------------------------------
# TableServer — snapshot consistency under interleaved schedules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_interleaved_writes_and_reads_observe_exact_seqno(
    schema, meshname, request
):
    """Every read reports a seqno and matches that seqno's oracle exactly.

    The writer applies queued batches in submit order (``window`` per
    publish); an oracle is forked at every publish by replaying the
    applied prefix of the op log.  Reads interleave at every stage —
    including against stale pre-step snapshots — and must always agree
    with the oracle AT THEIR REPORTED SEQNO (no torn reads, no early
    visibility of queued writes).
    """
    mesh = request.getfixturevalue(meshname)
    d = 8 if meshname == "mesh8" else 1
    table = DistributedHashTable(
        mesh, ("d",), hash_range=1 << 12, schema=schema, max_deltas=8
    )
    rng = np.random.default_rng(13 + d + schema.value_cols)
    keys = _keys_for(schema, rng, 256)
    vals = _values_for(schema, 0, 256)
    server = TableServer(table, keys, vals, window=2)

    ops = []  # full submit-order op log; ops[:applied] are visible
    applied = 0

    def oracle_at(n_applied):
        o = Oracle()
        o.insert(keys, vals)
        for kind, kk, vv in ops[:n_applied]:
            o.insert(kk, vv) if kind == "insert" else o.delete(kk)
        return o

    oracles = {0: oracle_at(0)}  # seqno -> oracle

    def pump():
        """Drive the writer; record an oracle fork at every publish."""
        nonlocal applied
        while True:
            n = server.step()
            if not n:
                break
            applied += n
            oracles[server.current().seqno] = oracle_at(applied)

    def read_and_check(reqs):
        counts, seq = server.query_many(reqs)
        oracle = oracles[seq]
        for req, got in zip(reqs, counts):
            want = np.array([oracle.count(k) for k in req], np.int32)
            np.testing.assert_array_equal(got, want)

    def submit_insert(n, start):
        ins = _keys_for(schema, rng, n, lo=1 << 16, hi=1 << 17)
        iv = _values_for(schema, start, n)
        server.submit_insert(ins, iv)
        ops.append(("insert", ins, iv))
        return ins

    def submit_delete(kk):
        server.submit_delete(kk)
        ops.append(("delete", kk, None))

    # reads interleave with queued-but-unapplied writes
    read_and_check([keys[:16], keys[100:120]])
    ins1 = submit_insert(8 * d, 10_000)
    read_and_check([ins1])  # still seqno 0: queued ≠ visible
    submit_delete(keys[:8])
    pump()  # window=2: one publish
    assert server.current().seqno == 1
    read_and_check([ins1, keys[:16], keys[:8]])

    # second wave: reinsert deleted keys, delete delta keys — 3 ops over
    # window 2 → two publishes, each with its own oracle fork
    ins2 = submit_insert(8 * d, 20_000)
    submit_delete(ins1[: 2 * d])
    re = keys[:8]
    rev = _values_for(schema, 30_000, 8)
    server.submit_insert(re, rev)
    ops.append(("insert", re, rev))
    read_and_check([ins2])  # pre-step: none of the wave visible
    pump()
    assert server.current().seqno == 3
    read_and_check([re, ins2, ins1, keys[:32]])
    # and a stale-oracle sanity: seqno-2 fork differs from seqno-3
    assert oracles[2].count(re[0]) + 1 == oracles[3].count(re[0])
    assert server.stats().reads > 0


def test_server_maintenance_folds_and_stays_consistent(mesh8):
    """A steady write stream triggers policy folds; answers stay exact and
    the delta ring never overflows."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, max_deltas=4)
    rng = np.random.default_rng(17)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    vals = np.arange(512, dtype=np.int32)
    server = TableServer(
        table, keys, vals, policy=CompactionPolicy(max_delta_depth=4, fold_k=2)
    )
    oracle = Oracle()
    oracle.insert(keys, vals)

    next_val = 1000
    live = []
    for wave in range(12):
        ins = rng.integers(1 << 14, 1 << 15, 16, dtype=np.uint32)
        iv = np.arange(next_val, next_val + 16, dtype=np.int32)
        next_val += 16
        server.submit_insert(ins, iv)
        oracle.insert(ins, iv)
        live.extend(ins.tolist())
        if wave % 3 == 2:
            dead = np.array(live[:8], np.uint32)
            server.submit_delete(dead)
            oracle.delete(dead)
            live = live[8:]
        server.drain()
    stats = server.stats()
    assert stats.folds + stats.full_compacts >= 1  # maintenance really ran
    # the policy keeps the ring admissible: depth may sit AT the trigger
    # after the last insert (the fold runs lazily before the next one) but
    # the 12 waves above could only complete if no insert ever overflowed.
    assert stats.shadow.delta_depth <= table.max_deltas

    q = np.concatenate([keys[:32], np.array(live[:32], np.uint32)])
    counts, seq = server.query_many([q])
    want = np.array([oracle.count(k) for k in q], np.int32)
    np.testing.assert_array_equal(counts[0], want)
    (res,), _ = server.retrieve_many([q])
    for k, rows in zip(q, res):
        assert sorted(_value_rows(np.asarray(rows)), key=repr) == oracle.values(k)


def test_reads_flow_during_background_fold(mesh8):
    """Reads issued while a fold is in flight serve the pre-fold seqno,
    return oracle-exact answers, and the publish lands afterwards."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, max_deltas=8)
    rng = np.random.default_rng(19)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    vals = np.arange(512, dtype=np.int32)
    server = TableServer(table, keys, vals)
    oracle = Oracle()
    oracle.insert(keys, vals)
    for _ in range(4):
        ins = rng.integers(1 << 14, 1 << 15, 32, dtype=np.uint32)
        iv = np.arange(64, 96, dtype=np.int32)
        server.submit_insert(ins, iv)
        oracle.insert(ins, iv)
    server.drain()
    pre = server.current().seqno

    # warm the read executor for the current depth so the during-fold loop
    # measures serving (the fold's own first-trace dominates its runtime,
    # leaving a wide window for warm reads to land inside).
    server.query_many([keys[:24]])
    t = server.fold_async(k=2)
    reads_during = 0
    while t.is_alive():
        counts, seq = server.query_many([keys[:24]])
        assert seq == pre  # the old snapshot keeps serving
        np.testing.assert_array_equal(
            counts[0], [oracle.count(k) for k in keys[:24]]
        )
        reads_during += 1
    t.join()
    assert reads_during >= 1  # reads really interleaved with the fold
    assert server.current().seqno == pre + 1
    assert server.stats().folds == 1
    # post-fold reads: same answers, new seqno
    counts, seq = server.query_many([keys[:24]])
    assert seq == pre + 1
    np.testing.assert_array_equal(counts[0], [oracle.count(k) for k in keys[:24]])


def test_writes_defer_during_fold_then_apply(mesh8):
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, max_deltas=8)
    rng = np.random.default_rng(23)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    server = TableServer(table, keys, np.arange(512, dtype=np.int32))
    for _ in range(3):
        server.submit_insert(
            rng.integers(1 << 14, 1 << 15, 16, dtype=np.uint32),
            np.arange(16, dtype=np.int32),
        )
    server.drain()
    t = server.fold_async(k=1)
    server.submit_insert(
        rng.integers(1 << 14, 1 << 15, 16, dtype=np.uint32),
        np.arange(16, dtype=np.int32),
    )
    stepped = server.step()
    if t.is_alive():
        assert stepped == 0  # deferred while folding
    t.join()
    server.drain()
    assert server.pending() == 0


def test_delete_runs_trigger_policy_before_tombstone_overflow(mesh8):
    """A delete-heavy window must evaluate the policy per op: tombstone
    pressure escalates to a full fold mid-run instead of overflowing the
    buffer and silently losing deletes."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, tombstone_capacity=16
    )
    rng = np.random.default_rng(31)
    keys = rng.choice(np.arange(1 << 14, dtype=np.uint32), size=512, replace=False)
    server = TableServer(
        table,
        keys,
        np.arange(512, dtype=np.int32),
        policy=CompactionPolicy(max_delta_depth=8, tombstone_load=0.5),
        window=16,
    )
    # 8 delete batches of 8 keys = 64 deletes through a 16-slot buffer: only
    # per-op policy folds keep it admissible.
    dead = keys[:64]
    for i in range(8):
        server.submit_delete(dead[i * 8 : (i + 1) * 8])
    server.drain()
    stats = server.stats()
    assert stats.shadow.tombstone_dropped == 0  # nothing lost
    assert stats.full_compacts >= 1  # the escalation really fired
    counts, _ = server.query_many([dead, keys[64:96]])
    np.testing.assert_array_equal(counts[0], np.zeros(64, np.int32))
    assert (counts[1] == 1).all()


def test_fold_async_escalates_tombstone_pressure_at_depth_zero(mesh8):
    """A policy-driven background fold must run the full compact when the
    tombstone buffer saturates with NO deltas to fold (the depth-0 case an
    oldest-k fold cannot address)."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 11, tombstone_capacity=16
    )
    rng = np.random.default_rng(37)
    keys = rng.choice(np.arange(1 << 14, dtype=np.uint32), size=256, replace=False)
    server = TableServer(
        table,
        keys,
        np.arange(256, dtype=np.int32),
        policy=CompactionPolicy(max_delta_depth=8, tombstone_load=0.5),
    )
    # saturate the buffer directly on the shadow (bypassing step's per-op
    # policy) to model pressure at delta depth 0
    server._shadow = server._shadow.delete(jnp.asarray(keys[:12]))
    server.registry.publish(server._shadow)
    pre = server.current().seqno
    t = server.fold_async()  # policy-driven
    t.join()
    stats = server.stats()
    assert stats.full_compacts == 1 and stats.folds == 0
    assert stats.shadow.tombstone_count == 0  # buffer freed
    assert server.current().seqno == pre + 1  # published
    counts, _ = server.query_many([keys[:24]])
    np.testing.assert_array_equal(
        counts[0], [0] * 12 + [1] * 12
    )


def test_failed_write_is_requeued_and_surfaced(mesh8):
    """An exception while applying a write must not lose the batch or die
    silently: the op returns to the queue head and stats().last_error is
    set (the embedded loop stops on it; inline drivers see the raise)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 10, max_deltas=1)
    rng = np.random.default_rng(41)
    keys = rng.integers(0, 1 << 14, 256, dtype=np.uint32)
    # a policy that never folds: the second insert hits the ring-full error
    never = CompactionPolicy(
        max_delta_depth=None, tombstone_load=2.0, tombstone_overflow=False
    )
    server = TableServer(table, keys, np.arange(256, dtype=np.int32), policy=never)
    for _ in range(2):
        server.submit_insert(
            rng.integers(0, 1 << 14, 8, dtype=np.uint32),
            np.arange(8, dtype=np.int32),
        )
    with pytest.raises(RuntimeError, match="delta ring full"):
        server.step()
    assert server.pending() == 1  # the failed batch is back at the head
    stats = server.stats()
    assert stats.last_error and "delta ring full" in stats.last_error
    assert stats.writes_applied == 1  # the first insert did land + publish
    assert server.current().seqno == 1


def test_batcher_raises_instead_of_truncating(mesh8):
    """Exhausted capacity retries fail loudly — never a silently short list."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(33)
    base = rng.choice(np.arange(1 << 14, dtype=np.uint32), size=64, replace=False)
    keys = np.concatenate([base, np.repeat(base[0], 192)])  # hot key ×193
    state = table.init(jnp.asarray(keys))
    batcher = MicroBatcher(table, min_bucket=32, max_retries=1)
    batcher.retrieve_many(state, [base[1:9]])  # warm tiny caps
    with pytest.raises(RuntimeError, match="capacity doublings"):
        batcher.retrieve_many(state, [np.array([base[0]], np.uint32)])


def test_server_skew_fallback_surfaces_in_stats(mesh8):
    """The satellite's visibility requirement: a skew-guard fallback on the
    write path shows up in server stats."""
    from test_maintenance import _narrow_batch

    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(29)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    server = TableServer(table, keys, np.arange(512, dtype=np.int32))
    narrow = _narrow_batch(table, server.current().state, 512)
    server.submit_insert(narrow, np.arange(512, dtype=np.int32))
    server.drain()
    st = server.stats()
    assert st.skew_fallbacks == 1
    assert st.shadow.num_dropped == 0
    counts, _ = server.query_many([narrow[:32]])
    assert (counts[0] >= 1).all()
