"""Schema-layer tests: 64-bit keys and multi-column payloads.

Edge cases the ISSUE calls out: adversarial uint64 keys that collide in the
low 32 bits (a 32-bit-only compare or hash would conflate them),
duplicate-heavy uint64 multisets, multi-column payload round-trips on one
device and the 8-way conftest mesh, overflow reporting at every width, and
the kernel/jnp retrieval paths agreeing bit-for-bit.  Every check is
against a plain numpy/dict oracle built from python ints.
"""
from collections import Counter, defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashgraph, hashing
from repro.core.schema import TableSchema, pack_u64, unpack_u64
from repro.core.table import (
    DistributedHashTable,
    join_to_pairs,
    retrieval_to_lists,
)

# ---------------------------------------------------------------------------
# hashing: the multi-word murmur path
# ---------------------------------------------------------------------------


def _murmur3_32_bytes_py(data: bytes, seed: int) -> int:
    """Independent python port of MurmurHash3_x86_32 for whole 4-byte blocks."""
    M = 0xFFFFFFFF

    def rotl(x, r):
        return ((x << r) | (x >> (32 - r))) & M

    h = seed & M
    assert len(data) % 4 == 0
    for i in range(0, len(data), 4):
        k = int.from_bytes(data[i : i + 4], "little")
        k = (k * 0xCC9E2D51) & M
        k = rotl(k, 15)
        k = (k * 0x1B873593) & M
        h ^= k
        h = rotl(h, 13)
        h = (h * 5 + 0xE6546B64) & M
    h ^= len(data)
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & M
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & M
    h ^= h >> 16
    return h


@pytest.mark.parametrize("seed", [0, hashing.DEFAULT_SEED, 12345])
def test_murmur_packed_u64_matches_reference_port(seed):
    rng = np.random.default_rng(3)
    ks = np.concatenate(
        [
            np.array(
                [0, 1, 0xFFFFFFFF, 1 << 32, (1 << 64) - 2, 0xDEADBEEFCAFEF00D],
                dtype=np.uint64,
            ),
            rng.integers(0, (1 << 63) - 1, size=64).astype(np.uint64),
        ]
    )
    got = np.asarray(hashing.murmur3_packed(pack_u64(ks), seed=seed))
    want = np.array(
        [_murmur3_32_bytes_py(int(k).to_bytes(8, "little"), seed) for k in ks],
        dtype=np.uint32,
    )
    np.testing.assert_array_equal(got, want)


def test_pack_unpack_u64_roundtrip():
    rng = np.random.default_rng(4)
    ks = rng.integers(0, (1 << 64) - 1, size=256, dtype=np.uint64)
    np.testing.assert_array_equal(unpack_u64(pack_u64(ks)), ks)


# ---------------------------------------------------------------------------
# single-device: adversarial low-32-bit collisions
# ---------------------------------------------------------------------------


def _u64_low32_colliders(rng, n_hi, low_word=0xDEADBEEF):
    """n_hi distinct uint64 keys all sharing the same low 32 bits."""
    his = rng.choice(np.arange(1, 1 << 20, dtype=np.uint64), size=n_hi, replace=False)
    return (his << np.uint64(32)) | np.uint64(low_word)


def test_u64_low32_collisions_counts_exact():
    rng = np.random.default_rng(7)
    base = _u64_low32_colliders(rng, 64)
    mult = rng.integers(1, 8, size=64)
    keys = np.repeat(base, mult)
    rng.shuffle(keys)
    hg = hashgraph.build(pack_u64(keys), table_size=16)
    # queries: every present key + absent keys sharing the same low word
    absent = _u64_low32_colliders(rng, 64) + (np.uint64(1) << np.uint64(52))
    queries = np.concatenate([base, absent])
    counts = np.asarray(hashgraph.query_count_sorted(hg, pack_u64(queries)))
    c = Counter(keys.tolist())
    want = np.array([c[int(q)] for q in queries], np.int32)
    np.testing.assert_array_equal(counts, want)
    # a 32-bit table of the low words alone WOULD conflate them:
    hg32 = hashgraph.build(jnp.asarray(keys.astype(np.uint32)), table_size=16)
    c32 = np.asarray(
        hashgraph.query_count_sorted(hg32, jnp.asarray(queries.astype(np.uint32)))
    )
    assert (c32 != want).any(), "low-32 projection should collide — test is vacuous"


def test_u64_all_ones_low_word_is_a_valid_key():
    """Only the all-ones *two-lane* pattern is the padding sentinel."""
    keys = np.array(
        [(0x5 << 32) | 0xFFFFFFFF, (0xFFFFFFFF << 32) | 7], dtype=np.uint64
    )
    hg = hashgraph.build(pack_u64(keys), table_size=8)
    counts = np.asarray(hashgraph.query_count_sorted(hg, pack_u64(keys)))
    np.testing.assert_array_equal(counts, [1, 1])
    packed = pack_u64(keys)
    assert not bool(hashgraph.is_empty_key(packed).any())
    sentinel = pack_u64(np.array([(1 << 64) - 1], dtype=np.uint64))
    assert bool(hashgraph.is_empty_key(sentinel).all())


def test_u64_multicol_retrieve_single_device():
    rng = np.random.default_rng(11)
    base = _u64_low32_colliders(rng, 48)
    keys = np.repeat(base, rng.integers(1, 6, size=48))
    rng.shuffle(keys)
    vals = np.stack(
        [
            np.arange(len(keys), dtype=np.int32),
            rng.integers(-1000, 1000, len(keys)).astype(np.int32),
            np.full(len(keys), 42, np.int32),
        ],
        axis=1,
    )
    hg = hashgraph.build(pack_u64(keys), table_size=32, values=jnp.asarray(vals))
    oracle = defaultdict(list)
    for k, v in zip(keys.tolist(), vals.tolist()):
        oracle[int(k)].append(tuple(v))
    queries = np.concatenate([base, base + np.uint64(1)])
    total = sum(len(oracle[int(q)]) for q in queries)
    offsets, out, dropped = hashgraph.retrieve(
        hg, pack_u64(queries), capacity=total + 8
    )
    assert int(dropped) == 0
    offsets, out = np.asarray(offsets), np.asarray(out)
    assert out.shape[1] == 3
    for i, q in enumerate(queries):
        got = sorted(map(tuple, out[offsets[i] : offsets[i + 1]].tolist()))
        assert got == sorted(oracle[int(q)]), f"query {i}"


def test_lookup_first_multicol_rows():
    keys = np.array([10, 20], dtype=np.uint64) << np.uint64(40)
    vals = np.array([[1, 2], [3, 4]], dtype=np.int32)
    hg = hashgraph.build(pack_u64(keys), table_size=8, values=jnp.asarray(vals))
    q = np.array([keys[1], keys[0] + np.uint64(1)], dtype=np.uint64)
    out = np.asarray(hashgraph.lookup_first(hg, pack_u64(q)))
    np.testing.assert_array_equal(out, [[3, 4], [-1, -1]])


# ---------------------------------------------------------------------------
# duplicate-heavy uint64 multisets
# ---------------------------------------------------------------------------


def _dup_heavy_u64(rng, n_base, max_mult):
    base = rng.integers(0, (1 << 62) - 1, size=4 * n_base, dtype=np.uint64)
    base = np.unique(base)[:n_base]
    mult = rng.integers(1, max_mult + 1, size=len(base))
    keys = np.repeat(base, mult)
    rng.shuffle(keys)
    return base, keys


@pytest.mark.parametrize("max_mult", [16, 64])
def test_dup_heavy_u64_single_device(max_mult):
    rng = np.random.default_rng(max_mult)
    base, keys = _dup_heavy_u64(rng, 256, max_mult)
    vals = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(pack_u64(keys), table_size=512, values=jnp.asarray(vals))
    oracle = defaultdict(list)
    for k, v in zip(keys.tolist(), vals.tolist()):
        oracle[int(k)].append(int(v))
    queries = np.concatenate(
        [base[:128], rng.integers(0, (1 << 62) - 1, 64, dtype=np.uint64)]
    )
    total = sum(len(oracle[int(q)]) for q in queries)
    offsets, out, dropped = hashgraph.retrieve(
        hg, pack_u64(queries), capacity=total + 8
    )
    assert int(dropped) == 0
    offsets, out = np.asarray(offsets), np.asarray(out)
    for i, q in enumerate(queries):
        got = sorted(out[offsets[i] : offsets[i + 1]].tolist())
        assert got == sorted(oracle[int(q)]), f"query {i}"


@pytest.mark.slow
def test_dup_heavy_u64_mult_1024_mesh8(mesh8):
    """Duplicate-heavy uint64 multiset with multiplicities up to 1024."""
    rng = np.random.default_rng(1024)
    base = np.unique(rng.integers(0, (1 << 62) - 1, 2048, dtype=np.uint64))[:1024]
    mult = rng.integers(1, 1025, size=len(base))
    keys = np.repeat(base, mult)
    pad = (-len(keys)) % 8
    if pad:
        keys = np.concatenate([keys, rng.choice(base, size=pad)])
    rng.shuffle(keys)
    vals = np.arange(len(keys), dtype=np.int32)
    table = DistributedHashTable(
        mesh8,
        ("d",),
        hash_range=1 << 16,
        capacity_slack=2.0,
        schema=TableSchema("uint64", 1),
    )
    state = table.build(keys, values=vals)
    assert int(state.num_dropped) == 0
    oracle = defaultdict(list)
    for k, v in zip(keys.tolist(), vals.tolist()):
        oracle[int(k)].append(int(v))
    queries = np.concatenate(
        [
            rng.choice(base, size=512),
            rng.integers(0, (1 << 62) - 1, 512, dtype=np.uint64),
        ]
    )
    rng.shuffle(queries)
    counts = np.asarray(table.query(state, queries))
    want = np.array([len(oracle[int(q)]) for q in queries], np.int32)
    np.testing.assert_array_equal(counts, want)
    n_local = len(queries) // 8
    per_shard = [
        sum(len(oracle[int(q)]) for q in queries[s * n_local : (s + 1) * n_local])
        for s in range(8)
    ]
    cap = max(8, ((max(per_shard) + 64 + 7) // 8) * 8)
    res = table.retrieve(state, queries, out_capacity=cap)
    assert int(res.num_dropped) == 0
    per_query = retrieval_to_lists(res)
    for i, q in enumerate(queries):
        got = sorted(np.asarray(per_query[i]).tolist())
        assert got == sorted(oracle[int(q)]), f"query {i}"


# ---------------------------------------------------------------------------
# distributed round-trips: every width on mesh1 and mesh8
# ---------------------------------------------------------------------------

SCHEMAS = [
    TableSchema("uint32", 1),
    TableSchema("uint32", 4),
    TableSchema("uint64", 1),
    TableSchema("uint64", 2),
]


def _schema_case(rng, sch, n_base, max_mult):
    if sch.key_dtype == "uint64":
        base = np.unique(rng.integers(0, (1 << 62) - 1, 2 * n_base, dtype=np.uint64))[
            :n_base
        ]
        miss = rng.integers(0, (1 << 62) - 1, n_base, dtype=np.uint64)
    else:
        base = rng.choice(np.arange(1 << 24, dtype=np.uint32), n_base, replace=False)
        miss = rng.integers(0, 1 << 24, n_base, dtype=np.uint32)
    keys = np.repeat(base, rng.integers(1, max_mult + 1, size=len(base)))
    rng.shuffle(keys)
    if sch.value_cols == 1:
        vals = np.arange(len(keys), dtype=np.int32)
        rows = [int(v) for v in vals]
    else:
        vals = rng.integers(-(1 << 20), 1 << 20, (len(keys), sch.value_cols)).astype(
            np.int32
        )
        rows = [tuple(v) for v in vals.tolist()]
    oracle = defaultdict(list)
    for k, r in zip(keys.tolist(), rows):
        oracle[int(k)].append(r)
    return base, keys, vals, miss, oracle


@pytest.mark.parametrize("sch", SCHEMAS, ids=lambda s: f"{s.key_dtype}x{s.value_cols}")
@pytest.mark.parametrize("nmesh", ["mesh1", "mesh8"])
def test_schema_roundtrip_meshes(sch, nmesh, request):
    mesh = request.getfixturevalue(nmesh)
    d = 1 if nmesh == "mesh1" else 8
    rng = np.random.default_rng(hash((sch.key_dtype, sch.value_cols, d)) % (1 << 31))
    base, keys, vals, miss, oracle = _schema_case(rng, sch, 128, 6)
    pad = (-len(keys)) % d
    if pad:
        keys = np.concatenate([keys, rng.choice(base, size=pad)])
        extra = (
            np.arange(len(vals), len(vals) + pad, dtype=np.int32)
            if sch.value_cols == 1
            else np.zeros((pad, sch.value_cols), np.int32)
        )
        for k, r in zip(
            keys[-pad:].tolist(),
            extra.tolist() if sch.value_cols > 1 else extra.tolist(),
        ):
            oracle[int(k)].append(tuple(r) if sch.value_cols > 1 else int(r))
        vals = np.concatenate([vals, extra])
    table = DistributedHashTable(mesh, ("d",), hash_range=1 << 12, schema=sch)
    state = table.build(keys, values=vals)
    assert int(state.num_dropped) == 0
    queries = np.concatenate([rng.choice(base, 96), miss[: 128 - 96 + 32]])[
        : (128 // d) * d
    ]
    rng.shuffle(queries)
    counts = np.asarray(table.query(state, queries))
    want = np.array([len(oracle[int(q)]) for q in queries], np.int32)
    np.testing.assert_array_equal(counts, want)
    res = table.retrieve(state, queries, out_capacity=4096)
    assert int(res.num_dropped) == 0
    per_query = retrieval_to_lists(res)
    for i, q in enumerate(queries):
        got = np.asarray(per_query[i])
        got = (
            sorted(got.tolist())
            if sch.value_cols == 1
            else sorted(map(tuple, got.tolist()))
        )
        assert got == sorted(oracle[int(q)]), f"query {i}"
    join = table.inner_join(state, queries, out_capacity=4096)
    assert int(join.num_dropped) == 0
    pairs = join_to_pairs(join)
    assert pairs.shape[1] == 1 + sch.value_cols
    wantp = sorted(
        (i, *(v if isinstance(v, tuple) else (v,)))
        for i, q in enumerate(queries)
        for v in oracle[int(q)]
    )
    assert sorted(map(tuple, pairs.tolist())) == wantp


# ---------------------------------------------------------------------------
# overflow reporting at every width
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sch", SCHEMAS, ids=lambda s: f"{s.key_dtype}x{s.value_cols}")
def test_overflow_reported_every_width_single_device(sch):
    rng = np.random.default_rng(13)
    base, keys, vals, _, oracle = _schema_case(rng, sch, 64, 8)
    pk = pack_u64(keys) if sch.key_dtype == "uint64" else jnp.asarray(keys)
    hg = hashgraph.build(pk, table_size=64, values=jnp.asarray(vals))
    queries = keys[:128]
    pq = pack_u64(queries) if sch.key_dtype == "uint64" else jnp.asarray(queries)
    total = int(np.asarray(hashgraph.query_count_sorted(hg, pq)).sum())
    cap = max(8, total // 3)
    offsets, out, dropped = hashgraph.retrieve(hg, pq, capacity=cap)
    assert int(dropped) == total - cap  # exact, never silent
    assert int(np.asarray(offsets).max()) <= cap
    # the emitted slots are a prefix of the full stream at any width
    _, out_full, _ = hashgraph.retrieve(hg, pq, capacity=total)
    np.testing.assert_array_equal(np.asarray(out)[:cap], np.asarray(out_full)[:cap])


@pytest.mark.parametrize("sch", SCHEMAS[2:], ids=lambda s: f"{s.key_dtype}x{s.value_cols}")
def test_overflow_reported_mesh8(sch, mesh8):
    rng = np.random.default_rng(17)
    base, keys, vals, _, _ = _schema_case(rng, sch, 64, 8)
    pad = (-len(keys)) % 8
    if pad:
        keys = keys[: len(keys) - (len(keys) % 8)]
        vals = vals[: len(keys)]
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 10, capacity_slack=4.0, schema=sch
    )
    state = table.build(keys, values=vals)
    queries = keys[: (len(keys) // 8) * 8][:256]
    res = table.retrieve(state, queries, out_capacity=8, seg_capacity=8)
    assert int(res.num_dropped) > 0


# ---------------------------------------------------------------------------
# dynamic output buffers + seg planning + kernel path
# ---------------------------------------------------------------------------


def test_retrieve_auto_doubles_until_fit(mesh8):
    rng = np.random.default_rng(19)
    sch = TableSchema("uint64", 2)
    base, keys, vals, _, oracle = _schema_case(rng, sch, 64, 8)
    keys = keys[: (len(keys) // 8) * 8]
    vals = vals[: len(keys)]
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 10, schema=sch)
    state = table.build(keys, values=vals)
    queries = keys[:256]
    # tiny initial capacity must overflow, auto must recover exactly
    res = table.retrieve_auto(
        state, queries, out_capacity=8, seg_capacity=8, max_retries=10
    )
    assert int(res.num_dropped) == 0
    # values match the non-auto reference run
    ref = table.retrieve(state, queries, out_capacity=8192, seg_capacity=8192)
    got = retrieval_to_lists(res)
    want = retrieval_to_lists(ref)
    for g, w in zip(got, want):
        assert sorted(map(tuple, np.asarray(g).tolist())) == sorted(
            map(tuple, np.asarray(w).tolist())
        )
    # bounded: zero retries keeps the (reported) overflow
    res0 = table.retrieve_auto(
        state, queries, out_capacity=8, seg_capacity=8, max_retries=0
    )
    assert int(res0.num_dropped) > 0


def test_inner_join_auto_doubles_until_fit(mesh8):
    rng = np.random.default_rng(23)
    sch = TableSchema("uint32", 1)
    base, keys, vals, _, _ = _schema_case(rng, sch, 64, 8)
    keys = keys[: (len(keys) // 8) * 8]
    vals = vals[: len(keys)]
    oracle = defaultdict(list)
    for k, v in zip(keys.tolist(), vals.tolist()):
        oracle[int(k)].append(int(v))
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 10, schema=sch)
    state = table.build(keys, values=vals)
    queries = keys[:256]
    join = table.inner_join_auto(
        state, queries, out_capacity=8, seg_capacity=8, max_retries=10
    )
    assert int(join.num_dropped) == 0
    wantp = sorted(
        (i, v) for i, q in enumerate(queries) for v in oracle[int(q)]
    )
    assert sorted(map(tuple, join_to_pairs(join).tolist())) == wantp


def test_seg_capacity_planning_matches_explicit(mesh8):
    """seg_capacity=None sizes segments exactly from the counts round."""
    rng = np.random.default_rng(29)
    sch = TableSchema("uint64", 1)
    base, keys, vals, _, oracle = _schema_case(rng, sch, 128, 8)
    keys = keys[: (len(keys) // 8) * 8]
    vals = vals[: len(keys)]
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, schema=sch)
    state = table.build(keys, values=vals)
    queries = keys[:512]
    planned = table.retrieve(state, queries, out_capacity=8192, seg_capacity=None)
    explicit = table.retrieve(state, queries, out_capacity=8192, seg_capacity=8192)
    assert int(planned.num_dropped) == 0
    got = retrieval_to_lists(planned)
    want = retrieval_to_lists(explicit)
    for g, w in zip(got, want):
        assert sorted(np.asarray(g).tolist()) == sorted(np.asarray(w).tolist())


@pytest.mark.parametrize(
    "sch", [TableSchema("uint32", 1), TableSchema("uint64", 3)],
    ids=lambda s: f"{s.key_dtype}x{s.value_cols}",
)
def test_kernel_path_matches_jnp_path(sch, mesh8):
    """ROADMAP kernel-path retrieval: Pallas csr_gather wired into
    _retrieve_parts agrees bit-for-bit with the jnp path (interpret mode
    stands in for the TPU lowering on this CPU-only CI)."""
    rng = np.random.default_rng(31)
    base, keys, vals, _, _ = _schema_case(rng, sch, 96, 6)
    keys = keys[: (len(keys) // 8) * 8]
    vals = vals[: len(keys)]
    kw = dict(hash_range=1 << 11, schema=sch)
    t_jnp = DistributedHashTable(mesh8, ("d",), use_kernel=False, **kw)
    t_krn = DistributedHashTable(mesh8, ("d",), use_kernel=True, **kw)
    state = t_jnp.build(keys, values=vals)
    queries = keys[:256]
    a = t_jnp.retrieve(state, queries, out_capacity=4096, seg_capacity=4096)
    b = t_krn.retrieve(state, queries, out_capacity=4096, seg_capacity=4096)
    np.testing.assert_array_equal(np.asarray(a.offsets), np.asarray(b.offsets))
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert int(a.num_dropped) == int(b.num_dropped) == 0
    ja = t_jnp.inner_join(state, queries, out_capacity=4096, seg_capacity=4096)
    jb = t_krn.inner_join(state, queries, out_capacity=4096, seg_capacity=4096)
    np.testing.assert_array_equal(np.asarray(ja.query_idx), np.asarray(jb.query_idx))
    np.testing.assert_array_equal(np.asarray(ja.values), np.asarray(jb.values))


def test_csr_gather_kernel_lane_aware():
    """kernels.ops.csr_gather on a (Tn, C) table == per-run numpy oracle."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(37)
    n_rows = 40
    counts = rng.integers(0, 5, n_rows).astype(np.int32)
    tn = 128
    starts = rng.integers(0, tn - 5, n_rows).astype(np.int32)
    table = rng.integers(-1000, 1000, (tn, 3)).astype(np.int32)
    cap = int(counts.sum()) + 8
    off, rows, vals, dropped = ops.csr_gather(
        jnp.asarray(starts), jnp.asarray(counts), jnp.asarray(table),
        capacity=cap, interpret=True,
    )
    want_vals, want_rows = ref.csr_gather_ref(starts, counts, table, cap)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(want_vals))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(want_rows))
    assert int(dropped) == 0
    # core jnp idiom agrees too
    _, rows2, vals2, _ = hashgraph.csr_gather(
        jnp.asarray(starts), jnp.asarray(counts), jnp.asarray(table), cap
    )
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(vals2))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(rows2))


# ---------------------------------------------------------------------------
# the uint32 1-column schema is bit-identical to the schema-free API
# ---------------------------------------------------------------------------


def test_default_schema_is_prior_api(mesh8):
    rng = np.random.default_rng(41)
    keys = rng.integers(0, 1 << 20, 1024, dtype=np.uint32)
    vals = np.arange(1024, dtype=np.int32)
    t_default = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    t_schema = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, schema=TableSchema("uint32", 1)
    )
    s1 = t_default.build(jnp.asarray(keys), values=jnp.asarray(vals))
    s2 = t_schema.build(keys, values=vals)
    q = keys[:256]
    np.testing.assert_array_equal(
        np.asarray(t_default.query(s1, jnp.asarray(q))),
        np.asarray(t_schema.query(s2, q)),
    )
    a = t_default.retrieve(s1, jnp.asarray(q), out_capacity=2048, seg_capacity=2048)
    b = t_schema.retrieve(s2, q, out_capacity=2048, seg_capacity=2048)
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))
    np.testing.assert_array_equal(np.asarray(a.offsets), np.asarray(b.offsets))
